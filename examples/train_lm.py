"""End-to-end training driver: a ~100M-param LM for a few hundred steps.

Defaults are sized for this 1-core CPU container (a ~10M slice of the
qwen1.5 family, 120 steps, checkpoint+resume live); ``--hundred-m`` uses
the real ~100M config (run it on actual hardware), and
``--arch <id> --full`` trains any assigned architecture's published config
on the production mesh.

    PYTHONPATH=src python examples/train_lm.py
    PYTHONPATH=src python examples/train_lm.py --hundred-m --steps 300
"""
import argparse
import dataclasses

import jax

from repro.configs import ARCHS
from repro.models.api import build_model
from repro.models.common import ShapeCfg
from repro.models.parallel import ParallelCfg
from repro.optim import AdamWConfig
from repro.train import TrainConfig, Trainer


def small_cfg():
    return dataclasses.replace(
        ARCHS["qwen1.5-0.5b"].reduced(), name="qwen-tiny-10m",
        n_layers=4, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
        d_ff=768, vocab_size=8192, vocab_pad_multiple=256)


def hundred_m_cfg():
    return dataclasses.replace(
        ARCHS["qwen1.5-0.5b"], name="qwen-100m",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
        d_ff=2048, vocab_size=32768, tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--hundred-m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = hundred_m_cfg() if args.hundred_m else small_cfg()
    model = build_model(cfg)
    print(f"model {cfg.name}: {cfg.param_count():,} params, "
          f"{len(jax.devices())} device(s)")
    tc = TrainConfig(
        steps=args.steps, ckpt_every=max(args.steps // 4, 10),
        log_every=max(args.steps // 15, 1),
        opt=AdamWConfig(lr=6e-4, warmup_steps=args.steps // 10,
                        total_steps=args.steps))
    tr = Trainer(model, cfg, ParallelCfg(mesh=None, remat="none"), tc,
                 shape=ShapeCfg("ex", "train", args.seq, args.batch),
                 ckpt_dir=args.ckpt_dir)
    start = tr.resume()
    if start:
        print(f"resumed from checkpoint at step {start}")
    for m in tr.run():
        print(f"  step {m['step']:4d}  loss {m['loss']:.4f}  "
              f"lr {m['lr']:.2e}  {m['sec']:.2f}s/step")
    h = tr.history
    print(f"loss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f} over "
          f"{args.steps} steps (ckpts in {args.ckpt_dir})")


if __name__ == "__main__":
    main()
