"""Quickstart: the paper's result in one minute.

Generates a paper-style FJSP instance (10 jobs x 4 DAG tasks, 5 servers),
solves the bi-level problem (optimal makespan -> carbon-minimal schedule
under the same makespan), and prints the schedules + savings.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core import generate_instance, pack, synthesize, validate
from repro.core.carbon import sample_window
from repro.core.solvers import solve_bilevel
from repro.core.solvers.annealing import SAConfig


def timeline(start, dur, assign, mask, M, width=80):
    """ASCII Gantt: one row per machine."""
    T = len(start)
    end = int(max(start[t] + dur[t] for t in range(T) if mask[t]))
    scale = max(1, -(-end // width))
    rows = []
    for m in range(M):
        row = ["."] * (end // scale + 1)
        for t in range(T):
            if mask[t] and assign[t] == m:
                for e in range(start[t], start[t] + dur[t]):
                    row[e // scale] = chr(ord("A") + t % 26)
        rows.append(f"  m{m}: " + "".join(row))
    return "\n".join(rows)


def main():
    rng = np.random.default_rng(7)
    inst = generate_instance(rng, n_jobs=10, k_tasks=4, n_machines=5)
    p = pack(inst)
    trace = synthesize("AU-SA", days=30)
    window = sample_window(trace, rng, 1200)
    cum = jnp.asarray(window.cumulative())

    print(f"instance: {inst.n_jobs} jobs, {inst.n_tasks} tasks, "
          f"{inst.n_machines} servers; AU-SA carbon trace")
    res = solve_bilevel(p, cum, jax.random.key(0), objective="carbon",
                        stretch=1.0, cfg1=SAConfig(pop=96, iters=150),
                        cfg2=SAConfig(pop=96, iters=150))
    dur = np.asarray(p.dur)
    base, opt = res.baseline, res.optimized
    mask = np.asarray(p.task_mask)

    # Both schedules through the shared validator (Eqs. 4-8 + deadline).
    validate.assert_feasible_np(p, np.asarray(base.start),
                                np.asarray(base.assign), ctx="baseline")
    validate.assert_feasible_np(p, np.asarray(opt.start),
                                np.asarray(opt.assign),
                                deadline=int(res.deadline),
                                ctx="carbon-aware")

    print(f"\noptimal makespan (carbon-agnostic): {int(res.opt_makespan)} "
          f"epochs ({int(res.opt_makespan) / 4:.1f} h)")
    print(timeline(np.asarray(base.start),
                   dur[np.arange(p.T), np.asarray(base.assign)],
                   np.asarray(base.assign), mask, p.M))
    print(f"  carbon: {float(base.carbon):,.0f} gCO2   "
          f"energy: {float(base.energy):.1f} kWh")

    print(f"\ncarbon-aware schedule (same makespan bound, S=1):")
    print(timeline(np.asarray(opt.start),
                   dur[np.arange(p.T), np.asarray(opt.assign)],
                   np.asarray(opt.assign), mask, p.M))
    print(f"  carbon: {float(opt.carbon):,.0f} gCO2   "
          f"energy: {float(opt.energy):.1f} kWh")
    print(f"\n=> carbon savings at S=1: "
          f"{100 * float(res.carbon_savings):.1f}% "
          f"(paper: ~25% avg homogeneous)")


if __name__ == "__main__":
    main()
