"""Flagship example: a day of ML batch jobs, scheduled carbon-aware.

Builds a daily batch of real workloads (offline inference / training
pipelines / finetune sweeps over the assigned architectures), prices each
task on heterogeneous v5e slices via the roofline energy model, solves the
paper's bi-level FJSP (makespan-optimal baseline -> carbon-aware under
S x OPT), then EXECUTES the schedule in the cluster simulator with a
mid-run machine failure to show elastic re-solve + checkpoint restart.

    PYTHONPATH=src python examples/cluster_sim.py [--jobs 6] [--stretch 1.5]
"""
import argparse

import numpy as np

import jax.numpy as jnp

from repro.cluster import ClusterExecutor, make_cluster_instance
from repro.cluster.executor import FaultPlan
from repro.cluster.workloads import sample_daily_batch
from repro.core import pack, synthesize, validate
from repro.core.carbon import sample_window


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=6)
    ap.add_argument("--stretch", type=float, default=1.5)
    ap.add_argument("--region", default="AU-SA")
    ap.add_argument("--seed", type=int, default=3)
    args = ap.parse_args()

    rng = np.random.default_rng(args.seed)
    specs = sample_daily_batch(rng, n_jobs=args.jobs)
    print("today's batch:")
    for s in specs:
        print(f"  {s.template:18s} {s.arch:14s} {s.n_steps:4d} steps, "
              f"arrives epoch {s.arrival}")
    inst = make_cluster_instance(specs, seed=args.seed)
    p = pack(inst)
    trace = synthesize(args.region, days=30)
    cum = jnp.asarray(sample_window(trace, rng, 2000).cumulative())

    ex = ClusterExecutor(p, cum, stretch=args.stretch, seed=args.seed)
    plan = ex.plan()
    # Shared validator (Eqs. 4-8) before anything executes.
    validate.assert_feasible_np(p, plan["start"], plan["assign"],
                                ctx="cluster plan")
    print(f"\ncarbon-aware plan (S={args.stretch}): makespan "
          f"{plan['makespan']} epochs, carbon {plan['carbon']:,.0f} gCO2")

    clean = ex.execute(plan)
    print(f"clean execution : makespan {clean.achieved_makespan}, carbon "
          f"{clean.achieved_carbon:,.0f} gCO2 "
          f"(overhead {100 * clean.recovery_overhead:.1f}%)")

    fault = FaultPlan(fail_machine=2, fail_epoch=plan["makespan"] // 3)
    faulty = ex.execute(plan, fault)
    print(f"with machine-2 failure @ epoch {fault.fail_epoch}: "
          f"makespan {faulty.achieved_makespan}, "
          f"carbon {faulty.achieved_carbon:,.0f} gCO2, "
          f"{faulty.n_resolves} re-solve(s), {faulty.n_restarts} "
          f"restart(s), overhead {100 * faulty.recovery_overhead:.1f}%")

    slow = ex.execute(plan, FaultPlan(straggle_task=1, straggle_factor=3.0))
    print(f"with a 3x straggler on task 1: makespan "
          f"{slow.achieved_makespan}, {slow.n_speculative} speculative "
          f"cop(y/ies) issued")


if __name__ == "__main__":
    main()
