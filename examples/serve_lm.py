"""Batched-serving example: continuous batching over mixed requests.

Runs the ServeEngine (prefill + pooled decode with per-lane positions)
over a queue of synthetic prompts on a reduced config, and prints
per-request outputs + aggregate throughput.

    PYTHONPATH=src python examples/serve_lm.py [--arch mamba2-370m]
"""
import argparse
import time

import numpy as np

import jax

from repro.configs import ALL_ARCHS, ARCHS
from repro.models.api import build_model
from repro.models.params import init_params
from repro.models.parallel import ParallelCfg
from repro.serve import Request, ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=ALL_ARCHS)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced()
    model = build_model(cfg)
    params = init_params(jax.random.key(0), model.defs)
    par = ParallelCfg(mesh=None, remat="none")
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(
                0, cfg.vocab_size, args.prompt_len).astype(np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    eng = ServeEngine(model, params, cfg, par,
                      ServeConfig(batch_slots=args.slots,
                                  max_len=args.prompt_len + args.max_new + 8))
    t0 = time.perf_counter()
    done = eng.run(reqs)
    dt = time.perf_counter() - t0
    for r in sorted(done, key=lambda r: r.rid):
        print(f"req {r.rid}: prompt[:6]={r.prompt[:6].tolist()} -> "
              f"out={r.out_tokens}")
    n = sum(len(r.out_tokens) for r in done)
    print(f"{len(done)} requests, {n} tokens, {dt:.1f}s "
          f"({n / dt:.1f} tok/s, {args.slots} lanes)")


if __name__ == "__main__":
    main()
