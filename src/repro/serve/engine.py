"""Batched serving engine: prefill + decode with continuous batching.

A fixed pool of ``batch_slots`` decode lanes runs one jit'd decode step per
tick over the whole pool (caches are [L, B, ...] arrays — the exact shapes
the ``decode_*`` dry-run cells lower).  New requests are prefilled
individually (a second jit'd program) and their caches inserted into a free
lane; finished lanes (EOS or ``max_new``) are evicted and refilled —
vLLM-style continuous batching reduced to its JAX-native core.

Greedy and temperature sampling; per-request token logs; deterministic
given the seed.  The engine is what ``examples/serve_lm.py`` and the
offline-inference cluster workload drive.  Lane occupancy lives in the
shared :class:`repro.serve.lanes.LanePool` — the same insert/step/evict
shape the streaming dispatch engine (:mod:`repro.stream`) reuses for
scheduling instead of decoding.

Semantics contracts (regression-locked in ``tests/test_serve.py``):

* ``max_new`` counts **decode** tokens; the prefill-sampled continuation
  token is emitted in addition (``out_tokens`` holds ``1 + max_new`` ids
  for an un-truncated, non-EOS request);
* a request evicted at the ``max_len`` KV horizon before reaching
  ``max_new``/EOS is surfaced with ``truncated=True``, never silently;
* ``run`` drains the lane pool before returning — unfinished requests come
  back ``done=False`` *and* their lanes are freed, so back-to-back ``run``
  calls on one engine never re-serve stale lanes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from repro.models.common import ArchConfig
from repro.models.parallel import ParallelCfg
from repro.obs import MetricsRegistry, Tracer, get_tracer
from repro.serve.lanes import LanePool


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [S] int32
    max_new: int = 16
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    truncated: bool = False            # evicted at the max_len KV horizon


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch_slots: int = 4
    max_len: int = 256                 # KV-cache horizon per lane
    temperature: float = 0.0           # 0 = greedy
    eos_id: int = -1                   # -1: never EOS (synthetic vocab)
    seed: int = 0


class ServeEngine:
    def __init__(self, model: Model, params, cfg: ArchConfig,
                 par: ParallelCfg, sc: ServeConfig = ServeConfig(),
                 tracer: Tracer | None = None,
                 metrics: MetricsRegistry | None = None):
        self.model, self.params, self.cfg, self.par, self.sc = \
            model, params, cfg, par, sc
        # Host-side telemetry (repro.obs): never inside jitted code, so
        # sampled tokens are bit-exact with tracing on or off.  The tick
        # index is the simulation clock for trace timestamps.
        self.tracer = tracer if tracer is not None else get_tracer()
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._wall_seen: set[str] = set()
        self._tick = 0
        self._decode = jax.jit(
            lambda p, b: model.decode(p, b, cfg, par))
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cfg, par))
        self._key = jax.random.key(sc.seed)
        self.caches: dict[str, Any] | None = None
        self.lanes = LanePool(sc.batch_slots)
        self.lane_pos = np.zeros(sc.batch_slots, np.int32)

    # -- cache pool -----------------------------------------------------------
    def _init_caches(self, template: dict) -> None:
        """Allocate the lane pool from a single-request prefill's caches.

        KV time dims are resized to the ``max_len`` horizon; SSM/conv/cross
        caches keep their shapes."""
        B, M = self.sc.batch_slots, self.sc.max_len
        pool = {}
        for k, v in template.items():
            shape = (v.shape[0], B) + v.shape[2:]
            if k in ("k_cache", "v_cache"):
                W = min(v.shape[2], M) if self.cfg.attn_window else M
                shape = (v.shape[0], B, W) + v.shape[3:]
            pool[k] = jnp.zeros(shape, v.dtype)
        self.caches = pool

    def _insert(self, lane: int, caches_1: dict, prompt_len: int) -> None:
        for k, v in caches_1.items():
            pool = self.caches[k]
            if k in ("k_cache", "v_cache"):
                W = pool.shape[2]
                if v.shape[2] >= W:
                    src = v[:, :, :W]
                else:
                    src = jnp.pad(v, [(0, 0), (0, 0), (0, W - v.shape[2])]
                                  + [(0, 0)] * (v.ndim - 3))
            else:
                src = v
            self.caches[k] = pool.at[:, lane].set(src[:, 0])

    # -- telemetry ------------------------------------------------------------
    def _observe_wall(self, name: str, seconds: float) -> None:
        """first = jit compile + execute span (or a warm process-cache hit);
        the rest are warm steps — the compile/warm split summary() reports."""
        suffix = "_first" if name not in self._wall_seen else "_warm"
        self._wall_seen.add(name)
        self.metrics.histogram(name + suffix).observe(seconds)

    def summary(self) -> dict:
        """Aggregate view of the last ``run`` from the metrics registry."""
        snap = self.metrics.snapshot()
        return {
            "requests_admitted": snap.get("requests_admitted", 0),
            "requests_completed": snap.get("requests_completed", 0),
            "requests_truncated": snap.get("requests_truncated", 0),
            "decode_tokens": snap.get("decode_tokens", 0),
            "ticks": snap.get("ticks", 0),
            "wall": {k: v for k, v in snap.items()
                     if k.startswith(("decode_wall_s", "prefill_wall_s"))},
        }

    # -- scheduling -----------------------------------------------------------
    def _admit(self, queue: list[Request]) -> None:
        for lane, req in self.lanes.admit(queue):
            t0 = time.perf_counter()
            batch = {"tokens": jnp.asarray(req.prompt[None, :])}
            if self.cfg.n_encoder_layers:
                batch["frame_embeds"] = jnp.zeros(
                    (1, len(req.prompt), self.cfg.d_model), jnp.bfloat16)
            if self.cfg.frontend == "vision_stub":
                P = min(self.cfg.n_frontend_tokens, 8)
                batch["patch_embeds"] = jnp.zeros(
                    (1, P, self.cfg.d_model), jnp.bfloat16)
            logits, caches_1 = self._prefill(self.params, batch)
            jax.block_until_ready(caches_1)   # prefill_wall_s covers the solve
            if self.caches is None:
                self._init_caches(caches_1)
            self._insert(lane, caches_1, len(req.prompt))
            tok = self._sample(logits)[0]
            req.out_tokens.append(int(tok))
            self.lane_pos[lane] = len(req.prompt)
            self._observe_wall("prefill_wall_s", time.perf_counter() - t0)
            self.metrics.counter("requests_admitted").inc()
            self.tracer.instant("admit", self._tick, rid=req.rid, lane=lane,
                                prompt_len=len(req.prompt))

    def _sample(self, logits: jnp.ndarray) -> np.ndarray:
        logits = logits[..., :self.cfg.vocab_size]
        if self.sc.temperature <= 0:
            return np.asarray(jnp.argmax(logits, -1))
        self._key, k = jax.random.split(self._key)
        return np.asarray(jax.random.categorical(
            k, logits / self.sc.temperature))

    # -- main loop ------------------------------------------------------------
    def run(self, requests: list[Request], max_ticks: int = 10_000
            ) -> list[Request]:
        queue = list(requests)
        done: list[Request] = []
        self.metrics.reset()
        self._wall_seen = set()
        self._tick = 0
        for _ in range(max_ticks):
            self._admit(queue)
            active = [l for l, _ in self.lanes.active()]
            if not active:
                if not queue:
                    break
                continue
            if self.tracer.enabled:
                self.tracer.counter("lanes_active", self._tick, len(active))
            t0 = time.perf_counter()
            # Pool decode tick: every lane advances one token at its own
            # position (decode_step supports per-lane pos vectors).
            last = jnp.asarray(
                [r.out_tokens[-1] if r else 0 for r in self.lanes.payloads()],
                jnp.int32)[:, None]
            batch = {"token": last, "pos": jnp.asarray(self.lane_pos),
                     **self.caches}
            logits, self.caches = self._decode(self.params, batch)
            toks = self._sample(logits)          # host sync (np.asarray)
            self._observe_wall("decode_wall_s", time.perf_counter() - t0)
            self.metrics.counter("ticks").inc()
            self.metrics.counter("decode_tokens").inc(len(active))
            for lane in active:
                req = self.lanes.payload(lane)
                req.out_tokens.append(int(toks[lane]))
                self.lane_pos[lane] += 1
                # max_new counts *decode* tokens — the prefill-sampled token
                # (out_tokens[0]) is in addition, not one of the max_new.
                n_decode = len(req.out_tokens) - 1
                finished = (toks[lane] == self.sc.eos_id
                            or n_decode >= req.max_new)
                horizon = self.lane_pos[lane] >= self.sc.max_len - 1
                if finished or horizon:
                    req.done = True
                    req.truncated = bool(horizon and not finished)
                    done.append(req)
                    self.lanes.evict(lane)
                    self.metrics.counter("requests_completed").inc()
                    if req.truncated:
                        self.metrics.counter("requests_truncated").inc()
                    self.tracer.instant("evict", self._tick, rid=req.rid,
                                        lane=lane, tokens=len(req.out_tokens),
                                        truncated=req.truncated)
            self._tick += 1
        # Drain: whatever is still in flight comes back done=False, but its
        # lane is freed — a second run() on this engine starts clean instead
        # of double-serving stale lanes.
        leftover = self.lanes.drain()
        self.lane_pos[:] = 0
        return done + leftover
