from repro.serve.engine import Request, ServeConfig, ServeEngine
from repro.serve.lanes import LanePool

__all__ = ["LanePool", "Request", "ServeConfig", "ServeEngine"]
