"""Lane-pool bookkeeping for continuous-batching engines.

Both long-running engines in this repo have the same host-side shape: a
fixed pool of ``n_lanes`` slot lanes whose device arrays stay shape-static,
a FIFO queue of pending work, one jitted step over the whole pool per tick,
and insert/evict between ticks.  :class:`LanePool` is that shape hoisted
out of :class:`repro.serve.engine.ServeEngine` (decode lanes holding
requests) so :class:`repro.stream.engine.StreamEngine` (dispatch lanes
holding DAG jobs) reuses it instead of growing a second copy.

The pool tracks *which lane holds which payload* — nothing else.  Device
state (caches, dispatch progress) stays with the engine; an empty lane's
device rows are inert by the engine's own padding convention.
"""
from __future__ import annotations

from typing import Any, Callable, Iterator


class LanePool:
    """Host-side occupancy of a fixed pool of slot lanes.

    Payloads are arbitrary (a serve ``Request``, a stream job record).
    ``admit`` fills free lanes from a FIFO queue (or, via its ``select``
    policy hook, from the ready prefix in policy order); ``evict`` frees one
    lane; ``drain`` empties the pool (the end-of-run reset that makes
    engines re-entrant — see the ``ServeEngine.run`` re-entry fix).
    """

    def __init__(self, n_lanes: int):
        if n_lanes < 1:
            raise ValueError(f"LanePool needs >= 1 lane, got {n_lanes}")
        self._slots: list[Any] = [None] * n_lanes

    @property
    def n_lanes(self) -> int:
        return len(self._slots)

    def payload(self, lane: int) -> Any:
        """The payload in ``lane`` (None if free)."""
        return self._slots[lane]

    def payloads(self) -> list[Any]:
        """All slots in lane order (None where free) — for building per-lane
        device inputs."""
        return list(self._slots)

    def free_lanes(self) -> list[int]:
        return [l for l, s in enumerate(self._slots) if s is None]

    def active(self) -> Iterator[tuple[int, Any]]:
        """(lane, payload) pairs for occupied lanes, in lane order."""
        return ((l, s) for l, s in enumerate(self._slots) if s is not None)

    def any_active(self) -> bool:
        return any(s is not None for s in self._slots)

    def insert(self, lane: int, payload: Any) -> None:
        if self._slots[lane] is not None:
            raise ValueError(f"lane {lane} is occupied")
        if payload is None:
            raise ValueError("payload must not be None (None marks a free "
                             "lane)")
        self._slots[lane] = payload

    def evict(self, lane: int) -> Any:
        """Free ``lane``, returning its payload."""
        payload = self._slots[lane]
        if payload is None:
            raise ValueError(f"lane {lane} is already free")
        self._slots[lane] = None
        return payload

    def admit(self, queue, ready: Callable[[Any], bool] | None = None,
              select: Callable[[list], int] | None = None
              ) -> list[tuple[int, Any]]:
        """Fill free lanes from ``queue`` (removed in place).

        ``queue`` is any mutable sequence; a ``collections.deque`` makes the
        default FIFO pop O(1) — with a plain list every admission shifts the
        whole backlog (the O(n^2)-under-load behavior the stream engine's
        deque fixed; a list still works, for callers that don't care).

        ``ready`` (optional) guards eligibility — with ``queue`` sorted by
        readiness (arrival order), the eligible items are exactly the prefix
        passing ``ready``, and admission stops when the head fails it (a
        stream job that hasn't *arrived* yet must not jump the FIFO order).

        ``select`` (optional) is the admission-policy hook: given the list
        of currently-eligible payloads (the ready prefix, queue order), it
        returns the index of the one to admit next.  ``None`` is FIFO
        (always index 0).  Policies only reorder *within* the ready set, so
        the not-yet-ready tail can never be jumped into a lane.

        Returns the ``(lane, payload)`` placements so the engine can run its
        per-admission device work (prefill, greedy/budget solve) for exactly
        the new payloads.
        """
        placed: list[tuple[int, Any]] = []
        for lane in self.free_lanes():
            if not queue or (ready is not None and not ready(queue[0])):
                break
            if select is None:
                item = (queue.popleft() if hasattr(queue, "popleft")
                        else queue.pop(0))
            else:
                n_ready = len(queue)
                if ready is not None:
                    n_ready = 0
                    for x in queue:
                        if not ready(x):
                            break
                        n_ready += 1
                i = int(select([queue[k] for k in range(n_ready)]))
                if not 0 <= i < n_ready:
                    raise ValueError(
                        f"admission policy chose index {i} outside the "
                        f"ready prefix of length {n_ready}")
                item = queue[i]
                del queue[i]
            self._slots[lane] = item
            placed.append((lane, item))
        return placed

    def drain(self) -> list[Any]:
        """Evict every occupied lane; returns the payloads in lane order."""
        out = [s for s in self._slots if s is not None]
        self._slots = [None] * len(self._slots)
        return out
