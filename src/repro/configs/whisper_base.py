"""whisper-base — [audio] 6L d_model=512 8H (kv=8) d_ff=2048 vocab=51865,
enc-dec with conv frontend STUB (``input_specs`` supplies precomputed frame
embeddings).  [arXiv:2212.04356; unverified]
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,
    n_encoder_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    tie_embeddings=True,
    act="gelu",
    norm="layernorm",
    pos="sinusoidal",
    frontend="audio_stub",
    notes="conv frontend stubbed; decode cells exercise a 32k self-KV shape",
)
