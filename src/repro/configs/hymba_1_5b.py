"""hymba-1.5b — [hybrid] 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attention + mamba heads per block,
sliding-window attention (2048).  [arXiv:2411.13676; hf]

SSM head-dim chosen as 100 so the 3200-wide inner dim splits into 32 heads
(divisible by the 16-way tensor axis).  Sub-quadratic (SWA + SSM) -> runs
``long_500k``.
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    act="silu_glu",
    norm="rmsnorm",
    pos="rope",
    rope_theta=1e4,
    attn_window=2048,
    ssm_state=16,
    ssm_headdim=100,
    ssm_expand=2,
    ssm_conv=4,
    ssm_groups=1,
    ssm_chunk=256,
)
