"""mamba2-370m — [ssm] 48L d_model=1024 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality).  [arXiv:2405.21060; unverified]

Constant-size recurrent state -> runs the ``long_500k`` shape.
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    norm="rmsnorm",
    pos="none",
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_groups=1,
    ssm_chunk=256,
)
