"""Architecture registry: ``--arch <id>`` resolves here.

Each assigned architecture lives in its own module with the exact published
dims; ``get(name)`` returns the ArchConfig, ``ALL_ARCHS`` lists every id.
"""
from __future__ import annotations

from repro.configs import (codeqwen15_7b, deepseek_67b, hymba_1_5b,
                           kimi_k2_1t_a32b, llava_next_34b, mamba2_370m,
                           minitron_4b, qwen15_05b, qwen3_moe_30b_a3b,
                           whisper_base)
from repro.models.common import ArchConfig

_MODULES = (llava_next_34b, codeqwen15_7b, deepseek_67b, minitron_4b,
            qwen15_05b, whisper_base, mamba2_370m, qwen3_moe_30b_a3b,
            kimi_k2_1t_a32b, hymba_1_5b)

ARCHS: dict[str, ArchConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}
ALL_ARCHS = tuple(ARCHS)


def get(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]
