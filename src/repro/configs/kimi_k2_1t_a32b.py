"""kimi-k2-1t-a32b — [moe] 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384 experts top-8 (+1 shared) — trillion-param MoE
(paper-table).  [arXiv:2501.kimi2; unverified]

Routed experts alone: 61 x 384 x 3 x 7168 x 2048 ~ 1.03e12 params.
The ZeRO-3 / expert-parallel stress case of the suite.
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=2048,
    vocab_size=163840,
    act="silu_glu",
    norm="rmsnorm",
    pos="rope",
    rope_theta=5e7,
    n_experts=384,
    experts_per_token=8,
    n_shared_experts=1,
    capacity_factor=1.25,
    router_aux_weight=0.001,
)
