"""qwen3-moe-30b-a3b — [moe] 48L d_model=2048 32H (GQA kv=4) d_ff=768
vocab=151936, MoE 128 experts top-8, QK-norm.  [hf:Qwen/Qwen3-30B-A3B; hf]
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    qk_norm=True,
    act="silu_glu",
    norm="rmsnorm",
    pos="rope",
    rope_theta=1e6,
    n_experts=128,
    experts_per_token=8,
    capacity_factor=1.25,
    router_aux_weight=0.001,
)
