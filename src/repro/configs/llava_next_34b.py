"""llava-next-34b — [vlm] 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000, anyres tiling.  [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

The Yi-34B-style language backbone; the anyres vision tower is a STUB per
the assignment: ``input_specs`` supplies precomputed patch embeddings
(2880 tokens ~ base tile + 4 anyres tiles x 576 patches).
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    act="silu_glu",
    norm="rmsnorm",
    pos="rope",
    rope_theta=5e6,
    frontend="vision_stub",
    n_frontend_tokens=2880,
    notes="anyres vision frontend stubbed (precomputed patch embeddings)",
)
