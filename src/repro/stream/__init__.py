from repro.stream.arrivals import (ARRIVAL_NAMES, ARRIVALS, bursty, diurnal,
                                   poisson, sample_arrivals)
from repro.stream.engine import (StreamConfig, StreamEngine, StreamJob,
                                 StreamResult, event_log, sample_stream_jobs,
                                 simulate_stream)

__all__ = [
    "ARRIVALS", "ARRIVAL_NAMES", "poisson", "bursty", "diurnal",
    "sample_arrivals", "StreamConfig", "StreamEngine", "StreamJob",
    "StreamResult", "event_log", "sample_stream_jobs", "simulate_stream",
]
