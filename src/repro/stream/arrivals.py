"""Arrival-process families for continuous DAG job streams.

Everything through PR 5 is closed-batch: all instances known at t=0.  Real
carbon-aware clusters (PCAPS, CarbonFlex; gym-sparksched's
``job_arrival_rate``) see a *stream* of DAG jobs competing for the fleet.
This module is the arrival-time analogue of :mod:`repro.scenarios.families`:
seeded, parametric generators of arrival epochs, one per qualitative traffic
shape:

========== =====================================================
family     arrival process (rate = mean jobs per epoch)
========== =====================================================
poisson    homogeneous Poisson: iid exponential gaps
bursty     compound Poisson: burst centers at ``rate/mean_burst``,
           geometric(mean ``mean_burst``) jobs per burst arriving
           together — the queue-stressing shape
diurnal    inhomogeneous Poisson (thinning): intensity swings
           ``rate * (1 ± amp)`` over the 96-epoch day, peaking at
           ``peak_epoch`` — office-hours traffic
========== =====================================================

Contracts (property-tested in ``tests/test_stream.py``): arrival times are
sorted, lie in ``[0, horizon)``, are bit-identical across processes for
equal ``(family, rng seed, rate, horizon)``, and honor ``rate`` in
expectation (each family's mean job count is ``rate * horizon``).

Adding a family: write ``def myfam(rng, rate, horizon) -> np.ndarray`` of
sorted float times in ``[0, horizon)`` and register it in :data:`ARRIVALS`;
:func:`sample_arrivals` floors to integer epochs.
"""
from __future__ import annotations

import numpy as np

from repro.core.carbon import EPOCHS_PER_DAY


def poisson(rng: np.random.Generator, rate: float, horizon: int
            ) -> np.ndarray:
    """Homogeneous Poisson at ``rate`` jobs/epoch: exponential gaps."""
    times, t = [], float(rng.exponential(1.0 / rate))
    while t < horizon:
        times.append(t)
        t += float(rng.exponential(1.0 / rate))
    return np.asarray(times, dtype=np.float64)


def bursty(rng: np.random.Generator, rate: float, horizon: int,
           mean_burst: float = 4.0) -> np.ndarray:
    """Compound Poisson: Poisson burst centers at ``rate / mean_burst``,
    each burst geometric(mean ``mean_burst``) jobs arriving together —
    overall job rate is ``rate``, variance is ~``2 * mean_burst - 1`` times
    Poisson's, so equal-load streams stress the lane queue much harder."""
    centers = poisson(rng, rate / mean_burst, horizon)
    times: list[float] = []
    for c in centers:
        times.extend([float(c)] * int(rng.geometric(1.0 / mean_burst)))
    return np.asarray(times, dtype=np.float64)


def diurnal(rng: np.random.Generator, rate: float, horizon: int,
            amp: float = 0.8, peak_epoch: float = 56.0) -> np.ndarray:
    """Inhomogeneous Poisson via thinning: intensity
    ``rate * (1 + amp * cos(2*pi*(t - peak_epoch) / 96))`` — a day-periodic
    swing peaking at ``peak_epoch`` (default 14:00, office hours).  The
    cosine integrates to zero over a day, so the mean rate is ``rate``."""
    if not 0.0 <= amp <= 1.0:
        raise ValueError(f"diurnal amp must be in [0, 1], got {amp}")
    lam_max = rate * (1.0 + amp)
    times = []
    for t in poisson(rng, lam_max, horizon):
        lam = rate * (1.0 + amp * np.cos(
            2.0 * np.pi * (t - peak_epoch) / EPOCHS_PER_DAY))
        if float(rng.random()) * lam_max < lam:
            times.append(float(t))
    return np.asarray(times, dtype=np.float64)


ARRIVALS = {
    "poisson": poisson,
    "bursty": bursty,
    "diurnal": diurnal,
}

ARRIVAL_NAMES = tuple(ARRIVALS)


def sample_arrivals(family: str, rng: np.random.Generator, rate: float,
                    horizon: int) -> np.ndarray:
    """Sorted int32 arrival epochs in ``[0, horizon)`` from a named family."""
    try:
        fn = ARRIVALS[family]
    except KeyError:
        raise ValueError(f"unknown arrival family {family!r}; "
                         f"have {ARRIVAL_NAMES}") from None
    if rate <= 0.0:
        raise ValueError(f"arrival rate must be positive, got {rate}")
    if horizon < 1:
        raise ValueError(f"horizon must be >= 1 epoch, got {horizon}")
    times = fn(rng, rate, horizon)
    epochs = np.sort(np.floor(times)).astype(np.int32)
    assert epochs.size == 0 or (0 <= epochs[0] and epochs[-1] < horizon)
    return epochs
