"""Streaming dispatch service: continuous DAG arrivals into a lane pool.

The closed-batch machinery (PR 1-5) answers "given these instances at t=0,
how much carbon can gating save?".  This engine answers the question the
batch sweeps can't: what happens when delaying one job *back-pressures the
queue*.  It is :class:`repro.serve.engine.ServeEngine`'s continuous-batching
shape reused for scheduling instead of decoding:

* a fixed pool of ``n_lanes`` slot lanes, each holding one admitted DAG job
  packed to a static ``(pad_tasks, n_machines)`` shape (free lanes carry
  :func:`repro.scenarios.batching.padding_rows`-style inert padding, so the
  pool arrays never change shape);
* **one jitted gate-and-dispatch step over the whole pool per tick** —
  :func:`repro.core.solvers.online_jax.dispatch_epoch_shared` vmapped over
  lanes (partitioned) or scanned over them in priority order (shared),
  gated by the carbon quantile threshold (day-ahead
  :func:`~repro.core.solvers.online_jax.dirty_mask`, or forecast-banded via
  :func:`repro.forecast.rolling.rolling_dirty_mask` when
  ``forecast_every`` is set);
* admission runs a second jitted program per job (the scheduling analogue
  of serve's prefill): a greedy solve fixes the job's stretch budget and
  its carbon/energy baseline;
* completed jobs are evicted and their lanes refilled from the queue
  (:class:`repro.serve.lanes.LanePool` — the bookkeeping shared with the
  serve engine) — FIFO by default, or shortest-critical-path-first under
  backlog via the admission-policy hook (``admission="scpf"``).

Two fleet modes:

* ``shared_fleet=False`` (default) — each lane is an independent fleet
  partition (the lanes' machines are disjoint), so carbon gating couples
  jobs only through *lane occupancy*: delaying a job keeps its lane busy
  longer and later arrivals queue — the PCAPS-style carbon/latency tension
  the stream benchmark measures.
* ``shared_fleet=True`` — every lane contends for ONE pool-global machine
  set (the paper's common-fleet model): machine free-times are pool state
  threaded through a ``lax.scan`` over lanes in deterministic priority
  order (earliest admission first, rid tie-break), so one lane's placements
  consume machine free-time that later lanes see *within the same epoch*.
  Admission's greedy budget solve also starts from the live shared
  free-times, so stretch deadlines reflect real contention.

Contracts (property- and golden-tested in ``tests/test_stream.py`` /
``tests/test_stream_golden.py``):

* **closed-batch bit-exactness** — with every arrival at t=0 and enough
  lanes, each partitioned-mode job's dispatch decisions (start/assign/
  scheduled and the stretch budget) are bit-exact against the batched
  :func:`~repro.core.solvers.online_jax.online_carbon_gated_jax` path on
  the same instance, across scenario families x fleets (the engine's tick
  *is* that simulator's loop body);
* **determinism** — the whole run is a pure function of the seed: same
  seed, same event log, replay-locked by a tiny golden per fleet mode; the
  shared-fleet step depends only on the lane *priority order*, never on
  which physical lane a job landed in;
* every evicted schedule passes the shared validator
  (:mod:`repro.core.validate`), and shared-fleet evictions additionally
  verify no cross-lane machine overlap against every schedule already
  evicted this run.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
import types
from typing import Mapping, NamedTuple, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import validate
from repro.core.carbon import CarbonTrace, sample_window, synthesize
from repro.core.carbon import EPOCHS_PER_DAY
from repro.core.instance import Instance, Job, PackedInstance, pack
from repro.core.solvers.online_jax import (LaneState, dirty_mask,
                                           dispatch_epoch_shared,
                                           downstream_critical_path,
                                           init_lane_state, simulate_online)
from repro.core.objectives import evaluate
from repro.forecast.rolling import rolling_dirty_mask
from repro.obs import MetricsRegistry, Tracer, get_tracer
from repro.scenarios.batching import padding_rows
from repro.scenarios.fleets import build_fleet
from repro.scenarios.generator import ScenarioConfig, sample_job
from repro.serve.lanes import LanePool
from repro.stream.arrivals import sample_arrivals


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """One streaming scenario: traffic shape x job shape x pool x gate."""

    arrivals: str = "poisson"      # arrival family (repro.stream.arrivals)
    rate: float = 0.05             # mean jobs per epoch
    horizon: int = 1024            # stream length (epochs)
    n_lanes: int = 8               # fixed lane-pool size
    family: str = "layered"        # DAG family of the arriving jobs
    width: int = 3
    depth: int = 2
    n_machines: int = 3            # machines per lane partition
    fleet: str = "homog"
    mean_dur: float = 5.0          # exp mean of base task durations
    theta: float = 0.5             # carbon-gate quantile
    window: int = 96               # gate look-ahead window (epochs)
    stretch: float = 1.5           # per-job stretch budget
    machine_rule: str = "earliest_finish"
    region: str = "AU-SA"
    seed: int = 0
    forecast_every: int | None = None   # None: exact day-ahead gate
    forecast_scale: float = 1.0
    forecast_model: str = "oracle_ar1"
    shared_fleet: bool = False     # lanes contend for one machine set
    admission: str = "fifo"        # lane-refill policy (ADMISSION_POLICIES)

    def validate(self) -> "StreamConfig":
        from repro.stream.arrivals import ARRIVAL_NAMES
        if self.arrivals not in ARRIVAL_NAMES:
            raise ValueError(f"unknown arrival family {self.arrivals!r}")
        if self.n_lanes < 1:
            raise ValueError(f"n_lanes must be >= 1, got {self.n_lanes}")
        if self.admission not in ADMISSION_POLICIES:
            raise ValueError(f"unknown admission policy {self.admission!r}")
        return self


@dataclasses.dataclass
class StreamJob:
    """Host-side per-job record (the stream analogue of serve.Request)."""

    rid: int
    job: Job                        # job.arrival = stream arrival epoch
    inst: PackedInstance | None = None   # packed at admission (arrival = t)
    admitted: int = -1
    completed: int = -1             # absolute completion epoch
    budget: int = -1                # absolute stretch deadline
    greedy_makespan: int = -1       # absolute greedy completion (baseline)
    greedy_carbon: float = 0.0
    greedy_energy: float = 0.0
    carbon: float = 0.0
    energy: float = 0.0
    finished: bool = False
    truncated: bool = False         # fully placed, completes past the stream
    start: np.ndarray | None = None
    assign: np.ndarray | None = None

    @property
    def arrival(self) -> int:
        return self.job.arrival

    @property
    def queue_delay(self) -> int:
        """Epochs spent waiting for a free lane (-1 if never admitted)."""
        return self.admitted - self.job.arrival if self.admitted >= 0 else -1

    @property
    def carbon_savings(self) -> float:
        """1 - gated/greedy carbon (0 when unfinished or zero baseline)."""
        if not self.finished or self.greedy_carbon <= 0.0:
            return 0.0
        return 1.0 - self.carbon / self.greedy_carbon


# An un-observed histogram's snapshot (summary() placeholder).
_EMPTY_DIST = {"count": 0, "mean": 0.0, "p50": 0.0, "p90": 0.0, "max": 0.0}

# Admission-policy registry: payload-list -> index of the next admit.
# "fifo" is queue order; "scpf" admits the shortest-critical-path job among
# those already arrived (backlog triage: under contention, short jobs clear
# lanes faster) — both deterministic, rid tie-break.
ADMISSION_POLICIES = ("fifo", "scpf")


class StreamResult(NamedTuple):
    jobs: list[StreamJob]          # every stream job, rid order
    events: list[dict]             # serializable event log (golden-locked)
    meta: dict
    # StreamEngine.summary() of the run.  The default is an IMMUTABLE empty
    # mapping: a `summary: dict = {}` default here would be one dict object
    # shared by every StreamResult constructed without a summary, so any
    # in-place mutation of one run's summary would leak into all others
    # (regression-locked in tests/test_stream.py).  Real constructions pass
    # a fresh dict per result (see simulate_stream).
    summary: Mapping = types.MappingProxyType({})


# ---------------------------------------------------------------------------
# Jitted pool programs (module level: engines with equal shapes share them).
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n_epochs", "machine_rule"))
def _admission_eval(inst: PackedInstance, cum: jnp.ndarray,
                    stretch: jnp.ndarray, admitted: jnp.ndarray,
                    mfree0: jnp.ndarray, n_epochs: int, machine_rule: str):
    """Per-job admission solve (the scheduling analogue of serve prefill).

    Greedy-dispatches the job alone to fix the absolute stretch deadline
    ``admitted + int(stretch * greedy_relative)`` and the greedy
    carbon/energy baseline the savings metric is measured against.
    ``mfree0`` is the fleet the greedy starts on: all-zeros for a
    partitioned lane (its machines are idle by construction at insert), the
    *live shared free-times* for a shared fleet — so a shared-fleet job's
    deadline and baseline reflect the contention it is actually admitted
    into.  At ``admitted = 0`` on an idle fleet the budget arithmetic is
    bit-identical to
    :func:`~repro.core.solvers.online_jax.online_carbon_gated_jax`'s
    (same float32 cast chain) — part of the closed-batch parity contract.
    """
    state0 = init_lane_state(inst.T).merge(mfree0)
    g = simulate_online(inst, jnp.zeros((n_epochs,), bool), jnp.int32(0),
                        n_epochs=n_epochs, machine_rule=machine_rule,
                        state0=state0)
    obj = evaluate(inst, g.start, g.assign, cum)
    rel = (obj.makespan - admitted).astype(jnp.float32)
    budget = admitted + (jnp.float32(stretch) * rel).astype(jnp.int32)
    complete = jnp.all(g.scheduled | ~inst.task_mask)
    return downstream_critical_path(inst), budget, obj, complete


@functools.partial(jax.jit, static_argnames=("machine_rule",))
def _pool_tick(pool: PackedInstance, cp: jnp.ndarray, lstate: LaneState,
               mfree: jnp.ndarray, dirty: jnp.ndarray, budget: jnp.ndarray,
               t: jnp.ndarray, machine_rule: str):
    """ONE gate-and-dispatch step over the whole lane pool — epoch ``t``,
    partitioned fleets.

    :func:`dispatch_epoch_shared` vmapped over lanes, each with its own
    machine row ``mfree[lane]`` (disjoint partitions: lanes cannot interact
    through machines).  All lanes share the global gate bit ``dirty[t]`` and
    clock ``t``.  Returns the new pool state plus per-lane "all tasks
    placed" flags and completion epochs (the eviction signal).
    """
    dirty_t = dirty[t]
    lstate, mfree = jax.vmap(
        lambda i, c, s, mf, b: dispatch_epoch_shared(
            i, s, mf, dirty_t, b, t, machine_rule=machine_rule, cp=c)
    )(pool, cp, lstate, mfree, budget)
    done = jnp.all(lstate.scheduled | ~pool.task_mask, axis=1)
    comp = jnp.max(jnp.where(pool.task_mask, lstate.comp, 0), axis=1)
    return lstate, mfree, done, comp


@functools.partial(jax.jit, static_argnames=("machine_rule",))
def _pool_tick_shared(pool: PackedInstance, cp: jnp.ndarray,
                      lstate: LaneState, mfree: jnp.ndarray,
                      dirty: jnp.ndarray, budget: jnp.ndarray,
                      t: jnp.ndarray, order: jnp.ndarray, machine_rule: str):
    """ONE gate-and-dispatch step over the lane pool — epoch ``t``, SHARED
    fleet.

    A ``lax.scan`` over lanes in ``order`` (the deterministic priority
    permutation: occupied lanes by (admission epoch, rid), free lanes last)
    threading the single pool-global ``mfree [M]`` through every lane's
    :func:`dispatch_epoch_shared` — so a higher-priority lane's placements
    consume machine free-time that lower-priority lanes see *within this
    same epoch*.  Free (padding) lanes have no real tasks, place nothing,
    and leave ``mfree`` untouched, so scanning them is inert.  The result
    depends on ``order`` only through which *jobs* it ranks — not on which
    physical lane a job occupies (tested as lane-order determinism).
    """
    dirty_t = dirty[t]

    def body(mf, lane):
        inst = jax.tree.map(lambda x: x[lane], pool)
        st = jax.tree.map(lambda x: x[lane], lstate)
        st, mf = dispatch_epoch_shared(inst, st, mf, dirty_t, budget[lane],
                                       t, machine_rule=machine_rule,
                                       cp=cp[lane])
        return mf, st

    mfree, stacked = jax.lax.scan(body, mfree, order)
    # Scatter the scan-ordered rows back to lane order (order is a
    # permutation of 0..L-1).
    lstate = jax.tree.map(lambda x, s: x.at[order].set(s), lstate, stacked)
    done = jnp.all(lstate.scheduled | ~pool.task_mask, axis=1)
    comp = jnp.max(jnp.where(pool.task_mask, lstate.comp, 0), axis=1)
    return lstate, mfree, done, comp


@jax.jit
def _insert_lane(pool: PackedInstance, cp: jnp.ndarray, lstate: LaneState,
                 budget: jnp.ndarray, lane: jnp.ndarray,
                 inst: PackedInstance, job_cp: jnp.ndarray,
                 job_budget: jnp.ndarray):
    """Insert one admitted job into ``lane`` (serve's cache insert, for
    dispatch state): overwrite the lane's instance/cp/budget rows and zero
    its task-side progress.  Machine free-times are NOT touched here — a
    partitioned lane's row is cleared separately (:func:`_clear_lane_mfree`),
    while a shared fleet's global ``mfree`` must survive inserts unchanged
    (the machines stay busy regardless of which job a lane holds)."""
    pool = PackedInstance(*(getattr(pool, f).at[lane].set(getattr(inst, f))
                            for f in PackedInstance._fields))
    lstate = LaneState(*(getattr(lstate, f).at[lane].set(
        jnp.zeros_like(getattr(lstate, f)[lane]))
        for f in LaneState._fields))
    return pool, cp.at[lane].set(job_cp), lstate, budget.at[lane].set(
        job_budget)


@jax.jit
def _clear_lane_mfree(mfree: jnp.ndarray, lane: jnp.ndarray) -> jnp.ndarray:
    """Reset one partitioned lane's machine row to idle (the previous
    occupant completed at or before the insert epoch, so its residual
    free-times are stale by construction)."""
    return mfree.at[lane].set(jnp.zeros_like(mfree[lane]))


@jax.jit
def _eval_schedule(inst: PackedInstance, start: jnp.ndarray,
                   assign: jnp.ndarray, cum: jnp.ndarray):
    return evaluate(inst, start, assign, cum), \
        validate.total_violations(inst, start, assign)


# ---------------------------------------------------------------------------
# The engine.
# ---------------------------------------------------------------------------

class StreamEngine:
    """Long-running lane-pool dispatcher over one carbon trace.

    ``trace`` is the stream's global clock and carbon signal: epoch ``t`` of
    every lane is epoch ``t`` of the trace.  ``pad_tasks`` fixes the static
    task axis (jobs must fit); the fleet (``powers_kw``/``speeds``) is the
    per-lane machine partition.  See the module docstring for semantics and
    contracts.
    """

    def __init__(self, trace: CarbonTrace, powers_kw: Sequence[float],
                 speeds: Sequence[float], n_lanes: int, pad_tasks: int, *,
                 theta: float = 0.5, window: int = 96, stretch: float = 1.5,
                 machine_rule: str = "earliest_finish",
                 forecast_every: int | None = None,
                 forecast_scale: float = 1.0,
                 forecast_model: str = "oracle_ar1", seed: int = 0,
                 validate_evictions: bool = True,
                 shared_fleet: bool = False, admission: str = "fifo",
                 tracer: Tracer | None = None,
                 metrics: MetricsRegistry | None = None):
        if machine_rule not in ("earliest_finish", "min_energy"):
            raise ValueError(f"unknown machine_rule {machine_rule!r}")
        if admission not in ADMISSION_POLICIES:
            raise ValueError(f"unknown admission policy {admission!r}")
        # Telemetry is host-side only (bit-exact contract: repro.obs).  The
        # ambient tracer resolves to a no-op unless REPRO_TRACE=1 or a
        # global tracer is installed; metrics are always on (cheap Python
        # around an already-synchronous host loop) and feed summary().
        self.tracer = tracer if tracer is not None else get_tracer()
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._wall_seen: set[str] = set()
        self.forecast_every = forecast_every
        self.trace = trace
        self.powers = tuple(float(p) for p in powers_kw)
        self.speeds = tuple(float(s) for s in speeds)
        self.T, self.M = int(pad_tasks), len(self.powers)
        self.E = trace.n_epochs
        self.stretch = float(stretch)
        self.machine_rule = machine_rule
        self.validate_evictions = bool(validate_evictions)
        self.shared_fleet = bool(shared_fleet)
        self.admission = admission
        self._cp_cache: dict[int, int] = {}   # rid -> critical path (scpf)
        intensity = jnp.asarray(trace.intensity)
        self.cum = jnp.asarray(trace.cumulative())
        if forecast_every is None:
            # Exact day-ahead gate: identical thresholds to the batched path.
            self.dirty = dirty_mask(intensity, jnp.float32(theta),
                                    jnp.int32(window),
                                    max_window=int(window))
        else:
            # Forecast-banded gate: thresholds re-quantiled from rolling
            # imperfect forecasts (scale=0 reproduces the day-ahead gate).
            self.dirty = rolling_dirty_mask(
                intensity, jnp.float32(theta), jnp.int32(window),
                jax.random.key(seed), jnp.float32(forecast_scale),
                every=int(forecast_every), max_window=int(window),
                model=forecast_model)
        # Host copies for telemetry reads (the arrays are computed either
        # way on the first tick; pulling them here changes nothing).
        self._dirty_host = np.asarray(self.dirty)
        self._intensity_host = np.asarray(trace.intensity)
        self.pool = LanePool(n_lanes)
        self._reset_pool_state()

    def _reset_pool_state(self) -> None:
        L, T, M = self.pool.n_lanes, self.T, self.M
        self.pool_inst = padding_rows(L, T, M)      # inert free lanes
        self.lstate = LaneState(
            jnp.zeros((L, T), bool), jnp.zeros((L, T), jnp.int32),
            jnp.zeros((L, T), jnp.int32), jnp.zeros((L, T), jnp.int32))
        # Machine free-times: pool-global [M] when the fleet is shared,
        # one disjoint partition row per lane [L, M] otherwise.
        self.mfree = jnp.zeros((M,) if self.shared_fleet else (L, M),
                               jnp.int32)
        self.cp = jnp.zeros((L, T), jnp.int32)
        self.budget = jnp.zeros((L,), jnp.int32)
        self._done = np.zeros(L, bool)
        self._comp = np.zeros(L, np.int64)
        # Shared-fleet eviction validation: per-machine (start, end, rid)
        # intervals of every schedule evicted this run.
        self._fleet_busy: list[list[tuple[int, int, int]]] = \
            [[] for _ in range(M)]

    # -- admission / eviction -------------------------------------------------

    def _admit_job(self, lane: int, sj: StreamJob, t: int) -> bool:
        job = dataclasses.replace(sj.job, arrival=t)   # can't start pre-lane
        inst = pack(Instance(jobs=(job,), powers_kw=self.powers,
                             speeds=self.speeds), pad_tasks=self.T)
        # The greedy budget solve's starting fleet: idle for a partitioned
        # lane (its machines are free at insert by construction), the LIVE
        # shared free-times otherwise — a shared-fleet job's stretch
        # deadline and savings baseline are measured against what greedy
        # could do on the fleet it actually contends for.
        mfree0 = (self.mfree if self.shared_fleet
                  else jnp.zeros((self.M,), jnp.int32))
        t0 = time.perf_counter()
        cp, budget, obj, complete = _admission_eval(
            inst, self.cum, jnp.float32(self.stretch), jnp.int32(t), mfree0,
            n_epochs=self.E, machine_rule=self.machine_rule)
        complete = bool(complete)      # host sync: the admission solve ran
        self._observe_wall("admission_wall_s", time.perf_counter() - t0)
        if not complete:
            # Too late even greedily: reject instead of wedging the lane.
            # The job surfaces with admitted == -1 / finished == False.
            self.metrics.counter("jobs_rejected").inc()
            self.tracer.instant("reject", t, rid=sj.rid,
                                arrival=int(sj.arrival))
            return False
        self.pool_inst, self.cp, self.lstate, self.budget = _insert_lane(
            self.pool_inst, self.cp, self.lstate, self.budget,
            jnp.int32(lane), inst, cp, budget)
        if not self.shared_fleet:
            self.mfree = _clear_lane_mfree(self.mfree, jnp.int32(lane))
        sj.inst = inst
        sj.admitted = t
        sj.budget = int(budget)
        sj.greedy_makespan = int(obj.makespan)
        sj.greedy_carbon = float(obj.carbon)
        sj.greedy_energy = float(obj.energy)
        self.metrics.counter("jobs_admitted").inc()
        self.metrics.histogram("queue_delay_epochs").observe(sj.queue_delay)
        self.tracer.instant(
            "admit", t, rid=sj.rid, lane=lane, arrival=int(sj.arrival),
            queue_delay=int(sj.queue_delay), budget=int(sj.budget),
            carbon_gpkwh=round(float(self._intensity_host[t]), 3))
        return True

    def _finish(self, lane: int, sj: StreamJob,
                truncated: bool = False) -> None:
        self.pool.evict(lane)
        row = jax.tree.map(lambda x: x[lane], self.lstate)
        obj, viol = _eval_schedule(sj.inst, row.start, row.assign, self.cum)
        if self.validate_evictions and int(viol) != 0:
            raise AssertionError(
                f"evicted job rid={sj.rid} has an infeasible schedule "
                f"(violation mass {int(viol)})")
        if self.shared_fleet and self.validate_evictions:
            self._check_fleet_overlap(sj, np.asarray(row.start),
                                      np.asarray(row.assign))
        sj.completed = int(self._comp[lane])
        sj.carbon = float(obj.carbon)
        sj.energy = float(obj.energy)
        sj.start = np.asarray(row.start)
        sj.assign = np.asarray(row.assign)
        sj.finished = True
        sj.truncated = bool(truncated)
        self.metrics.counter("jobs_completed").inc()
        if truncated:
            self.metrics.counter("jobs_truncated").inc()
        self.metrics.histogram("carbon_savings_pct").observe(
            100.0 * sj.carbon_savings)
        if self.tracer.enabled:
            self.tracer.span(f"job:{sj.rid}", sj.admitted, sj.completed,
                             lane=lane, rid=sj.rid,
                             carbon_g=round(sj.carbon, 3),
                             greedy_carbon_g=round(sj.greedy_carbon, 3),
                             savings_pct=round(100 * sj.carbon_savings, 2))
            self.tracer.instant("evict", sj.completed, rid=sj.rid, lane=lane,
                                truncated=sj.truncated)

    def _check_fleet_overlap(self, sj: StreamJob, start: np.ndarray,
                             assign: np.ndarray) -> None:
        """Shared-fleet eviction invariant: no task of this schedule may
        overlap, on its machine, any task of a schedule already evicted this
        run.  Per-lane validation can't see this (each lane's validator only
        knows its own job); the threaded ``mfree`` makes it hold by
        construction, and this check keeps it honest."""
        dur = np.asarray(sj.inst.dur)
        for ti in np.nonzero(np.asarray(sj.inst.task_mask))[0]:
            m = int(assign[ti])
            s = int(start[ti])
            e = s + int(dur[ti, m])
            for (bs, be, brid) in self._fleet_busy[m]:
                if s < be and bs < e:
                    raise AssertionError(
                        f"shared-fleet overlap: rid={sj.rid} task {ti} "
                        f"[{s}, {e}) collides with rid={brid} "
                        f"[{bs}, {be}) on machine {m}")
            self._fleet_busy[m].append((s, e, sj.rid))

    # -- admission policy / lane priority -------------------------------------

    def _job_critical_path(self, sj: StreamJob) -> int:
        """Base-duration critical path of a job's DAG (machine-independent —
        the scpf admission key; cached per rid)."""
        got = self._cp_cache.get(sj.rid)
        if got is not None:
            return got
        job = sj.job
        cp = list(job.base_durations)
        succ: list[list[int]] = [[] for _ in range(job.n_tasks)]
        for u, v in job.edges:
            succ[u].append(v)
        for u in range(job.n_tasks - 1, -1, -1):
            if succ[u]:
                cp[u] = job.base_durations[u] + max(cp[v] for v in succ[u])
        val = max(cp, default=0)
        self._cp_cache[sj.rid] = val
        return val

    def _admission_select(self):
        """The LanePool ``select`` hook for the configured policy (None ==
        FIFO, the O(1) deque pop)."""
        if self.admission == "fifo":
            return None
        return lambda ready: min(
            range(len(ready)),
            key=lambda i: (self._job_critical_path(ready[i]), ready[i].rid))

    def _lane_order(self) -> jnp.ndarray:
        """Deterministic shared-fleet priority permutation for this tick:
        occupied lanes by (admission epoch, rid) — earliest-admitted job wins
        machine contention — then free lanes (inert in the scan)."""
        occ = sorted((sj.admitted, sj.rid, lane)
                     for lane, sj in self.pool.active())
        order = [lane for _, _, lane in occ] + self.pool.free_lanes()
        return jnp.asarray(order, jnp.int32)

    # -- telemetry ------------------------------------------------------------

    def _observe_wall(self, name: str, seconds: float) -> None:
        """Wall-clock split: the first call per name within a run lands in
        the ``*_first`` histogram (jit compile + execute — or a warm hit on
        the process-wide jit cache), later calls in ``*_warm``."""
        first = name not in self._wall_seen
        self._wall_seen.add(name)
        suffix = "_first" if first else "_warm"
        self.metrics.histogram(name + suffix).observe(seconds)

    def _trace_tick(self, t: int, queue: list) -> None:
        """Per-tick trace samples (guarded: zero work when tracing is off)."""
        active = sum(1 for _ in self.pool.active())
        dirty = bool(self._dirty_host[t])
        self.tracer.counter("gate", t, 1.0 if dirty else 0.0)
        self.tracer.counter("carbon_gpkwh", t,
                            float(self._intensity_host[t]))
        self.tracer.counter("lanes_active", t, active)
        self.tracer.counter("queue_len", t, sum(
            1 for s in queue if s.job.arrival <= t))
        if dirty and any(not self._done[lane]
                         for lane, _ in self.pool.active()):
            # The gate is closed while admitted work is still unplaced —
            # this epoch's ready tasks are (budget permitting) deferred.
            self.tracer.instant("gate_defer", t)
        if self.forecast_every is not None and t % self.forecast_every == 0:
            # Forecast re-quantile boundary: the rolling gate's thresholds
            # from here on were re-solved with epoch-t information.
            self.tracer.instant("forecast_resolve", t)

    def summary(self) -> dict:
        """Aggregate view of the last ``run`` from the metrics registry:
        job counts, the queue-delay and savings distributions, final lane
        occupancy, and the jit-compile vs warm wall-clock split."""
        snap = self.metrics.snapshot()
        return {
            "jobs_admitted": snap.get("jobs_admitted", 0),
            "jobs_rejected": snap.get("jobs_rejected", 0),
            "jobs_completed": snap.get("jobs_completed", 0),
            "jobs_truncated": snap.get("jobs_truncated", 0),
            "queue_delay_epochs": snap.get(
                "queue_delay_epochs", dict(_EMPTY_DIST)),
            "carbon_savings_pct": snap.get(
                "carbon_savings_pct", dict(_EMPTY_DIST)),
            "final_lane_occupancy": snap.get("final_lane_occupancy", 0),
            "gate_closed_epochs": snap.get("gate_closed_epochs", 0),
            "ticks": snap.get("ticks", 0),
            "wall": {k: v for k, v in snap.items()
                     if k.startswith(("tick_wall_s", "admission_wall_s"))},
        }

    # -- main loop ------------------------------------------------------------

    def run(self, jobs: Sequence[Job]) -> list[StreamJob]:
        """Serve a finite stream of jobs; returns one StreamJob per input
        (rid = input index), finished or flagged ``finished=False``.

        The pool is drained before returning, so back-to-back ``run`` calls
        on one engine are independent (the serve-engine re-entry contract).
        Per-run telemetry accumulates in ``self.metrics`` (reset on entry;
        read it through :meth:`summary`) and, when tracing is enabled, in
        ``self.tracer``.
        """
        for j in jobs:
            if j.n_tasks > self.T:
                raise ValueError(f"job with {j.n_tasks} tasks exceeds "
                                 f"pad_tasks={self.T}")
        self.metrics.reset()
        self._wall_seen: set[str] = set()
        sjobs = [StreamJob(rid=i, job=j) for i, j in enumerate(jobs)]
        # deque: the FIFO head pop in LanePool.admit is O(1) — with a plain
        # list every admission under backlog shifted the whole queue (the
        # O(n^2) fix, regression-locked in tests/test_serve.py).
        queue = collections.deque(
            sorted(sjobs, key=lambda s: (s.job.arrival, s.rid)))
        select = self._admission_select()
        t = 0
        while t < self.E - 1:
            # 1. evict lanes whose job finished executing by epoch t
            for lane, sj in list(self.pool.active()):
                if self._done[lane] and self._comp[lane] <= t:
                    self._finish(lane, sj)
            # 2. admit arrived jobs into the freed lanes (FIFO, or the
            #    configured policy over the ready prefix); jobs too close to
            #    the trace end to finish even greedily are rejected (they
            #    surface finished=False rather than wedging a lane)
            for lane, sj in self.pool.admit(
                    queue, ready=lambda s: s.job.arrival <= t,
                    select=select):
                if not self._admit_job(lane, sj, t):
                    self.pool.evict(lane)
                    sj.admitted = -1
            # 3. idle fast-forward: empty pool, next arrival in the future
            if not self.pool.any_active():
                if not queue:
                    break
                t = max(t + 1, int(queue[0].job.arrival))
                continue
            # 4. ONE jitted gate-and-dispatch step over the whole pool
            if self.tracer.enabled:
                self._trace_tick(t, queue)
            t0 = time.perf_counter()
            if self.shared_fleet:
                self.lstate, self.mfree, done, comp = _pool_tick_shared(
                    self.pool_inst, self.cp, self.lstate, self.mfree,
                    self.dirty, self.budget, jnp.int32(t),
                    self._lane_order(), machine_rule=self.machine_rule)
            else:
                self.lstate, self.mfree, done, comp = _pool_tick(
                    self.pool_inst, self.cp, self.lstate, self.mfree,
                    self.dirty, self.budget, jnp.int32(t),
                    machine_rule=self.machine_rule)
            self._done, self._comp = np.asarray(done), np.asarray(comp)
            self._observe_wall("tick_wall_s", time.perf_counter() - t0)
            self.metrics.counter("ticks").inc()
            if self._dirty_host[t]:
                self.metrics.counter("gate_closed_epochs").inc()
            t += 1
        # End-of-stream surfacing: any lane whose job is fully placed gets
        # its stats, including those whose completion epoch lands PAST the
        # final tick — those evict with truncated=True (the silent-drop fix:
        # a feasible, fully-dispatched schedule used to surface as
        # finished=False with no carbon/savings stats just because the trace
        # ended before its last task ran out).
        for lane, sj in list(self.pool.active()):
            if self._done[lane]:
                self._finish(lane, sj,
                             truncated=bool(self._comp[lane] > t))
        self.metrics.gauge("final_lane_occupancy").set(
            sum(1 for _ in self.pool.active()))
        # drain: unfinished jobs surface flagged; the pool resets so the
        # engine is re-entrant (never re-dispatches stale lanes)
        self.pool.drain()
        self._reset_pool_state()
        return sjobs


# ---------------------------------------------------------------------------
# Scenario-level entry points.
# ---------------------------------------------------------------------------

def sample_stream_jobs(rng: np.random.Generator,
                       cfg: StreamConfig) -> list[Job]:
    """One DAG job per arrival: arrival epochs from the configured arrival
    family, DAG + durations from the scenario generator's job sampler."""
    cfg.validate()
    arrivals = sample_arrivals(cfg.arrivals, rng, cfg.rate, cfg.horizon)
    scen = ScenarioConfig(family=cfg.family, n_jobs=1, width=cfg.width,
                          depth=cfg.depth, n_machines=cfg.n_machines,
                          fleet=cfg.fleet, mean_dur=cfg.mean_dur).validate()
    return [dataclasses.replace(sample_job(rng, scen), arrival=int(a))
            for a in arrivals]


def event_log(jobs: Sequence[StreamJob]) -> list[dict]:
    """Serializable per-job event records, rid order — the replay artifact
    the golden test locks (same seed -> identical log)."""
    out = []
    for sj in sorted(jobs, key=lambda s: s.rid):
        ev = {
            "rid": sj.rid,
            "arrival": int(sj.arrival),
            "admitted": int(sj.admitted),
            "queue_delay": int(sj.queue_delay),
            "finished": bool(sj.finished),
        }
        if sj.admitted >= 0:
            ev.update({
                "budget": int(sj.budget),
                "greedy_makespan": int(sj.greedy_makespan),
                "greedy_carbon_g": round(float(sj.greedy_carbon), 3),
            })
        if sj.finished:
            ev.update({
                "completed": int(sj.completed),
                "carbon_g": round(float(sj.carbon), 3),
                "energy_kwh": round(float(sj.energy), 4),
                "carbon_savings_pct": round(100 * sj.carbon_savings, 3),
            })
        if sj.truncated:
            # Conditional so pre-existing goldens (all jobs complete within
            # the stream) stay byte-identical.
            ev["truncated"] = True
        out.append(ev)
    return out


def simulate_stream(cfg: StreamConfig,
                    jobs: Sequence[Job] | None = None,
                    tracer: Tracer | None = None) -> StreamResult:
    """Run one streaming scenario end to end, deterministically.

    Everything derives from ``cfg.seed``: the arrival times, the job DAGs
    and durations, the fleet, and the carbon window (drawn from a
    synthesized year through :func:`repro.core.carbon.sample_window` — the
    path whose off-by-one fix makes the final window reachable).  ``jobs``
    overrides the sampled stream (the closed-batch parity tests inject
    arrival-at-0 jobs this way).  ``tracer`` (or ``REPRO_TRACE=1``)
    captures the run's event timeline — host-side only, bit-exact with
    tracing off.
    """
    cfg.validate()
    rng = np.random.default_rng(cfg.seed)
    if jobs is None:
        jobs = sample_stream_jobs(rng, cfg)
    powers, speeds = build_fleet(cfg.fleet, rng, cfg.n_machines)
    # Arrivals land in [0, horizon); the trace runs two days past it so
    # late arrivals (and stretch-delayed tails) have room to finish.
    n_epochs = cfg.horizon + 2 * EPOCHS_PER_DAY
    days = -(-n_epochs // EPOCHS_PER_DAY) + 2
    year = synthesize(cfg.region, days=days, seed=cfg.seed)
    trace = sample_window(year, rng, n_epochs)
    pad_tasks = max((j.n_tasks for j in jobs), default=1)
    eng = StreamEngine(trace, powers, speeds, cfg.n_lanes, pad_tasks,
                       theta=cfg.theta, window=cfg.window,
                       stretch=cfg.stretch, machine_rule=cfg.machine_rule,
                       forecast_every=cfg.forecast_every,
                       forecast_scale=cfg.forecast_scale,
                       forecast_model=cfg.forecast_model, seed=cfg.seed,
                       shared_fleet=cfg.shared_fleet,
                       admission=cfg.admission, tracer=tracer)
    sjobs = eng.run(jobs)
    meta = {
        "config": {k: (v if v is None or isinstance(v, (int, float, str,
                                                        bool)) else str(v))
                   for k, v in dataclasses.asdict(cfg).items()},
        "n_jobs": len(sjobs),
        "n_finished": sum(sj.finished for sj in sjobs),
        "pad_tasks": pad_tasks,
        "n_epochs": trace.n_epochs,
    }
    return StreamResult(sjobs, event_log(sjobs), meta, eng.summary())
