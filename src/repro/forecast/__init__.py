"""Carbon-forecast subsystem: imperfect forecasts + rolling re-quantiles.

Why this package exists
-----------------------
The paper's 25% carbon-savings figure is an *offline upper bound*, computed
against a perfect day-ahead carbon trace.  Everything between that bound and
a deployable scheduler is forecast error.  This package makes forecast
quality a first-class scenario axis: it generates calibrated imperfect
forecasts over any carbon trace, rolls them forward MPC-style, and feeds
them to the online gate (:mod:`repro.forecast.rolling`) and the rolling
replanner (:mod:`repro.core.solvers.rolling`) so the repo can quantify how
much of the offline bound survives at a given forecast quality.

Lead-time conventions
---------------------
* Time is the repo-standard 15-minute epoch grid; ``truth`` is the realized
  intensity, float32 ``[E]``.
* A forecast *issued at* epoch ``t0`` spans **absolute** epochs ``0..E-1``.
  The **lead** of epoch ``e`` is ``l = e - t0``.
* Leads ``l <= 0`` are the *observed prefix*: real-time telemetry plus
  history, equal to ``truth`` exactly.  In particular the current epoch
  (lead 0) is always known — the online gate compares *observed* intensity
  against *forecast* quantile thresholds.
* Per-lead error is calibrated to ``std(l) = scale * std(truth) *
  sqrt(1 - rho^(2l))`` — zero at lead 0, saturating at ``scale`` trace-stds
  for day-ahead leads.  ``scale = 0`` is the perfect oracle, *bit-exact*
  equal to ``truth``, which is the regression anchor: every rolling result
  at ``scale = 0`` must reproduce the day-ahead perfect-forecast result.

Quantile conventions
--------------------
* Gate thresholds are ``theta``-quantiles over the forecast window
  ``point[t : t + window]``, computed with the same masked-sort +
  ``np.quantile``-compatible interpolation as the day-ahead gate
  (:mod:`repro.core.solvers.online_jax`), so perfect-forecast results agree
  to the bit.
* A forecast's own uncertainty is exposed as Gaussian per-lead bands:
  :func:`repro.forecast.models.lead_quantiles` returns
  ``point + ndtri(q) * std(lead)``, clamped at 0.  Quantile levels ``q`` are
  probabilities in (0, 1); rows are returned in the caller's order.
* Rolling re-quantile: replan boundaries sit at multiples of ``every``;
  epoch ``t`` is gated by the forecast issued at ``(t // every) * every``.
  Error seeds fold the issue index (``jax.random.fold_in(key, k)``), so
  issues are independent draws while leads within one issue stay
  AR(1)-correlated.

Everything is shape-static jnp and ``vmap``s over (instances x error seeds x
policy/robustness grids); see ``benchmarks/forecast_robustness.py`` for the
full sweep.
"""
from repro.forecast.models import (AR1_RHO, EPOCHS_PER_DAY, Forecast, MODELS,
                                   error_std_per_lead, issue, lead_quantiles)
from repro.forecast.rolling import (band_conditioned_theta,
                                    day_ahead_dirty_mask, n_replans,
                                    online_rolling_gated_jax,
                                    rolling_band_dirty_mask,
                                    rolling_dirty_mask, theta_band_features)

__all__ = [
    "AR1_RHO", "EPOCHS_PER_DAY", "Forecast", "MODELS",
    "error_std_per_lead", "issue", "lead_quantiles",
    "band_conditioned_theta", "day_ahead_dirty_mask", "n_replans",
    "online_rolling_gated_jax", "rolling_band_dirty_mask",
    "rolling_dirty_mask", "theta_band_features",
]
