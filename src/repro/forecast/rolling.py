"""Rolling re-quantile carbon gate: re-issue the forecast, re-gate dispatch.

The day-ahead online gate (:mod:`repro.core.solvers.online_jax`) fixes its
quantile thresholds once, from the forecast available at epoch 0.  Under
forecast error that is exactly where the offline bound is lost: a threshold
computed from a stale day-ahead forecast keeps gating against valleys that
never materialize.  This module replaces it with the rolling scheme: every
``every`` epochs the forecast is re-issued for the *remaining* horizon
(:func:`repro.forecast.models.issue` at the new ``t0``) and the
``theta``-quantile gate thresholds are recomputed from it — short leads, small
errors, fresh thresholds.

Everything is one ``lax.scan`` over the (static) replan boundaries, built on
the same masked-sort + interpolated-quantile kernels the day-ahead gate uses
(``sorted_windows`` / ``_quantile_dirty``), so a **zero-noise rolling
forecast reproduces the day-ahead gate bit-exactly** — the regression the
tests lock.  The dirty decision at epoch ``t`` compares the *observed*
intensity ``truth[t]`` (real-time telemetry) against the quantile of the
*forecast* window ``point[t : t + window]`` from the most recent issue.

``vmap`` axes: instances (each with its own truth window) x error seeds x the
``(scale, every)`` robustness grid the benchmark sweeps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.instance import PackedInstance
from repro.core.objectives import makespan
from repro.core.solvers.online_jax import (OnlineSchedule, _quantile_dirty,
                                           online_greedy_jax, simulate_online,
                                           sorted_windows as _sorted_windows)
from repro.forecast import models


def n_replans(n_epochs: int, every: int) -> int:
    """Number of forecast issues covering ``n_epochs`` at one per ``every``."""
    if every <= 0:
        raise ValueError(f"replan interval must be positive, got {every}")
    return -(-n_epochs // every)


def _rolling_gate(truth, window, key, scale, every, max_window, model, rho,
                  theta_of):
    """Shared rolling re-quantile scan; ``theta_of(fc)`` picks the quantile.

    One ``lax.scan`` over the replan boundaries: issue ``k`` governs epochs
    ``[k * every, (k + 1) * every)`` (error seed ``fold_in(key, k)``, so
    successive issues are independent draws while leads within one issue
    stay AR(1)-correlated).  ``theta_of`` maps the issued
    :class:`~repro.forecast.models.Forecast` to a scalar or per-epoch
    quantile — the flat gate ignores ``fc``, the band-conditioned gate
    reads its uncertainty band.
    """
    truth = jnp.asarray(truth, jnp.float32)
    E = truth.shape[0]
    K = n_replans(E, every)

    def one_issue(_, k):
        fc = models.issue(truth, jnp.int32(k * every),
                          key=jax.random.fold_in(key, k),
                          model=model, scale=scale, rho=rho)
        sv, n = _sorted_windows(fc.point, window, max_window)
        return None, _quantile_dirty(truth, sv, n, theta_of(fc))

    _, rows = jax.lax.scan(one_issue, None, jnp.arange(K, dtype=jnp.int32))
    e = jnp.arange(E, dtype=jnp.int32)
    return rows[e // every, e]


@functools.partial(jax.jit,
                   static_argnames=("model", "every", "max_window"))
def rolling_dirty_mask(truth: jnp.ndarray, theta: jnp.ndarray,
                       window: jnp.ndarray, key: jax.Array,
                       scale: jnp.ndarray, every: int, max_window: int,
                       model: str = "oracle_ar1",
                       rho: float = models.AR1_RHO) -> jnp.ndarray:
    """``dirty[t]`` under rolling re-quantile (see module docstring).

    ``every`` and ``max_window`` are static; ``theta``/``window``/``scale``
    are traced, so robustness grids vmap over them without recompiling.
    """
    return _rolling_gate(truth, window, key, scale, every, max_window,
                         model, rho, lambda fc: theta)


# ---------------------------------------------------------------------------
# Forecast-conditioned thetas: gate quantile as a function of the per-lead
# uncertainty band (ROADMAP "forecast-aware gate thetas").
# ---------------------------------------------------------------------------

def band_conditioned_theta(theta_base: jnp.ndarray, theta_slope: jnp.ndarray,
                           feat: jnp.ndarray) -> jnp.ndarray:
    """Per-epoch gate quantile ``clip(base + slope * feat, 0, 1)``.

    ``feat`` is the normalized per-lead uncertainty (error std in
    trace-stds, :attr:`~repro.forecast.models.Forecast.std` over
    ``std(truth)``): a positive ``slope`` raises the quantile — gates less —
    where the forecast is uncertain, a negative one gates harder.
    ``slope = 0`` is exactly the flat ``theta_base`` (bit-exact, which the
    regression test locks).  The clip keeps the quantile in the domain the
    interpolation supports; :mod:`repro.learn` trains an unconstrained
    sigmoid parametrization instead and hands the evaluated per-epoch
    vector straight to :func:`~repro.core.solvers.online_jax.
    quantile_threshold`, which accepts either form.
    """
    return jnp.clip(theta_base + theta_slope * feat, 0.0, 1.0)


def theta_band_features(truth: jnp.ndarray, scale, every: int | None = None,
                        rho: float = models.AR1_RHO) -> jnp.ndarray:
    """Normalized per-lead uncertainty feature, float32 [E].

    ``feat[e] = std(lead of e) / std(truth) = scale * g(lead)`` with ``g``
    the stationary-AR(1) growth of :func:`repro.forecast.models.
    error_std_per_lead` — the feature the band-conditioned theta (and the
    forecast-conditioned learner) reads.  ``every = None`` is the day-ahead
    case (one issue at epoch 0, leads grow over the whole horizon);
    otherwise leads reset at each replan boundary, giving the sawtooth
    profile of the rolling re-issue sequence.
    """
    truth = jnp.asarray(truth, jnp.float32)
    E = truth.shape[0]
    e = jnp.arange(E, dtype=jnp.int32)
    lead = (e if every is None else e % every).astype(jnp.float32)
    g = jnp.sqrt(1.0 - jnp.float32(rho) ** (2.0 * lead))
    return jnp.asarray(scale, jnp.float32) * g


@functools.partial(jax.jit,
                   static_argnames=("model", "every", "max_window"))
def rolling_band_dirty_mask(truth: jnp.ndarray, theta_base: jnp.ndarray,
                            theta_slope: jnp.ndarray, window: jnp.ndarray,
                            key: jax.Array, scale: jnp.ndarray, every: int,
                            max_window: int, model: str = "oracle_ar1",
                            rho: float = models.AR1_RHO) -> jnp.ndarray:
    """Rolling re-quantile gate with a band-conditioned theta profile.

    Identical scan to :func:`rolling_dirty_mask` (one shared kernel,
    ``_rolling_gate``) except the quantile at epoch ``e`` is
    :func:`band_conditioned_theta` evaluated on the governing issue's own
    uncertainty band at ``e``'s lead.  ``theta_slope = 0`` reproduces
    :func:`rolling_dirty_mask` bit-exactly for ``theta_base`` in ``[0, 1]``
    (the per-epoch theta vector collapses to the flat ``theta_base`` and
    the quantile kernel broadcasts either form identically) — the
    regression ``tests/test_rolling.py`` locks.
    """
    truth = jnp.asarray(truth, jnp.float32)
    sigma = jnp.maximum(jnp.std(truth), 1e-6)
    return _rolling_gate(
        truth, window, key, scale, every, max_window, model, rho,
        lambda fc: band_conditioned_theta(theta_base, theta_slope,
                                          fc.std / sigma))


@functools.partial(jax.jit, static_argnames=("model", "max_window"))
def day_ahead_dirty_mask(truth: jnp.ndarray, theta: jnp.ndarray,
                         window: jnp.ndarray, key: jax.Array,
                         scale: jnp.ndarray, max_window: int,
                         model: str = "oracle_ar1",
                         rho: float = models.AR1_RHO) -> jnp.ndarray:
    """The day-ahead-only gate under an *imperfect* forecast.

    One forecast issued at epoch 0 fixes every threshold — the degenerate
    ``every >= E`` case of :func:`rolling_dirty_mask`, and with ``scale = 0``
    exactly :func:`repro.core.solvers.online_jax.dirty_mask` on ``truth``.
    """
    truth = jnp.asarray(truth, jnp.float32)
    fc = models.issue(truth, jnp.int32(0), key=jax.random.fold_in(key, 0),
                      model=model, scale=scale, rho=rho)
    sv, n = _sorted_windows(fc.point, window, max_window)
    return _quantile_dirty(truth, sv, n, theta)


def online_rolling_gated_jax(inst: PackedInstance, truth, key: jax.Array,
                             theta: float = 0.5, window: int = 96,
                             stretch: float = 1.5, every: int = 48,
                             scale: float = 1.0, model: str = "oracle_ar1",
                             machine_rule: str = "earliest_finish",
                             state0=None) -> OnlineSchedule:
    """Gated online dispatch with rolling re-quantile thresholds.

    Mirrors :func:`~repro.core.solvers.online_jax.online_carbon_gated_jax`
    (greedy run fixes the stretch budget, then the gated simulation), with
    the day-ahead dirty mask swapped for the rolling one.  ``scale = 0``
    reproduces the day-ahead dispatcher bit-exactly for every ``every``.
    ``state0`` warm-starts BOTH runs from an existing
    :class:`~repro.core.solvers.online_jax.DispatchState` (shared-fleet
    contention: the greedy budget baseline must face the same busy machines
    the gated run does), matching the day-ahead mirror's semantics.
    """
    truth = jnp.asarray(truth, jnp.float32)
    n_epochs = int(truth.shape[0])
    if state0 is None:
        g = online_greedy_jax(inst, n_epochs, machine_rule=machine_rule)
    else:
        g = simulate_online(inst, jnp.zeros((n_epochs,), bool), jnp.int32(0),
                            n_epochs=n_epochs, machine_rule=machine_rule,
                            state0=state0)
    ms0 = makespan(inst, g.start, g.assign)
    budget = (jnp.float32(stretch) * ms0.astype(jnp.float32)).astype(jnp.int32)
    dirty = rolling_dirty_mask(truth, jnp.float32(theta), jnp.int32(window),
                               key, jnp.float32(scale), every=every,
                               max_window=int(window), model=model)
    return simulate_online(inst, dirty, budget, n_epochs=n_epochs,
                           machine_rule=machine_rule, state0=state0)
