"""Shape-static carbon-intensity forecast generators.

A *forecast* here is what a grid operator (or a forecasting service like
Electricity Maps / WattTime) would hand the scheduler at a given epoch: a
point estimate of the intensity for every future epoch of the horizon, plus
a per-lead uncertainty band.  Everything is a pure jnp function of the
*realized* trace, an issue epoch and (for the stochastic model) a PRNG key,
so forecasts ``vmap`` over batched instances and error seeds and re-issue
inside ``lax.scan`` loops (see :mod:`repro.forecast.rolling`).

Conventions (shared with :mod:`repro.forecast.rolling` and
:mod:`repro.core.solvers.rolling`):

* ``truth`` is the realized intensity, float32 ``[E]`` at 15-min epochs.
* A forecast *issued at* epoch ``t0`` is an array over **absolute** epochs
  ``[E]``.  Epochs ``e <= t0`` are the *observed prefix* (real-time telemetry
  plus history) and equal ``truth`` exactly; epochs ``e > t0`` are predictions
  at **lead** ``l = e - t0 >= 1``.
* Lead 0 (the current epoch) is observable, so every model is exact there.
* Per-lead error follows the calibrated saturating curve
  ``std(l) = scale * std(truth) * sqrt(1 - rho^(2l))`` — the stationary-AR(1)
  error growth: small at short leads, saturating at ``scale`` trace-stds for
  day-ahead leads.  ``scale = 0`` makes every model the perfect oracle
  (bit-exact: the point forecast *is* ``truth``).

Models:

* ``oracle_ar1`` — truth plus an AR(1) error process *in lead*, the knob the
  forecast-robustness benchmark sweeps.  Error draws are keyed, so a rolling
  re-issue sequence uses ``jax.random.fold_in(key, k)`` per replan.
* ``persistence`` — every future epoch equals the last observed value.  The
  classic no-skill baseline.
* ``diurnal`` — tomorrow looks like today: each future epoch copies the most
  recent *observed* epoch at the same time of day (96-epoch period), the
  standard seasonal-naive forecast for strongly diurnal carbon traces.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

MODELS = ("oracle_ar1", "persistence", "diurnal")

EPOCHS_PER_DAY = 96     # 15-minute epochs (mirrors repro.core.carbon)
# Per-epoch persistence of the forecast error.  0.995 puts the error
# correlation time around two days, matching the empirical ~2-3x accuracy
# gap between intraday and day-ahead carbon forecasts: g(24 epochs) ~ 0.46
# vs g(96+) ~ 0.8-0.9 of the saturated error — re-forecasting every few
# hours genuinely helps.  (A fast-mixing rho would saturate the error within
# hours and erase the value of rolling re-issues.)
AR1_RHO = 0.995


class Forecast(NamedTuple):
    """One issued forecast over absolute epochs (see module docstring)."""

    point: jnp.ndarray      # float32 [E] point forecast; == truth for e <= t0
    std: jnp.ndarray        # float32 [E] per-lead error std; 0 for e <= t0
    issued_at: jnp.ndarray  # int32 scalar t0


def _leads(E: int, t0: jnp.ndarray) -> jnp.ndarray:
    """lead[e] = max(e - t0, 0), int32 [E]."""
    return jnp.maximum(jnp.arange(E, dtype=jnp.int32) - t0, 0)


def error_std_per_lead(truth: jnp.ndarray, t0: jnp.ndarray,
                       scale: jnp.ndarray, rho: float = AR1_RHO
                       ) -> jnp.ndarray:
    """Calibrated per-lead error std: ``scale * std(truth) * g(lead)``.

    ``g(l) = sqrt(1 - rho^(2l))`` is the stationary-AR(1) error growth —
    ``g(0) = 0`` (the current epoch is observed) and ``g -> 1`` for day-ahead
    leads, so ``scale`` reads as "error at saturation, in trace-stds".
    """
    lead = _leads(truth.shape[0], t0).astype(jnp.float32)
    sigma = jnp.std(truth)
    return (jnp.asarray(scale, jnp.float32) * sigma
            * jnp.sqrt(1.0 - jnp.float32(rho) ** (2.0 * lead)))


def _ar1_error_path(key: jax.Array, E: int, rho: float) -> jnp.ndarray:
    """err[l] for leads l = 0..E-1: AR(1) started at 0, unit stationary std.

    ``err[0] = 0`` and ``std(err[l]) = sqrt(1 - rho^(2l))`` — exactly the
    growth curve of :func:`error_std_per_lead`, so scaling by
    ``scale * std(truth)`` calibrates the realized error to the advertised
    band.
    """
    xi = jax.random.normal(key, (E,), jnp.float32)
    a = jnp.float32(rho)
    b = jnp.sqrt(1.0 - a * a)

    def step(acc, x):
        acc = a * acc + b * x
        return acc, acc

    _, err = jax.lax.scan(step, jnp.float32(0.0), xi)
    # err[i] is the error at lead i+1; lead 0 has zero error by definition.
    return jnp.concatenate([jnp.zeros((1,), jnp.float32), err[:-1]])


def _observed(truth: jnp.ndarray, t0: jnp.ndarray,
              future: jnp.ndarray) -> jnp.ndarray:
    """Splice the observed prefix (epochs <= t0) over a future estimate."""
    e = jnp.arange(truth.shape[0], dtype=jnp.int32)
    return jnp.where(e <= t0, truth, future)


@functools.partial(jax.jit, static_argnames=("model",))
def issue(truth: jnp.ndarray, t0: jnp.ndarray, key: jax.Array | None = None,
          model: str = "oracle_ar1", scale: float = 1.0,
          rho: float = AR1_RHO) -> Forecast:
    """Issue one forecast at epoch ``t0`` (see module docstring).

    ``scale`` calibrates the error band (0 == perfect oracle, point forecast
    bit-identical to ``truth``).  ``key`` seeds the ``oracle_ar1`` error draw
    and is ignored by the deterministic structural models; for those,
    ``scale`` only sizes the *reported* uncertainty band.
    """
    if model not in MODELS:
        raise ValueError(f"unknown forecast model {model!r}")
    truth = jnp.asarray(truth, jnp.float32)
    t0 = jnp.asarray(t0, jnp.int32)
    E = truth.shape[0]
    std = error_std_per_lead(truth, t0, scale, rho)

    if model == "oracle_ar1":
        if key is None:
            raise ValueError("oracle_ar1 needs a PRNG key")
        lead = _leads(E, t0)
        err = _ar1_error_path(key, E, rho)[lead]
        sigma = jnp.std(truth)
        point = truth + jnp.asarray(scale, jnp.float32) * sigma * err
    elif model == "persistence":
        point = _observed(truth, t0, jnp.broadcast_to(truth[t0], (E,)))
    else:  # diurnal seasonal-naive
        e = jnp.arange(E, dtype=jnp.int32)
        days_back = (e - t0 + EPOCHS_PER_DAY - 1) // EPOCHS_PER_DAY
        src = jnp.clip(e - EPOCHS_PER_DAY * days_back, 0, t0)
        point = _observed(truth, t0, truth[src])

    # Intensity is physically non-negative; truth > 0 so the observed prefix
    # (and the scale=0 oracle) is untouched by the clamp.
    point = jnp.maximum(point, 0.0)
    return Forecast(point=point, std=std, issued_at=t0)


def lead_quantiles(fc: Forecast, qs: Sequence[float]) -> jnp.ndarray:
    """Gaussian per-lead quantile bands, float32 ``[Q, E]``.

    ``out[i, e] = max(point[e] + ndtri(qs[i]) * std[e], 0)`` — the forecast's
    own uncertainty model, matching :func:`error_std_per_lead`.  On the
    observed prefix std is 0, so every quantile collapses to the truth.
    """
    z = jax.scipy.special.ndtri(jnp.asarray(qs, jnp.float32))
    return jnp.maximum(fc.point[None, :] + z[:, None] * fc.std[None, :], 0.0)
