"""Grid carbon-intensity traces.

The paper uses hourly Electricity Maps data (2024) from four regions:
AU-SA, US-CAL (CAISO), US-TEX (ERCOT) and CA-ON.  Real traces are not
redistributable inside this offline container, so we ship

  * a deterministic synthetic generator calibrated to the *statistical
    profile* the paper describes for each region (mean level, diurnal
    variability, solar penetration), and
  * a CSV ingestion path (``from_csv``) so real Electricity Maps exports can
    drop in unchanged on a production deployment.

Traces are resampled to 15-minute epochs.  The decoders never integrate
I(tau) directly; they use the *cumulative carbon-energy* array

    cum[e] = sum_{e' < e} I[e'] * EPOCH_HOURS        (gCO2 per kW)

so the emissions of a task on machine m starting at epoch s for d epochs are

    P_m * (cum[s + d] - cum[s])                      (gCO2)

— Def. 2.3 as a single gather, the TPU-friendly form.
"""
from __future__ import annotations

import dataclasses
import zlib

import numpy as np

from repro.core.instance import EPOCH_HOURS

EPOCHS_PER_HOUR = 4
EPOCHS_PER_DAY = 96


@dataclasses.dataclass(frozen=True)
class RegionProfile:
    """Statistical knobs for the synthetic generator (per paper Section 3.2)."""

    name: str
    mean: float          # average intensity, gCO2/kWh
    diurnal_amp: float   # amplitude of the day/night sinusoid
    solar_depth: float   # midday dip from solar (duck curve), gCO2/kWh
    noise_std: float     # hour-to-hour noise (wind / dispatch)
    seasonal_amp: float  # yearly seasonal swing
    floor: float = 5.0   # intensity can't go below this


# Calibrated to the qualitative description in the paper:
#  AU-SA : high daily variation, strong renewables (solar+wind), moderate mean.
#  CAL   : duck curve — deep midday solar dip, evening ramp, moderate mean.
#  TEX   : higher mean, *less* daily variation (savings are smaller).
#  CA-ON : ~90% low-carbon (hydro/nuclear) — very low mean, little headroom.
REGIONS: dict[str, RegionProfile] = {
    "AU-SA": RegionProfile("AU-SA", mean=170.0, diurnal_amp=110.0,
                           solar_depth=120.0, noise_std=45.0, seasonal_amp=25.0),
    "CAL":   RegionProfile("CAL", mean=240.0, diurnal_amp=70.0,
                           solar_depth=140.0, noise_std=30.0, seasonal_amp=30.0),
    "TEX":   RegionProfile("TEX", mean=420.0, diurnal_amp=55.0,
                           solar_depth=45.0, noise_std=25.0, seasonal_amp=20.0),
    "CA-ON": RegionProfile("CA-ON", mean=45.0, diurnal_amp=28.0,
                           solar_depth=10.0, noise_std=12.0, seasonal_amp=8.0),
}


@dataclasses.dataclass(frozen=True)
class CarbonTrace:
    """A carbon-intensity trace at 15-minute resolution."""

    name: str
    intensity: np.ndarray  # float32 [E] gCO2/kWh per epoch

    @property
    def n_epochs(self) -> int:
        return int(self.intensity.shape[0])

    def cumulative(self) -> np.ndarray:
        """cum[e] in gCO2-per-kW; length E+1; cum[0] = 0."""
        cum = np.zeros(self.n_epochs + 1, dtype=np.float64)
        np.cumsum(self.intensity.astype(np.float64) * EPOCH_HOURS, out=cum[1:])
        return cum.astype(np.float32)

    def window(self, start_epoch: int, length: int) -> "CarbonTrace":
        """Slice ``length`` epochs starting at ``start_epoch`` (wraps around)."""
        idx = (start_epoch + np.arange(length)) % self.n_epochs
        return CarbonTrace(self.name, self.intensity[idx])


def synthesize(region: str = "AU-SA", days: int = 366, seed: int = 2024) -> CarbonTrace:
    """Generate a deterministic year-long synthetic trace for ``region``."""
    prof = REGIONS[region]
    # crc32, not hash(): str hashing is randomized per process, which would
    # make the "deterministic" generator emit a different trace every run.
    rng = np.random.default_rng((seed, zlib.crc32(region.encode()) & 0xFFFF))
    hours = days * 24
    t = np.arange(hours, dtype=np.float64)
    hod = t % 24.0
    doy = t / 24.0

    # Diurnal demand curve: low at 4am, peaks early evening (~19h).
    diurnal = prof.diurnal_amp * np.sin((hod - 9.0) / 24.0 * 2 * np.pi)
    # Solar dip: gaussian bump centred at 12:30, scaled by season.
    season = 1.0 + 0.35 * np.sin((doy - 15.0) / 366.0 * 2 * np.pi)  # summer peak
    solar = -prof.solar_depth * season * np.exp(-0.5 * ((hod - 12.5) / 2.6) ** 2)
    seasonal = prof.seasonal_amp * np.sin((doy - 30.0) / 366.0 * 2 * np.pi)
    # AR(1) noise for hour-to-hour persistence (wind fronts, dispatch).
    eps = rng.normal(0.0, prof.noise_std, size=hours)
    noise = np.empty(hours)
    acc = 0.0
    for i in range(hours):  # tiny; runs once per trace
        acc = 0.82 * acc + eps[i]
        noise[i] = acc
    noise *= np.sqrt(1 - 0.82 ** 2)

    hourly = np.maximum(prof.floor, prof.mean + diurnal + solar + seasonal + noise)
    per_epoch = np.repeat(hourly, EPOCHS_PER_HOUR).astype(np.float32)
    return CarbonTrace(region, per_epoch)


def from_csv(path: str, name: str = "csv", column: int = 1,
             hourly: bool = True) -> CarbonTrace:
    """Ingest an Electricity Maps-style CSV export: ``timestamp,intensity``.

    Real exports have holes (sensor outages parse as NaN).  Dropping those
    rows would *shift every later hour* on the time grid — a schedule's
    epoch ``e`` would no longer be the trace's hour ``e/4`` — so interior
    gaps are filled by linear interpolation on the row grid (the time axis
    stays aligned) and gaps at the trace edges, which have no anchor to
    interpolate from, raise instead of being silently invented.
    """
    vals = np.atleast_1d(np.genfromtxt(path, delimiter=",", skip_header=1,
                                       usecols=(column,))).astype(np.float64)
    if vals.size < 2:
        raise ValueError(
            f"{path}: only {vals.size} data row(s) — a trace needs at "
            "least 2 rows to define a time axis (truncated export?)")
    finite = np.isfinite(vals)
    if not finite.any():
        raise ValueError(f"{path}: no finite intensity values in column "
                         f"{column}")
    if not finite.all():
        idx = np.arange(vals.size)
        lo, hi = idx[finite][0], idx[finite][-1]
        if lo != 0 or hi != vals.size - 1:
            raise ValueError(
                f"{path}: non-finite values at the trace edges (rows "
                f"[0, {lo}) / ({hi}, {vals.size})) cannot be interpolated — "
                "trim the export or fill them upstream")
        vals[~finite] = np.interp(idx[~finite], idx[finite], vals[finite])
    vals = vals.astype(np.float32)
    if hourly:
        vals = np.repeat(vals, EPOCHS_PER_HOUR)
    return CarbonTrace(name, vals)


def constant(value: float, epochs: int, name: str = "const") -> CarbonTrace:
    """Flat trace — with it, carbon optimization degenerates to energy
    optimization; useful for tests."""
    return CarbonTrace(name, np.full(epochs, value, dtype=np.float32))


def sample_window(trace: CarbonTrace, rng: np.random.Generator,
                  horizon: int) -> CarbonTrace:
    """Random start point into a year trace (paper: 'Each instance starts at a
    random point in the trace').

    Every start with a full in-trace window is reachable: the valid starts
    are ``0 .. n_epochs - horizon`` *inclusive* (``rng.integers`` has an
    exclusive upper bound, hence the ``+ 1`` — without it the final window
    was never sampled).
    """
    start = int(rng.integers(0, max(1, trace.n_epochs - horizon + 1)))
    return trace.window(start, horizon)
