"""The paper's primary contribution: carbon-aware flexible job-shop
scheduling of DAG workloads (bi-level makespan -> carbon/energy protocol),
implemented as TPU-friendly JAX population search over SGS encodings.

Public API:
    instance   — FJSP instances (jobs, DAG tasks, machines) + generators
    carbon     — carbon-intensity traces (4 region profiles, CSV ingest)
    objectives — makespan / energy / carbon evaluators
    validate   — shared feasibility validator (Eqs. 4-8 + budget)
    decoder    — SGS decoders + carbon timing sweep
    solvers    — SA / GA / exact oracle / bi-level driver / online dispatch
"""
from repro.core import carbon, decoder, instance, objectives, validate
from repro.core.instance import (Instance, Job, PackedInstance,
                                 generate_instance, pack, stack_packed)
from repro.core.carbon import CarbonTrace, REGIONS, synthesize
from repro.core.solvers import (BilevelResult, ScheduleResult, solve_bilevel,
                                solve_bilevel_batch, solve_ga, solve_sa)

__all__ = [
    "carbon", "decoder", "instance", "objectives", "validate",
    "Instance", "Job", "PackedInstance", "generate_instance", "pack",
    "stack_packed", "CarbonTrace", "REGIONS", "synthesize",
    "BilevelResult", "ScheduleResult", "solve_bilevel",
    "solve_bilevel_batch", "solve_ga", "solve_sa",
]
