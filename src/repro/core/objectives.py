"""Schedule objectives (paper Definitions 2.1-2.3) and feasibility checks.

All evaluators take a schedule as ``(start[T], assign[T])`` integer arrays
plus the :class:`~repro.core.instance.PackedInstance` and (for carbon) the
cumulative carbon trace.  Everything is jnp and shape-static so it vmaps over
candidate populations and batched instances.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.instance import EPOCH_HOURS, PackedInstance


class Objectives(NamedTuple):
    makespan: jnp.ndarray   # int32 scalar (epochs)
    energy: jnp.ndarray     # float32 scalar (kWh)
    carbon: jnp.ndarray     # float32 scalar (gCO2)


def task_durations(inst: PackedInstance, assign: jnp.ndarray) -> jnp.ndarray:
    """dur[t, assign[t]] -> int32 [T]."""
    return jnp.take_along_axis(inst.dur, assign[:, None], axis=1)[:, 0]


def makespan(inst: PackedInstance, start: jnp.ndarray,
             assign: jnp.ndarray) -> jnp.ndarray:
    """Def 2.1 — max completion over (real) tasks."""
    comp = start + task_durations(inst, assign)
    return jnp.max(jnp.where(inst.task_mask, comp, 0)).astype(jnp.int32)


def energy(inst: PackedInstance, assign: jnp.ndarray) -> jnp.ndarray:
    """Def 2.2 — sum of P_m * p_{t,m} (kWh). Start-time independent."""
    d = task_durations(inst, assign).astype(jnp.float32)
    p = inst.power[assign]
    return jnp.sum(jnp.where(inst.task_mask, p * d * EPOCH_HOURS, 0.0))


def carbon(inst: PackedInstance, start: jnp.ndarray, assign: jnp.ndarray,
           cum: jnp.ndarray) -> jnp.ndarray:
    """Def 2.3 — sum of P_m * (cum[s+d] - cum[s]) (gCO2).

    ``cum`` is the cumulative carbon-energy trace (gCO2 per kW), length E+1.
    Starts/completions beyond the trace are clipped (tests guarantee the
    horizon covers every feasible schedule).
    """
    d = task_durations(inst, assign)
    e = cum.shape[0] - 1
    s0 = jnp.clip(start, 0, e)
    s1 = jnp.clip(start + d, 0, e)
    g = inst.power[assign] * (cum[s1] - cum[s0])
    return jnp.sum(jnp.where(inst.task_mask, g, 0.0))


def evaluate(inst: PackedInstance, start: jnp.ndarray, assign: jnp.ndarray,
             cum: jnp.ndarray) -> Objectives:
    return Objectives(makespan(inst, start, assign),
                      energy(inst, assign),
                      carbon(inst, start, assign, cum))


def utilization(inst: PackedInstance, start: jnp.ndarray,
                assign: jnp.ndarray) -> jnp.ndarray:
    """Busy machine-epochs / (M * makespan) — the paper's utilization metric."""
    d = task_durations(inst, assign).astype(jnp.float32)
    busy = jnp.sum(jnp.where(inst.task_mask, d, 0.0))
    ms = makespan(inst, start, assign).astype(jnp.float32)
    return busy / (inst.M * jnp.maximum(ms, 1.0))


# ---------------------------------------------------------------------------
# Feasibility (Appendix A constraints, Eqs. 4-8).
# ---------------------------------------------------------------------------

def violations(inst: PackedInstance, start: jnp.ndarray,
               assign: jnp.ndarray) -> jnp.ndarray:
    """Total constraint-violation epochs (0 == feasible). jit/vmap friendly.

    Checks: arrivals (Eq. 4), DAG precedence (Eq. 5), machine validity
    (Eq. 6), no-overlap per machine (Eq. 8).
    """
    T = inst.T
    d = task_durations(inst, assign)
    comp = start + d
    mask = inst.task_mask

    # Eq. 4: start >= arrival.
    v_arr = jnp.sum(jnp.where(mask, jnp.maximum(inst.arrival - start, 0), 0))

    # Eq. 5: for every edge (u -> t): start[t] >= comp[u].
    gap = comp[None, :] - start[:, None]          # [t, u]: must be <= 0 on edges
    v_dep = jnp.sum(jnp.where(inst.pred & mask[:, None] & mask[None, :],
                              jnp.maximum(gap, 0), 0))

    # Eq. 6: assigned machine must be allowed.
    ok = jnp.take_along_axis(inst.allowed, assign[:, None], axis=1)[:, 0]
    v_mach = jnp.sum(jnp.where(mask & ~ok, 1, 0)) * jnp.int32(10**6)

    # Eq. 8: no-overlap — for every pair on the same machine, intervals must
    # be disjoint. Overlap(a,b) = max(0, min(end) - max(start)).
    same_m = (assign[:, None] == assign[None, :])
    both = mask[:, None] & mask[None, :]
    iu = ~jnp.tri(T, dtype=bool)  # strictly upper: each unordered pair once
    ov = jnp.minimum(comp[:, None], comp[None, :]) - \
        jnp.maximum(start[:, None], start[None, :])
    v_olap = jnp.sum(jnp.where(same_m & both & iu, jnp.maximum(ov, 0), 0))

    return (v_arr + v_dep + v_mach + v_olap).astype(jnp.int32)


def check_feasible_np(inst: PackedInstance, start, assign) -> list[str]:
    """Python-level feasibility report (for tests / the exact oracle)."""
    start = np.asarray(start)
    assign = np.asarray(assign)
    dur = np.asarray(inst.dur)
    mask = np.asarray(inst.task_mask)
    pred = np.asarray(inst.pred)
    arr = np.asarray(inst.arrival)
    allowed = np.asarray(inst.allowed)
    probs = []
    T = dur.shape[0]
    comp = start + dur[np.arange(T), assign]
    for t in range(T):
        if not mask[t]:
            continue
        if not allowed[t, assign[t]]:
            probs.append(f"task {t}: machine {assign[t]} not allowed")
        if start[t] < arr[t]:
            probs.append(f"task {t}: starts {start[t]} before arrival {arr[t]}")
        for u in range(T):
            if pred[t, u] and mask[u] and start[t] < comp[u]:
                probs.append(f"task {t}: starts {start[t]} before pred {u} ends {comp[u]}")
        for u in range(t + 1, T):
            if mask[u] and assign[u] == assign[t]:
                if max(start[t], start[u]) < min(comp[t], comp[u]):
                    probs.append(f"tasks {t},{u} overlap on machine {assign[t]}")
    return probs
