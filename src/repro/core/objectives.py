"""Schedule objectives (paper Definitions 2.1-2.3) and feasibility checks.

All evaluators take a schedule as ``(start[T], assign[T])`` integer arrays
plus the :class:`~repro.core.instance.PackedInstance` and (for carbon) the
cumulative carbon trace.  Everything is jnp and shape-static so it vmaps over
candidate populations and batched instances.

Feasibility checking lives in :mod:`repro.core.validate` (the shared
validator); ``violations`` / ``check_feasible_np`` are re-exported here for
backward compatibility.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.instance import EPOCH_HOURS, PackedInstance
from repro.core.validate import (check_feasible_np,  # noqa: F401  (re-export)
                                 task_durations,
                                 total_violations as violations)


class Objectives(NamedTuple):
    makespan: jnp.ndarray   # int32 scalar (epochs)
    energy: jnp.ndarray     # float32 scalar (kWh)
    carbon: jnp.ndarray     # float32 scalar (gCO2)


def makespan(inst: PackedInstance, start: jnp.ndarray,
             assign: jnp.ndarray) -> jnp.ndarray:
    """Def 2.1 — max completion over (real) tasks."""
    comp = start + task_durations(inst, assign)
    return jnp.max(jnp.where(inst.task_mask, comp, 0)).astype(jnp.int32)


def energy(inst: PackedInstance, assign: jnp.ndarray) -> jnp.ndarray:
    """Def 2.2 — sum of P_m * p_{t,m} (kWh). Start-time independent."""
    d = task_durations(inst, assign).astype(jnp.float32)
    p = inst.power[assign]
    return jnp.sum(jnp.where(inst.task_mask, p * d * EPOCH_HOURS, 0.0))


def carbon(inst: PackedInstance, start: jnp.ndarray, assign: jnp.ndarray,
           cum: jnp.ndarray) -> jnp.ndarray:
    """Def 2.3 — sum of P_m * (cum[s+d] - cum[s]) (gCO2).

    ``cum`` is the cumulative carbon-energy trace (gCO2 per kW), length E+1.
    Starts/completions beyond the trace are clipped (tests guarantee the
    horizon covers every feasible schedule).
    """
    d = task_durations(inst, assign)
    e = cum.shape[0] - 1
    s0 = jnp.clip(start, 0, e)
    s1 = jnp.clip(start + d, 0, e)
    g = inst.power[assign] * (cum[s1] - cum[s0])
    return jnp.sum(jnp.where(inst.task_mask, g, 0.0))


def evaluate(inst: PackedInstance, start: jnp.ndarray, assign: jnp.ndarray,
             cum: jnp.ndarray) -> Objectives:
    return Objectives(makespan(inst, start, assign),
                      energy(inst, assign),
                      carbon(inst, start, assign, cum))


# ---------------------------------------------------------------------------
# Differentiable (fractional-start) objective terms — the gate-policy learner
# (repro.learn) optimizes these; at integer starts they agree exactly with
# makespan / carbon above, so the relaxation introduces no value gap.
# ---------------------------------------------------------------------------

def soft_makespan(inst: PackedInstance, start: jnp.ndarray,
                  assign: jnp.ndarray) -> jnp.ndarray:
    """Def 2.1 over *fractional* float32 starts (``max`` subgradient).

    ``assign`` stays integral (the relaxation differentiates start times
    only).  At integer starts this equals :func:`makespan` exactly.
    """
    comp = start.astype(jnp.float32) + \
        task_durations(inst, assign).astype(jnp.float32)
    return jnp.max(jnp.where(inst.task_mask, comp, 0.0))


def soft_carbon(inst: PackedInstance, start: jnp.ndarray, assign: jnp.ndarray,
                cum: jnp.ndarray) -> jnp.ndarray:
    """Def 2.3 over fractional starts: linear interpolation of ``cum``.

    ``d/ds soft_carbon = P_m * (intensity[s + d] - intensity[s])`` — the
    marginal carbon of delaying a task is the intensity gap between where it
    would end and where it would start, which is exactly the signal a
    gradient-trained gate threshold needs.  At integer starts the
    interpolation hits the knots and the value equals :func:`carbon`
    bit-for-bit.
    """
    ftype = cum.dtype                # float32 normally; float64 under x64
    d = task_durations(inst, assign).astype(ftype)
    e = jnp.asarray(cum.shape[0] - 1, ftype)
    grid = jnp.arange(cum.shape[0], dtype=ftype)
    s0 = jnp.clip(start.astype(ftype), 0.0, e)
    s1 = jnp.clip(start.astype(ftype) + d, 0.0, e)
    c0 = jnp.interp(s0, grid, cum)
    c1 = jnp.interp(s1, grid, cum)
    g = inst.power[assign] * (c1 - c0)
    return jnp.sum(jnp.where(inst.task_mask, g, 0.0))


def utilization(inst: PackedInstance, start: jnp.ndarray,
                assign: jnp.ndarray) -> jnp.ndarray:
    """Busy machine-epochs / (usable machines * makespan).

    The paper's utilization metric, with the denominator counting machines
    *usable by at least one real task* rather than the raw array width — so
    machine padding (``pack(..., pad_machines=...)``, whose padded columns
    are never ``allowed``) leaves the metric bit-identical to the unpadded
    instance.  For ordinary instances every machine serves some task and the
    two denominators coincide.
    """
    d = task_durations(inst, assign).astype(jnp.float32)
    busy = jnp.sum(jnp.where(inst.task_mask, d, 0.0))
    ms = makespan(inst, start, assign).astype(jnp.float32)
    usable = jnp.sum(jnp.any(inst.allowed & inst.task_mask[:, None],
                             axis=0).astype(jnp.float32))
    return busy / (jnp.maximum(usable, 1.0) * jnp.maximum(ms, 1.0))


# Feasibility (Appendix A constraints, Eqs. 4-8) lives in repro.core.validate
# — the single shared validator; `violations` / `check_feasible_np` /
# `task_durations` are re-exported above for the historical import path.
