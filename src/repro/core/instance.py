"""FJSP instance model: jobs with DAG task dependencies on heterogeneous machines.

Mirrors the paper's Appendix A inputs:
  - jobs ``j`` with arrival times ``a_j`` (epochs),
  - per-job task DAGs ``G_j = (V_j, E_j)``,
  - machines ``m`` with power draw ``P_m`` (kW) and per-task processing
    times ``p_{t,m}`` (epochs, 1 epoch = 15 minutes),
  - every task may run on a subset of machines (``allowed``).

Two representations:
  * :class:`Instance` — numpy/object level, built by generators, convenient
    for the exact oracle and for humans.
  * :class:`PackedInstance` — fixed-shape jnp arrays (padded) consumed by the
    vmapped JAX decoders/solvers.  Tasks are topologically indexed so that a
    predecessor always has a smaller index than its successor.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import numpy as np

import jax.numpy as jnp

# A task that cannot run on machine m gets this processing time; the decoder
# masks such machines out, this is belt-and-braces.
INF_DUR = np.int32(2**20)

EPOCH_HOURS = 0.25  # 15-minute epochs, as in the paper.

# The paper's heterogeneous setup (Section 3.1): five server classes.
HETERO_POWERS_KW = (0.25, 0.5, 1.0, 1.5, 2.0)
HETERO_SPEEDS = (1.0 / 3.0, 1.0 / 2.0, 1.0, 4.0 / 3.0, 2.0)


@dataclasses.dataclass(frozen=True)
class Job:
    """One job: ``k`` tasks with a DAG over them and an arrival epoch."""

    arrival: int
    # durations on the *baseline* (speed-1) machine, one per task, in epochs.
    base_durations: tuple[int, ...]
    # DAG edges (u, v): task u must complete before task v starts. Local
    # indices 0..k-1, topologically consistent (u < v).
    edges: tuple[tuple[int, int], ...]

    @property
    def n_tasks(self) -> int:
        return len(self.base_durations)


@dataclasses.dataclass(frozen=True)
class Instance:
    """A full FJSP instance (numpy level)."""

    jobs: tuple[Job, ...]
    powers_kw: tuple[float, ...]   # per machine
    speeds: tuple[float, ...]      # per machine, relative to baseline
    # allowed[j][i] -> tuple of machine ids; None means "all machines".
    allowed: tuple | None = None

    @property
    def n_jobs(self) -> int:
        return len(self.jobs)

    @property
    def n_machines(self) -> int:
        return len(self.powers_kw)

    @property
    def n_tasks(self) -> int:
        return sum(j.n_tasks for j in self.jobs)

    def durations_matrix(self) -> np.ndarray:
        """[T, M] int32 processing times (ceil of base/speed), INF if disallowed."""
        T, M = self.n_tasks, self.n_machines
        dur = np.full((T, M), INF_DUR, dtype=np.int32)
        t = 0
        for ji, job in enumerate(self.jobs):
            for i, d in enumerate(job.base_durations):
                for m in range(M):
                    if self.allowed is not None and m not in self.allowed[ji][i]:
                        continue
                    dur[t, m] = max(1, int(np.ceil(d / self.speeds[m])))
                t += 1
        return dur


class PackedInstance(NamedTuple):
    """Fixed-shape, padded arrays for the JAX decoders.

    All tasks across all jobs are flattened to a single axis of length ``T``
    (static), topologically ordered (any predecessor index < successor index).

    Padding contract (property-tested in ``tests/test_scenarios.py``):

    * **Padded tasks** have ``task_mask == False``, zero duration on machine
      0 and no dependencies, so they are scheduled instantly and never affect
      the objectives (which mask them out).
    * **Padded machines** (``pack(..., pad_machines=M)``) are appended after
      the real machines with ``allowed == False`` for every task, ``INF_DUR``
      processing times for real tasks and zero power.  No decoder or
      dispatcher can ever select them (every machine choice masks on
      ``allowed``), so padding the machine axis is *inert*: the padded and
      unpadded dispatch of the same instance are bit-exact on the real tasks
      (real machine indices are preserved — padding only appends columns).

    Together the two axes let :func:`repro.scenarios.batching.pack_aligned`
    stack *mixed-shape* instances (different DAG families, task counts and
    fleet sizes) into one ``[B, ...]`` batch that ``online_jax``/``rolling``
    and the SA/GA solvers vmap over unchanged.
    """

    dur: jnp.ndarray        # int32 [T, M]
    allowed: jnp.ndarray    # bool  [T, M]
    pred: jnp.ndarray       # bool  [T, T] ; pred[t, u] == True -> u before t
    arrival: jnp.ndarray    # int32 [T]
    job: jnp.ndarray        # int32 [T]
    task_mask: jnp.ndarray  # bool  [T]
    power: jnp.ndarray      # float32 [M]

    @property
    def T(self) -> int:  # noqa: N802 - matches the math.
        return self.dur.shape[-2]   # trailing axes: valid for [B, ...] stacks

    @property
    def M(self) -> int:  # noqa: N802
        return self.dur.shape[-1]


def pack(inst: Instance, pad_tasks: int | None = None,
         pad_machines: int | None = None) -> PackedInstance:
    """Pack an :class:`Instance` to fixed-shape arrays.

    ``pad_tasks`` / ``pad_machines`` pad the task and machine axes so
    instances of different sizes (task counts *and* fleet sizes) can be
    stacked into one batch — see the padding contract on
    :class:`PackedInstance`.  Padded machines are never ``allowed``, carry
    ``INF_DUR`` durations for real tasks and zero power, so they are inert:
    no dispatcher or decoder can place work on them.
    """
    T_real, M_real = inst.n_tasks, inst.n_machines
    T = pad_tasks or T_real
    M = pad_machines or M_real
    if T < T_real:
        raise ValueError(f"pad_tasks={T} < real task count {T_real}")
    if M < M_real:
        raise ValueError(f"pad_machines={M} < real machine count {M_real}")

    dur = np.zeros((T, M), dtype=np.int32)
    allowed = np.zeros((T, M), dtype=bool)
    pred = np.zeros((T, T), dtype=bool)
    arrival = np.zeros((T,), dtype=np.int32)
    job_id = np.zeros((T,), dtype=np.int32)
    task_mask = np.zeros((T,), dtype=bool)
    power = np.zeros((M,), dtype=np.float32)
    power[:M_real] = np.asarray(inst.powers_kw, dtype=np.float32)

    dmat = inst.durations_matrix()
    dur[:T_real, :M_real] = dmat
    allowed[:T_real, :M_real] = dmat < INF_DUR
    # Padded machine columns: disallowed, INF duration for real tasks
    # (belt-and-braces — `allowed` already masks them out everywhere).
    dur[:T_real, M_real:] = INF_DUR
    t0 = 0
    for ji, job in enumerate(inst.jobs):
        k = job.n_tasks
        for (u, v) in job.edges:
            if not (0 <= u < v < k):
                raise ValueError(f"edge ({u},{v}) not topological in job {ji}")
            pred[t0 + v, t0 + u] = True
        arrival[t0:t0 + k] = job.arrival
        job_id[t0:t0 + k] = ji
        task_mask[t0:t0 + k] = True
        t0 += k
    # Padding tasks: dur 0 on machine 0 only, no deps, arrive at 0.
    if T > T_real:
        allowed[T_real:, 0] = True

    return PackedInstance(
        dur=jnp.asarray(dur),
        allowed=jnp.asarray(allowed),
        pred=jnp.asarray(pred),
        arrival=jnp.asarray(arrival),
        job=jnp.asarray(job_id),
        task_mask=jnp.asarray(task_mask),
        power=jnp.asarray(power),
    )


def stack_packed(insts: Sequence[PackedInstance]) -> PackedInstance:
    """Stack same-shape packed instances along a leading batch axis.

    Instances must share ``(T, M)`` — pack them with common ``pad_tasks`` /
    ``pad_machines`` (or use :func:`repro.scenarios.batching.pack_aligned`,
    which computes the common shape for you).
    """
    if not insts:
        raise ValueError("stack_packed: empty instance sequence")
    shapes = {(p.T, p.M) for p in insts}
    if len(shapes) > 1:
        raise ValueError(
            "stack_packed: mixed (T, M) shapes "
            f"{sorted(shapes)} — pack with common pad_tasks/pad_machines "
            "(see repro.scenarios.batching.pack_aligned)")
    return PackedInstance(*(jnp.stack([getattr(p, f) for p in insts])
                            for f in PackedInstance._fields))


# ---------------------------------------------------------------------------
# Generators (Section 3.1 of the paper).
# ---------------------------------------------------------------------------

def chain_edges(k: int) -> tuple[tuple[int, int], ...]:
    """t0 -> t1 -> ... -> t_{k-1}."""
    return tuple((i, i + 1) for i in range(k - 1))


def branch_edges(k: int) -> tuple[tuple[int, int], ...]:
    """Root feeding two (near-)balanced chains (the middle shape of Fig. 3)."""
    if k <= 2:
        return chain_edges(k)
    edges = [(0, 1), (0, 2)]
    # Continue the two branches alternately: 1->3, 2->4, 3->5, ...
    for v in range(3, k):
        edges.append((v - 2, v))
    return tuple(edges)


def fanout_edges(k: int) -> tuple[tuple[int, int], ...]:
    """One root feeding all other tasks (the right shape of Fig. 3)."""
    return tuple((0, v) for v in range(1, k))


DAG_SHAPES = ("chain", "branch", "fanout")
_EDGE_FNS = {"chain": chain_edges, "branch": branch_edges, "fanout": fanout_edges}


def sample_job(rng: np.random.Generator, k: int, mean_dur: float = 7.0,
               arrival_horizon: int = 96, shape: str | None = None) -> Job:
    """Sample one job per the paper: exp(mean 7 epochs) durations (ceil, >=1),
    uniform arrival in the next 24h (96 epochs), DAG from Fig. 3 shapes."""
    if shape is None:
        shape = DAG_SHAPES[rng.integers(len(DAG_SHAPES))]
    durs = np.maximum(1, np.ceil(rng.exponential(mean_dur, size=k))).astype(int)
    arrival = int(rng.integers(0, arrival_horizon))
    return Job(arrival=arrival, base_durations=tuple(int(d) for d in durs),
               edges=_EDGE_FNS[shape](k))


def generate_instance(
    rng: np.random.Generator,
    n_jobs: int = 10,
    k_tasks: int = 4,
    n_machines: int = 5,
    heterogeneous: bool = False,
    mean_dur: float = 7.0,
    arrival_horizon: int = 96,
    shape: str | None = None,
) -> Instance:
    """Sample a paper-style instance (Section 3.1 defaults: n=10, k=4, M=5)."""
    jobs = tuple(sample_job(rng, k_tasks, mean_dur, arrival_horizon, shape)
                 for _ in range(n_jobs))
    if heterogeneous:
        if n_machines == 5:
            powers, speeds = HETERO_POWERS_KW, HETERO_SPEEDS
        else:  # cycle the 5 classes
            powers = tuple(HETERO_POWERS_KW[i % 5] for i in range(n_machines))
            speeds = tuple(HETERO_SPEEDS[i % 5] for i in range(n_machines))
    else:
        powers = (1.0,) * n_machines
        speeds = (1.0,) * n_machines
    return Instance(jobs=jobs, powers_kw=powers, speeds=speeds)
