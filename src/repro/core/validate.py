"""Shared schedule-feasibility validator — one source of truth for Eqs. 4-8.

Every layer that produces or consumes a schedule ``(start[T], assign[T])``
checks it here, against the constraints of the paper's Appendix A MILP:

  Eq. 4  arrivals          start[t] >= a_{j(t)}
  Eq. 5  DAG precedence    start[v] >= start[u] + p_{u,assign[u]} on edges u->v
  Eq. 6  machine validity  assign[t] in allowed[t]
  Eq. 8  no-overlap        intervals on one machine are pairwise disjoint
  budget (deadline)        completion[t] <= deadline — the ``S x OPT`` cap of
                           the bi-level protocol (Section 3.1) and the online
                           stretch budget of the dispatchers.

(Eq. 7 — each task runs on exactly one machine — holds structurally: the
``assign`` representation cannot express anything else.)

Two paths over the same semantics:

* :func:`violation_report` / :func:`total_violations` — jnp, jit- and
  vmap-friendly, return integer violation *masses* (0 == feasible).  Used by
  solvers, decoders and batched benchmarks without host round-trips.
  :func:`total_violations_batch` maps them over stacked (padded) instances
  plus any number of per-instance sweep axes (policy grids, forecast seeds,
  scenario cells) in one call.
* :func:`check_feasible_np` / :func:`assert_feasible_np` — numpy/Python,
  return human-readable problem strings.  Used by tests and the oracles.

Padded tasks (``task_mask == False``) are ignored by every check.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.instance import PackedInstance

_MACHINE_WEIGHT = jnp.int32(10**6)  # one disallowed assignment >> any epoch mass


class ViolationReport(NamedTuple):
    """Per-constraint violation masses (int32 scalars; all-zero == feasible)."""

    arrival: jnp.ndarray     # Eq. 4: epochs started before arrival
    precedence: jnp.ndarray  # Eq. 5: epochs a task overlaps a predecessor
    machine: jnp.ndarray     # Eq. 6: count of disallowed assignments
    overlap: jnp.ndarray     # Eq. 8: overlap epochs on shared machines
    budget: jnp.ndarray      # deadline: epochs of completion past it

    @property
    def total(self) -> jnp.ndarray:
        return (self.arrival + self.precedence + self.machine
                + self.overlap + self.budget)

    @property
    def feasible(self) -> jnp.ndarray:
        return self.total == 0


def task_durations(inst: PackedInstance, assign: jnp.ndarray) -> jnp.ndarray:
    """dur[t, assign[t]] -> int32 [T].  Owned here (the lowest layer above
    ``instance``); ``objectives`` re-exports it for the historical path."""
    return jnp.take_along_axis(inst.dur, assign[:, None], axis=1)[:, 0]


def violation_report(inst: PackedInstance, start: jnp.ndarray,
                     assign: jnp.ndarray,
                     deadline: jnp.ndarray | None = None) -> ViolationReport:
    """Per-constraint violation masses; jit/vmap friendly.

    ``deadline`` (optional, epochs): when given, completions past it count as
    budget violations — pass the bi-level ``S x OPT`` deadline or the online
    stretch budget.
    """
    T = inst.T
    d = task_durations(inst, assign)
    comp = start + d
    mask = inst.task_mask

    # Eq. 4: start >= arrival.
    v_arr = jnp.sum(jnp.where(mask, jnp.maximum(inst.arrival - start, 0), 0))

    # Eq. 5: for every edge (u -> t): start[t] >= comp[u].
    gap = comp[None, :] - start[:, None]          # [t, u]: must be <= 0 on edges
    v_dep = jnp.sum(jnp.where(inst.pred & mask[:, None] & mask[None, :],
                              jnp.maximum(gap, 0), 0))

    # Eq. 6: assigned machine must be allowed.
    ok = jnp.take_along_axis(inst.allowed, assign[:, None], axis=1)[:, 0]
    v_mach = jnp.sum(jnp.where(mask & ~ok, 1, 0))

    # Eq. 8: no-overlap — for every pair on the same machine, intervals must
    # be disjoint. Overlap(a,b) = max(0, min(end) - max(start)).
    same_m = (assign[:, None] == assign[None, :])
    both = mask[:, None] & mask[None, :]
    iu = ~jnp.tri(T, dtype=bool)  # strictly upper: each unordered pair once
    ov = jnp.minimum(comp[:, None], comp[None, :]) - \
        jnp.maximum(start[:, None], start[None, :])
    v_olap = jnp.sum(jnp.where(same_m & both & iu, jnp.maximum(ov, 0), 0))

    if deadline is None:
        v_bud = jnp.int32(0)
    else:
        over = comp - jnp.asarray(deadline).astype(jnp.int32)
        v_bud = jnp.sum(jnp.where(mask, jnp.maximum(over, 0), 0))

    return ViolationReport(v_arr.astype(jnp.int32), v_dep.astype(jnp.int32),
                           v_mach.astype(jnp.int32), v_olap.astype(jnp.int32),
                           v_bud.astype(jnp.int32))


def total_violations(inst: PackedInstance, start: jnp.ndarray,
                     assign: jnp.ndarray,
                     deadline: jnp.ndarray | None = None) -> jnp.ndarray:
    """Scalar violation mass (0 == feasible); machine violations weighted so a
    single disallowed assignment dominates any epoch-mass term (solvers use
    this as a penalty)."""
    r = violation_report(inst, start, assign, deadline)
    return (r.arrival + r.precedence + r.machine * _MACHINE_WEIGHT
            + r.overlap + r.budget).astype(jnp.int32)


def total_violations_batch(insts: PackedInstance, start, assign,
                           deadline=None) -> jnp.ndarray:
    """Batched feasibility over stacked (padded) instances.

    ``insts`` carries a leading instance axis ``[B, ...]`` (from
    :func:`repro.core.instance.stack_packed`); ``start``/``assign`` are
    ``[B, *extra, T]`` where ``*extra`` are any per-instance sweep axes — a
    gate-policy grid, forecast seeds, a scenario cell axis — broadcast
    against their instance.  ``deadline`` (optional) broadcasts to
    ``[B, *extra]``.  Returns int32 violation masses of shape
    ``[B, *extra]``; all-zero == every schedule in the sweep is feasible.
    Padded tasks and machines are ignored exactly as in
    :func:`violation_report`.
    """
    start = jnp.asarray(start)
    assign = jnp.asarray(assign)
    n_extra = start.ndim - 2
    if n_extra < 0:
        raise ValueError(f"start must be at least [B, T], got {start.shape}")
    if deadline is None:
        fn = lambda i, s, a: total_violations(i, s, a)
        for _ in range(n_extra):
            fn = jax.vmap(fn, in_axes=(None, 0, 0))
        return jax.vmap(fn)(insts, start, assign)
    deadline = jnp.broadcast_to(jnp.asarray(deadline), start.shape[:-1])
    fn = lambda i, s, a, d: total_violations(i, s, a, d)
    for _ in range(n_extra):
        fn = jax.vmap(fn, in_axes=(None, 0, 0, 0))
    return jax.vmap(fn)(insts, start, assign, deadline)


# ---------------------------------------------------------------------------
# numpy / Python path — human-readable reports for tests and oracles.
# ---------------------------------------------------------------------------

def check_feasible_np(inst: PackedInstance, start, assign,
                      deadline: int | None = None) -> list[str]:
    """Python-level feasibility report: one string per violation, [] if
    feasible.  Same semantics as :func:`violation_report` (independent
    implementation, so the two paths cross-check each other in tests)."""
    start = np.asarray(start)
    assign = np.asarray(assign)
    dur = np.asarray(inst.dur)
    mask = np.asarray(inst.task_mask)
    pred = np.asarray(inst.pred)
    arr = np.asarray(inst.arrival)
    allowed = np.asarray(inst.allowed)
    probs = []
    T = dur.shape[0]
    comp = start + dur[np.arange(T), assign]
    for t in range(T):
        if not mask[t]:
            continue
        if not allowed[t, assign[t]]:
            probs.append(f"task {t}: machine {assign[t]} not allowed")
        if start[t] < arr[t]:
            probs.append(f"task {t}: starts {start[t]} before arrival {arr[t]}")
        if deadline is not None and comp[t] > deadline:
            probs.append(f"task {t}: ends {comp[t]} past deadline {deadline}")
        for u in range(T):
            if pred[t, u] and mask[u] and start[t] < comp[u]:
                probs.append(f"task {t}: starts {start[t]} before pred {u} ends {comp[u]}")
        for u in range(t + 1, T):
            if mask[u] and assign[u] == assign[t]:
                if max(start[t], start[u]) < min(comp[t], comp[u]):
                    probs.append(f"tasks {t},{u} overlap on machine {assign[t]}")
    return probs


def assert_feasible_np(inst: PackedInstance, start, assign,
                       deadline: int | None = None, ctx: str = "") -> None:
    """Raise ``AssertionError`` with the full problem list if infeasible."""
    probs = check_feasible_np(inst, start, assign, deadline)
    if probs:
        head = f"infeasible schedule{f' ({ctx})' if ctx else ''}:"
        raise AssertionError("\n  ".join([head] + probs))
