"""Online carbon-aware list scheduling — the paper's "future work" probed.

The paper computes *offline upper bounds* and asks (§4) whether online
heuristics can approach them.  This module implements two event-driven
dispatchers that see a job only at its arrival (and a day-ahead carbon
forecast, which grid operators publish):

* :func:`online_greedy` — carbon-agnostic earliest-task-first on the
  earliest-finishing machine (the classic Graham list scheduler): the
  online *makespan* baseline.
* :func:`online_carbon_gated` — same dispatch rule, but a ready task may
  *wait* while the current intensity is above the ``theta``-quantile of
  the forecast over the next ``window`` epochs — bounded by a makespan
  budget ``stretch x`` the carbon-agnostic online makespan, so waiting can
  never blow up completion time (the S-knob of the paper, applied online).

Both run in plain numpy (they are sequential simulations by nature) and
return (start, assign) arrays that the standard objectives evaluate, so
benchmarks can report: offline bound vs. online achievable, same traces.
"""
from __future__ import annotations

import numpy as np

from repro.core.instance import PackedInstance

# Machine choice among the *free* allowed machines at dispatch time (all
# candidates start now, so min duration == earliest finish):
#   earliest_finish — (duration, energy) lexicographic: the makespan-greedy
#                     rule of the Graham list scheduler.
#   min_energy      — (energy, duration) lexicographic: ROADMAP's "min-energy
#                     dispatch under the gate"; trades completion time for
#                     power-proportional cost on heterogeneous menus.
# Ties beyond the key fall to the lowest machine index (stable min).
ONLINE_MACHINE_RULES = ("earliest_finish", "min_energy")


def _np_inst(inst: PackedInstance):
    return (np.asarray(inst.dur), np.asarray(inst.allowed),
            np.asarray(inst.pred), np.asarray(inst.arrival),
            np.asarray(inst.task_mask), np.asarray(inst.power))


def _critical_path(dur, allowed, pred, mask) -> np.ndarray:
    """Downstream critical path per task (min-duration), incl. itself."""
    T = dur.shape[0]
    dmin = np.where(allowed, dur, 1 << 20).min(1)
    cp = np.zeros(T, np.int64)
    for t in range(T - 1, -1, -1):          # topological (pred[u,t] => t<u)
        if not mask[t]:
            continue
        succ = [u for u in range(T) if pred[u, t] and mask[u]]
        cp[t] = dmin[t] + (max(cp[u] for u in succ) if succ else 0)
    return cp


def _simulate(inst: PackedInstance, intensity: np.ndarray | None,
              theta: float, window: int, budget: int | None,
              machine_rule: str = "earliest_finish"):
    if machine_rule not in ONLINE_MACHINE_RULES:
        raise ValueError(f"unknown machine_rule {machine_rule!r}")
    dur, allowed, pred, arrival, mask, power = _np_inst(inst)
    T, M = dur.shape
    real = mask.nonzero()[0]
    cp = _critical_path(dur, allowed, pred, mask)
    start = np.zeros(T, np.int64)
    assign = np.zeros(T, np.int64)
    comp = np.full(T, -1, np.int64)
    mfree = np.zeros(M, np.int64)
    done: set[int] = set()
    horizon = len(intensity) if intensity is not None else 1 << 20
    t = 0
    while len(done) < len(real) and t < horizon - 1:
        progressed = True
        while progressed:
            progressed = False
            for tk in real:
                if comp[tk] >= 0 or arrival[tk] > t:
                    continue
                if any(pred[tk, u] and mask[u]
                       and (comp[u] < 0 or comp[u] > t) for u in range(T)):
                    continue
                # carbon gate: wait out dirty epochs while the task's
                # downstream critical path still fits the budget.
                if intensity is not None and budget is not None:
                    w = intensity[t:min(t + window, horizon)]
                    thresh = np.quantile(w, theta)
                    dirty = intensity[t] > thresh + 1e-9
                    if dirty and t + 1 + int(cp[tk]) <= budget:
                        continue
                free = [m for m in range(M)
                        if allowed[tk, m] and mfree[m] <= t]
                if not free:
                    continue
                if machine_rule == "min_energy":
                    m = min(free, key=lambda m: (power[m] * dur[tk, m],
                                                 dur[tk, m]))
                else:
                    m = min(free, key=lambda m: (dur[tk, m],
                                                 power[m] * dur[tk, m]))
                start[tk], assign[tk] = t, m
                comp[tk] = t + dur[tk, m]
                mfree[m] = comp[tk]
                if comp[tk] == t:               # zero-length guard
                    done.add(tk)
                progressed = True
        t += 1
        for tk in real:
            if comp[tk] == t and tk not in done:
                done.add(tk)
    return start, assign


def online_greedy(inst: PackedInstance,
                  machine_rule: str = "earliest_finish"
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Carbon-agnostic earliest-task-first (online makespan baseline)."""
    return _simulate(inst, None, 0.0, 1, None, machine_rule=machine_rule)


def online_carbon_gated(inst: PackedInstance, intensity: np.ndarray,
                        theta: float = 0.5, window: int = 96,
                        stretch: float = 1.5, budget: int | None = None,
                        machine_rule: str = "earliest_finish"
                        ) -> tuple[np.ndarray, np.ndarray]:
    """Carbon-gated dispatch under an online makespan budget.

    ``intensity``: per-epoch gCO2/kWh forecast (the cum-trace's diffs).
    Budget = ``stretch x`` the greedy online makespan (computed first) —
    the online analogue of the paper's S-constraint.  Pass ``budget``
    directly (``int(stretch * greedy_makespan)``) to skip the internal
    greedy run, e.g. when sweeping many policies over one instance.
    ``machine_rule`` picks among free machines (see ONLINE_MACHINE_RULES);
    the greedy budget run uses the same rule so the stretch cap is relative
    to the rule's own baseline.
    """
    if budget is None:
        s0, a0 = online_greedy(inst, machine_rule=machine_rule)
        dur = np.asarray(inst.dur)
        mask = np.asarray(inst.task_mask)
        T = dur.shape[0]
        ms0 = int(max((s0[t] + dur[t, a0[t]]) for t in range(T) if mask[t]))
        budget = int(stretch * ms0)
    return _simulate(inst, np.asarray(intensity), theta, window, budget,
                     machine_rule=machine_rule)
