"""Rolling-horizon (MPC-style) replanning over the batched solvers.

The bi-level solver (:mod:`repro.core.solvers.bilevel`) plans once against a
perfect trace.  This module re-plans: at every boundary ``r_k = k * every``
it re-issues the carbon forecast for the remaining horizon
(:func:`repro.forecast.models.issue` at ``t0 = r_k``), freezes every task
that has already *started* executing under the incumbent plan, and re-runs
the SA search on the remaining sub-DAG against the updated forecast — model
predictive control with the paper's phase-2 search as the per-step
controller.  The whole replan sequence is one ``lax.scan`` (one XLA
program), and :func:`solve_mpc_batch` vmaps it over instances x forecast
seeds — including mixed-shape scenario batches padded by
:func:`repro.scenarios.batching.pack_aligned` (the freeze transform
preserves the padding contract: padded tasks are never frozen because they
never "start", and padded machines stay disallowed since ``_frozen_instance``
only ever *shrinks* ``allowed`` for frozen real tasks).

Freezing without changing the SGS decoder
-----------------------------------------
A started task cannot move (its start is in the past) nor migrate (it is
running).  Both are enforced by an *instance transform* plus a *candidate
projection*, so the stock SGS/SA machinery is reused unchanged:

* ``arrival``: frozen tasks get ``arrival = start`` (pinning the earliest
  start at the executed start), free tasks get ``arrival = max(arrival,
  r_k)`` (nothing can start in the past);
* ``allowed``: frozen tasks shrink to the one machine they run on, so every
  mutation/crossover in SA/GA keeps them there;
* priorities: frozen tasks are projected into a high band
  (``FROZEN_BAND - start``) so SGS places them first, in executed-start
  order.  Earliest-feasible placement then reproduces the executed prefix
  *exactly*: arrival pins the lower bound, and the incumbent's feasibility
  guarantees machines and predecessors impose nothing later.
* the timing sweep gets the ``frozen`` mask and never shifts a frozen task
  (``decode_full(..., frozen=...)``).

Every replan keeps the incumbent plan as a warm start *and* as a fallback
(the incumbent stays feasible for the transformed instance because free
tasks start at ``>= r_k`` by construction), so planned carbon under the
current forecast is monotone non-increasing across a replan — with a perfect
forecast (``scale = 0``) realized carbon can only improve on the day-ahead
plan.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.instance import EPOCH_HOURS, PackedInstance
from repro.core.objectives import evaluate, utilization
from repro.core.solvers import common
from repro.core.solvers.annealing import SAConfig, solve_sa
from repro.forecast import models as fmodels

NO_DEADLINE = jnp.int32(1 << 27)

# Frozen tasks live this far above any free candidate priority (free prios
# are clamped to FREE_CEIL), so SGS always places the executed prefix first,
# in executed-start order.  Both bounds are small powers of two: every
# integer in [FROZEN_BAND - 2^20, FROZEN_BAND] is exactly representable in
# float32, so ``FROZEN_BAND - start`` keeps *distinct* priorities for
# distinct starts (a 1e9-style band would collapse them — ulp(1e9) = 64 —
# and place frozen tasks in index order, breaking the prefix).
FROZEN_BAND = jnp.float32(2 ** 21)
FREE_CEIL = jnp.float32(2 ** 19)


class MPCConfig(NamedTuple):
    """Static knobs of the rolling replanner (hashable; jit-static)."""

    every: int = 48                  # replan interval (epochs)
    n_replans: int = 4               # boundaries 0, every, ..., (n-1)*every
    stretch: float = 1.5             # deadline = floor(stretch * OPT)
    model: str = "oracle_ar1"        # forecast model (repro.forecast.models)
    rho: float = fmodels.AR1_RHO
    sa: SAConfig = SAConfig(pop=32, iters=40, sweeps=1)       # per replan
    sa_phase1: SAConfig = SAConfig(pop=48, iters=80)          # OPT makespan


class MPCResult(NamedTuple):
    """Leading axes from :func:`solve_mpc_batch`: [B instances, S seeds]."""

    start: jnp.ndarray            # int32 [T] final executed plan
    assign: jnp.ndarray           # int32 [T]
    opt_makespan: jnp.ndarray     # phase-1 OPT (epochs)
    deadline: jnp.ndarray         # floor(stretch * OPT)
    baseline: common.ScheduleResult   # carbon-agnostic plan, true-trace eval
    realized: common.ScheduleResult   # final plan evaluated on the true trace
    plans_start: jnp.ndarray      # int32 [K, T] incumbent after each replan
    plans_assign: jnp.ndarray     # int32 [K, T]
    frozen_counts: jnp.ndarray    # int32 [K] tasks frozen at each boundary
    planned_carbon: jnp.ndarray   # float32 [K] plan's carbon under its forecast


def forecast_cum(point: jnp.ndarray) -> jnp.ndarray:
    """Cumulative carbon-energy of a (forecast) intensity; float32 [E+1]."""
    return jnp.concatenate([
        jnp.zeros((1,), jnp.float32),
        jnp.cumsum(point.astype(jnp.float32) * EPOCH_HOURS)])


def _project(prio, assign, frozen, start_inc, assign_inc):
    """Clamp a candidate onto the frozen prefix (see module docstring)."""
    prio = jnp.minimum(prio, FREE_CEIL)
    prio = jnp.where(frozen, FROZEN_BAND - start_inc.astype(jnp.float32),
                     prio)
    assign = jnp.where(frozen, assign_inc, assign).astype(jnp.int32)
    return prio, assign


def _frozen_instance(inst: PackedInstance, frozen, start, assign,
                     r) -> PackedInstance:
    """Pin frozen tasks at (start, machine); bar free tasks from the past."""
    onehot = jnp.arange(inst.M, dtype=jnp.int32)[None, :] == assign[:, None]
    allowed = jnp.where(frozen[:, None], onehot, inst.allowed)
    arrival = jnp.where(frozen, start,
                        jnp.maximum(inst.arrival, r)).astype(jnp.int32)
    return inst._replace(allowed=allowed, arrival=arrival)


@functools.partial(jax.jit, static_argnames=("objective", "cfg"))
def solve_mpc(inst: PackedInstance, truth: jnp.ndarray, cum_true: jnp.ndarray,
              key: jax.Array, fc_key: jax.Array, scale: jnp.ndarray,
              objective: str = "carbon",
              cfg: MPCConfig = MPCConfig()) -> MPCResult:
    """Rolling-horizon replanning of one instance (see module docstring).

    ``truth``: realized intensity [E] — the forecasts' ground truth.
    ``cum_true``: cumulative carbon-energy [E+1] used for *realized*
    evaluation (pass the trace's own ``cumulative()`` so every method in a
    benchmark is scored by the same integral).  ``fc_key`` seeds the
    forecast error draws (folded per replan); ``key`` seeds the search.
    ``cfg.n_replans`` should cover the deadline (``n_replans * every >=
    stretch * OPT``); later boundaries freeze everything and degenerate to
    no-ops.
    """
    sweeps = max(cfg.sa.sweeps, 1)
    k1, k_run = jax.random.split(key)

    # ---- Phase 1: carbon-agnostic OPT fixes the deadline and the initial
    # incumbent (the plan a day-ahead deployment would start executing).
    p1 = solve_sa(inst, cum_true, NO_DEADLINE, k1, objective="makespan",
                  machine_rule="earliest_finish", cfg=cfg.sa_phase1)
    baseline = common.decode_full(
        inst, cum_true, NO_DEADLINE, p1.prio, p1.assign,
        objective="makespan", machine_rule="earliest_finish", sweeps=0)
    opt_ms = baseline.makespan
    deadline = jnp.floor(cfg.stretch * opt_ms.astype(jnp.float32) + 1e-6
                         ).astype(jnp.int32)

    def replan(carry, k):
        start, assign, key = carry
        r = (k * cfg.every).astype(jnp.int32)
        frozen = inst.task_mask & (start < r)
        inst_k = _frozen_instance(inst, frozen, start, assign, r)

        fc = fmodels.issue(truth, r, key=jax.random.fold_in(fc_key, k),
                           model=cfg.model, scale=scale, rho=cfg.rho)
        cum_k = forecast_cum(fc.point)

        prio0, assign0 = _project(-start.astype(jnp.float32), assign,
                                  frozen, start, assign)
        key, k_sa = jax.random.split(key)
        out = solve_sa(inst_k, cum_k, deadline, k_sa, objective=objective,
                       machine_rule="fixed", cfg=cfg.sa,
                       prio_init=prio0, assign_init=assign0, frozen=frozen)
        prio_f, assign_f = _project(out.prio, out.assign, frozen, start,
                                    assign)
        cand = common.decode_full(inst_k, cum_k, deadline, prio_f, assign_f,
                                  objective=objective, machine_rule="fixed",
                                  sweeps=sweeps, frozen=frozen)
        inc = common.decode_full(inst_k, cum_k, deadline, prio0, assign0,
                                 objective=objective, machine_rule="fixed",
                                 sweeps=sweeps, frozen=frozen)
        # Keep whichever plan the *current* forecast scores better (the
        # incumbent decode is feasible by construction, so this is the same
        # warm-start guard bilevel uses).
        better = (common.fitness_of(inst_k, cand, deadline, objective)
                  < common.fitness_of(inst_k, inc, deadline, objective))
        pick = lambda a, b: jnp.where(better, a, b)
        new_start = pick(cand.start, inc.start)
        new_assign = pick(cand.assign, inc.assign)
        planned = pick(cand.carbon, inc.carbon)
        return ((new_start, new_assign, key),
                (new_start, new_assign, frozen.sum().astype(jnp.int32),
                 planned))

    init = (baseline.start, baseline.assign, k_run)
    (start, assign, _), (plans_s, plans_a, frozen_counts, planned) = \
        jax.lax.scan(replan, init,
                     jnp.arange(cfg.n_replans, dtype=jnp.int32))

    obj = evaluate(inst, start, assign, cum_true)
    realized = common.ScheduleResult(
        start, assign, obj.makespan, obj.energy, obj.carbon,
        utilization(inst, start, assign))

    return MPCResult(
        start=start, assign=assign, opt_makespan=opt_ms, deadline=deadline,
        baseline=baseline, realized=realized,
        plans_start=plans_s, plans_assign=plans_a,
        frozen_counts=frozen_counts, planned_carbon=planned)


def solve_mpc_batch(insts: PackedInstance, truths: jnp.ndarray,
                    cums_true: jnp.ndarray, keys: jax.Array,
                    fc_keys: jax.Array, scale, **kw) -> MPCResult:
    """vmap of :func:`solve_mpc` over [B] instances x [S] forecast seeds.

    ``insts``/``truths``/``cums_true``/``keys``: leading [B]; ``fc_keys``:
    [S].  ``scale`` is shared.  Result axes: [B, S, ...].
    """
    scale = jnp.float32(scale)
    per_seed = jax.vmap(
        lambda inst, truth, cum, key, fck: functools.partial(
            solve_mpc, **kw)(inst, truth, cum, key, fck, scale),
        in_axes=(None, None, None, None, 0))
    return jax.vmap(per_seed, in_axes=(0, 0, 0, 0, None))(
        insts, truths, cums_true, keys, fc_keys)
