"""The paper's bi-level protocol (Section 2/3.1), end to end in JAX.

Phase 1  — classic FJSP: minimize makespan, carbon-agnostic.  The result is
           both the baseline schedule (against which savings are reported)
           and the constraint OPT.
Phase 2  — minimize carbon (Def 2.3) or energy (Def 2.2) subject to
           makespan <= floor(S * OPT) for stretch factor S >= 1, warm-started
           from the phase-1 schedule (which is always feasible for S >= 1, so
           savings are never negative by construction — unlike the paper's
           timeout'd CP-SAT, which occasionally returns worse-than-baseline
           schedules at large S, see Fig. 5b).

``solve_bilevel`` is a pure jnp function of (instance, trace, key);
``solve_bilevel_batch`` vmaps it across instances so a whole benchmark
config (e.g. 1000 paper instances) is one XLA program.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.instance import PackedInstance
from repro.core.solvers import common
from repro.core.solvers.annealing import SAConfig, solve_sa
from repro.core.solvers.genetic import GAConfig, solve_ga

NO_DEADLINE = jnp.int32(1 << 27)


class BilevelResult(NamedTuple):
    opt_makespan: jnp.ndarray       # phase-1 OPT (epochs)
    deadline: jnp.ndarray           # floor(S * OPT)
    baseline: common.ScheduleResult  # carbon-agnostic, makespan-optimal
    optimized: common.ScheduleResult
    carbon_savings: jnp.ndarray     # 1 - opt.carbon / baseline.carbon
    energy_savings: jnp.ndarray     # 1 - opt.energy / baseline.energy


@functools.partial(
    jax.jit, static_argnames=("objective", "stretch", "solver", "cfg1", "cfg2",
                              "use_kernels"))
def solve_bilevel(inst: PackedInstance, cum: jnp.ndarray, key: jax.Array,
                  objective: str = "carbon", stretch: float = 1.0,
                  solver: str = "sa",
                  cfg1: SAConfig | GAConfig | None = None,
                  cfg2: SAConfig | GAConfig | None = None,
                  use_kernels: bool | None = None) -> BilevelResult:
    """``use_kernels`` selects the Pallas fitness path inside both solver
    phases (bit-exact equal to the jnp path, so the result is identical
    either way); ``None`` defers to ``REPRO_KERNELS`` / backend default."""
    if solver == "sa":
        solve = solve_sa
        cfg1 = cfg1 or SAConfig()
        cfg2 = cfg2 or cfg1
    elif solver == "ga":
        solve = solve_ga
        cfg1 = cfg1 or GAConfig()
        cfg2 = cfg2 or cfg1
    else:
        raise ValueError(f"unknown solver {solver!r}")
    k1, k2 = jax.random.split(key)

    # ---- Phase 1: makespan-only (the carbon-agnostic baseline). ----------
    p1 = solve(inst, cum, NO_DEADLINE, k1, objective="makespan",
               machine_rule="earliest_finish", cfg=cfg1,
               use_kernels=use_kernels)
    baseline = common.decode_full(
        inst, cum, NO_DEADLINE, p1.prio, p1.assign,
        objective="makespan", machine_rule="earliest_finish", sweeps=0)
    opt_ms = baseline.makespan
    deadline = jnp.floor(stretch * opt_ms.astype(jnp.float32) + 1e-6
                         ).astype(jnp.int32)

    # ---- Phase 2: carbon/energy under makespan <= S * OPT. ---------------
    # Warm start: the baseline's own (sequence, assignment) is feasible.
    p2 = solve(inst, cum, deadline, k2, objective=objective,
               machine_rule="fixed", cfg=cfg2,
               prio_init=-baseline.start.astype(jnp.float32),
               assign_init=baseline.assign, use_kernels=use_kernels)
    optimized = common.decode_full(
        inst, cum, deadline, p2.prio, p2.assign,
        objective=objective, machine_rule="fixed", sweeps=max(
            getattr(cfg2, "sweeps", 2), 1))

    # Guard: if phase 2 somehow ended worse (it cannot, given the warm start
    # chain is kept, but belt-and-braces), fall back to the timing-swept
    # baseline which is feasible by construction.
    fallback = common.decode_full(
        inst, cum, deadline, -baseline.start.astype(jnp.float32),
        baseline.assign, objective=objective, machine_rule="fixed",
        sweeps=max(getattr(cfg2, "sweeps", 2), 1))
    key_obj = {"carbon": 4, "energy": 3}[objective]
    use_fb = (optimized[key_obj] > fallback[key_obj]) | \
        (optimized.makespan > deadline)
    optimized = jax.tree.map(
        lambda a, b: jnp.where(use_fb, b, a), optimized, fallback)

    return BilevelResult(
        opt_makespan=opt_ms,
        deadline=deadline,
        baseline=baseline,
        optimized=optimized,
        carbon_savings=1.0 - optimized.carbon / jnp.maximum(baseline.carbon, 1e-9),
        energy_savings=1.0 - optimized.energy / jnp.maximum(baseline.energy, 1e-9),
    )


def solve_bilevel_batch(insts: PackedInstance, cums: jnp.ndarray,
                        keys: jax.Array, **kw) -> BilevelResult:
    """vmap of :func:`solve_bilevel` over a leading instance axis."""
    fn = functools.partial(solve_bilevel, **kw)
    return jax.vmap(fn)(insts, cums, keys)
