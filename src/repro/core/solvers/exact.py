"""Exact oracle for tiny FJSP instances (pure Python / numpy).

Replaces the paper's CP-SAT *in tests only*: it certifies that the JAX
metaheuristics reach the optimal makespan and near-optimal carbon on
instances small enough to enumerate.  Two searches:

* :func:`exact_makespan` — enumerate (topological order, machine assignment)
  pairs and decode each with earliest-start SGS.  The SGS image contains a
  makespan-optimal schedule (DESIGN.md §3), so the minimum over the
  enumeration is the true OPT.
* :func:`exact_carbon` — DFS over tasks in topological order, branching on
  (machine, start epoch) with branch-and-bound pruning; exact over the given
  horizon.  Exponential — keep T <= 5, H <= 16 in tests.
"""
from __future__ import annotations

import itertools

import numpy as np

from repro.core.instance import PackedInstance


def _np_inst(inst: PackedInstance):
    return (np.asarray(inst.dur), np.asarray(inst.allowed),
            np.asarray(inst.pred), np.asarray(inst.arrival),
            np.asarray(inst.task_mask), np.asarray(inst.power))


def _topological_orders(pred: np.ndarray, mask: np.ndarray):
    """Yield every topological order of the real tasks."""
    T = pred.shape[0]
    real = [t for t in range(T) if mask[t]]

    def rec(placed: list[int], remaining: set[int]):
        if not remaining:
            yield list(placed)
            return
        for t in sorted(remaining):
            if all((not pred[t, u]) or (u in placed) for u in range(T) if mask[u]):
                placed.append(t)
                remaining.remove(t)
                yield from rec(placed, remaining)
                placed.pop()
                remaining.add(t)

    yield from rec([], set(real))


def _sgs_np(order, assign, dur, pred, arrival, mask, M):
    """Earliest-start SGS for a fixed order + assignment. Returns (start, ms)."""
    T = dur.shape[0]
    comp = np.zeros(T, np.int64)
    start = np.zeros(T, np.int64)
    mfree = np.zeros(M, np.int64)
    for t in order:
        m = assign[t]
        pc = max([comp[u] for u in range(T) if pred[t, u] and mask[u]], default=0)
        s = max(arrival[t], pc, mfree[m])
        start[t] = s
        comp[t] = s + dur[t, m]
        mfree[m] = comp[t]
    ms = max((comp[t] for t in range(T) if mask[t]), default=0)
    return start, ms


def exact_makespan(inst: PackedInstance) -> int:
    """True optimal makespan by enumeration. Exponential — tiny instances only."""
    dur, allowed, pred, arrival, mask, _ = _np_inst(inst)
    T, M = dur.shape
    real = [t for t in range(T) if mask[t]]
    best = np.inf
    machine_choices = [
        [m for m in range(M) if allowed[t, m]] for t in range(T)]
    for order in _topological_orders(pred, mask):
        for combo in itertools.product(*(machine_choices[t] for t in real)):
            assign = np.zeros(T, np.int64)
            for t, m in zip(real, combo):
                assign[t] = m
            _, ms = _sgs_np(order, assign, dur, pred, arrival, mask, M)
            best = min(best, ms)
    return int(best)


def exact_carbon(inst: PackedInstance, cum: np.ndarray, deadline: int
                 ) -> tuple[float, np.ndarray, np.ndarray]:
    """Exact minimum carbon subject to makespan <= deadline.

    Returns (carbon, start, assign). Branch-and-bound over tasks in
    topological index order; each branch picks (machine, start).
    """
    dur, allowed, pred, arrival, mask, power = _np_inst(inst)
    cum = np.asarray(cum, np.float64)
    T, M = dur.shape
    real = [t for t in range(T) if mask[t]]
    best = {"carbon": np.inf, "start": None, "assign": None}
    start = np.zeros(T, np.int64)
    assign = np.zeros(T, np.int64)
    busy: list[list[tuple[int, int]]] = [[] for _ in range(M)]

    def feasible_on(m: int, s: int, e: int) -> bool:
        return all(e <= bs or s >= be for (bs, be) in busy[m])

    def rec(i: int, carbon_so_far: float):
        if carbon_so_far >= best["carbon"]:
            return
        if i == len(real):
            best["carbon"] = carbon_so_far
            best["start"] = start.copy()
            best["assign"] = assign.copy()
            return
        t = real[i]
        pc = max([start[u] + dur[u, assign[u]]
                  for u in range(T) if pred[t, u] and mask[u]], default=0)
        lo = max(int(arrival[t]), pc)
        for m in range(M):
            if not allowed[t, m]:
                continue
            d = int(dur[t, m])
            for s in range(lo, deadline - d + 1):
                if not feasible_on(m, s, s + d):
                    continue
                g = float(power[m]) * (cum[s + d] - cum[s])
                start[t], assign[t] = s, m
                busy[m].append((s, s + d))
                rec(i + 1, carbon_so_far + g)
                busy[m].pop()
        start[t], assign[t] = 0, 0

    rec(0, 0.0)
    return best["carbon"], best["start"], best["assign"]
