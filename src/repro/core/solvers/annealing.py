"""Massively-parallel simulated annealing over SGS encodings.

``pop`` independent Metropolis chains run in lockstep under ``vmap``; every
``migrate_every`` iterations the worst quartile of chains is re-seeded from
the global best (a cheap exploitation step that mimics CP-SAT's solution
sharing between workers).  The whole solve is a single ``lax.scan`` — one
XLA program, no host round-trips — and vmaps again over batched instances.

This is the TPU-native replacement for the paper's CP-SAT search
(DESIGN.md §3): thousands of dumb concurrent searches instead of one clever
sequential one.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.decoder import upward_rank
from repro.core.instance import PackedInstance
from repro.core.solvers import common


class SAConfig(NamedTuple):
    pop: int = 128
    iters: int = 200
    sweeps: int = 2            # carbon timing sweeps inside the decode
    sigma: float = 3.0         # priority-noise scale (epochs of rank)
    p_machine_move: float = 0.35
    migrate_every: int = 25
    t0_frac: float = 0.3       # initial temperature = frac * fitness IQR
    t_decay: float = 0.97


class SolveOut(NamedTuple):
    prio: jnp.ndarray     # best candidate found
    assign: jnp.ndarray
    fitness: jnp.ndarray  # its fitness


@functools.partial(jax.jit,
                   static_argnames=("objective", "machine_rule", "cfg",
                                    "use_kernels"))
def solve_sa(inst: PackedInstance, cum: jnp.ndarray, deadline: jnp.ndarray,
             key: jax.Array, objective: str = "carbon",
             machine_rule: str = "fixed", cfg: SAConfig = SAConfig(),
             prio_init: jnp.ndarray | None = None,
             assign_init: jnp.ndarray | None = None,
             frozen: jnp.ndarray | None = None,
             use_kernels: bool | None = None) -> SolveOut:
    """Minimize ``objective`` (see solvers.common) over SGS candidates.

    ``frozen`` (optional bool [T]) marks already-executing tasks (rolling
    replans): their priorities are never perturbed — init noise, proposals
    and migration all mask them — so the executed prefix the caller encoded
    in ``prio_init``/``assign_init`` survives the whole search exactly, and
    the timing sweep inside the decode never moves them either.

    ``use_kernels`` selects the Pallas fitness path (bit-exact equal to
    the jnp path — the solve result is identical either way); ``None``
    defers to ``REPRO_KERNELS`` / the backend default, see
    :func:`repro.core.solvers.common.population_fitness`.
    """
    T = inst.T
    free = (jnp.ones((T,), bool) if frozen is None else ~frozen)
    sweeps = 0 if objective == "makespan" else cfg.sweeps
    fit_v = lambda p, a: common.population_fitness(  # noqa: E731
        inst, cum, deadline, p, a, objective, machine_rule, sweeps,
        frozen=frozen, use_kernels=use_kernels)

    k_init, k_assign, k_run = jax.random.split(key, 3)
    rank = upward_rank(inst)
    if prio_init is None:
        prio_init = rank
    prio = (prio_init[None, :]
            + cfg.sigma * jax.random.normal(k_init, (cfg.pop, T)) * free)
    # Keep one undisturbed copy of the init (chain 0).
    prio = prio.at[0].set(prio_init)
    if assign_init is None:
        assign = common.random_allowed_assign(k_assign, inst, (cfg.pop,))
    else:
        assign = jnp.broadcast_to(assign_init, (cfg.pop, T)).astype(jnp.int32)
    fit = fit_v(prio, assign)

    spread = jnp.percentile(fit, 75) - jnp.percentile(fit, 25)
    t0 = cfg.t0_frac * jnp.maximum(spread, 1e-3)

    b0 = jnp.argmin(fit)
    best = (prio[b0], assign[b0], fit[b0])

    def step(carry, it):
        key, prio, assign, fit, best = carry
        key, k1, k2, k3, k4, k5, k6 = jax.random.split(key, 7)
        temp = t0 * cfg.t_decay ** it

        # Priority proposal: gaussian noise on a random ~2-task subset.
        mask = jax.random.bernoulli(k1, 2.0 / T, (cfg.pop, T)) & free
        dp = cfg.sigma * jax.random.normal(k2, (cfg.pop, T)) * mask
        new_prio = prio + dp
        # Machine proposal: with prob p, reassign one random task.
        do_m = jax.random.bernoulli(k3, cfg.p_machine_move, (cfg.pop,))
        t_idx = jax.random.randint(k4, (cfg.pop,), 0, T)
        new_m = common.random_allowed_assign(k5, inst, (cfg.pop,))
        picked = jnp.take_along_axis(new_m, t_idx[:, None], 1)[:, 0]
        new_assign = jnp.where(
            (jnp.arange(T)[None, :] == t_idx[:, None]) & do_m[:, None],
            picked[:, None], assign)

        new_fit = fit_v(new_prio, new_assign)
        u = jax.random.uniform(k6, (cfg.pop,))
        accept = (new_fit < fit) | (u < jnp.exp(-(new_fit - fit)
                                                / jnp.maximum(temp, 1e-6)))
        prio = jnp.where(accept[:, None], new_prio, prio)
        assign = jnp.where(accept[:, None], new_assign, assign)
        fit = jnp.where(accept, new_fit, fit)

        # Track global best.
        i = jnp.argmin(fit)
        bp, ba, bf = best
        better = fit[i] < bf
        best = (jnp.where(better, prio[i], bp),
                jnp.where(better, assign[i], ba),
                jnp.where(better, fit[i], bf))

        # Migration: worst quartile <- best + fresh noise.
        def migrate(args):
            key, prio, assign, fit = args
            kk1, kk2 = jax.random.split(key)
            thresh = jnp.percentile(fit, 75)
            worst = fit >= thresh
            mp = best[0][None, :] + cfg.sigma * jax.random.normal(
                kk1, (cfg.pop, T)) * free
            prio = jnp.where(worst[:, None], mp, prio)
            assign = jnp.where(worst[:, None],
                               jnp.broadcast_to(best[1], (cfg.pop, T)), assign)
            fit = jnp.where(worst, fit_v(prio, assign), fit)
            return prio, assign, fit

        key, km = jax.random.split(key)
        prio, assign, fit = jax.lax.cond(
            (it % cfg.migrate_every) == cfg.migrate_every - 1,
            migrate, lambda a: (a[1], a[2], a[3]), (km, prio, assign, fit))
        return (key, prio, assign, fit, best), None

    (_, _, _, _, best), _ = jax.lax.scan(
        step, (k_run, prio, assign, fit, best),
        jnp.arange(cfg.iters, dtype=jnp.int32))
    return SolveOut(*best)
