"""Shared pieces for the population solvers: candidate decoding + fitness.

A candidate is ``(prio[T] float32, assign[T] int32)``.  Decoding = SGS
(+ carbon timing sweep for the carbon/energy objectives); fitness = the
objective plus a large penalty per epoch of deadline violation, so the
constrained problem (makespan <= S * OPT) is handled by the same
unconstrained search.

The paper's energy objective uses carbon as a tiny tie-break weight
(Section 3.2, "Optimizing for energy usage vs carbon emissions") — we use
1e-6 gCO2/kWh-scale weight, below the smallest energy quantum (one epoch of
the smallest server = 0.0625 kWh).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.decoder import sgs, timing_sweep
from repro.core.instance import PackedInstance
from repro.core.objectives import Objectives, evaluate, utilization

OBJECTIVES = ("makespan", "carbon", "energy")
DEADLINE_PENALTY = 1e5       # fitness units per epoch of overshoot
ENERGY_CARBON_TIEBREAK = 1e-6


class ScheduleResult(NamedTuple):
    start: jnp.ndarray
    assign: jnp.ndarray
    makespan: jnp.ndarray
    energy: jnp.ndarray
    carbon: jnp.ndarray
    utilization: jnp.ndarray


@functools.partial(jax.jit,
                   static_argnames=("objective", "machine_rule", "sweeps"))
def decode_full(inst: PackedInstance, cum: jnp.ndarray, deadline: jnp.ndarray,
                prio: jnp.ndarray, assign: jnp.ndarray,
                objective: str = "carbon", machine_rule: str = "fixed",
                sweeps: int = 2) -> ScheduleResult:
    """Candidate -> feasible schedule + objective values."""
    dec = sgs(inst, prio, assign, machine_rule=machine_rule)
    start = dec.start
    if objective != "makespan" and sweeps > 0:
        start = timing_sweep(inst, start, dec.assign, cum, deadline, sweeps)
    obj: Objectives = evaluate(inst, start, dec.assign, cum)
    return ScheduleResult(start, dec.assign, obj.makespan, obj.energy,
                          obj.carbon, utilization(inst, start, dec.assign))


def fitness_of(res: ScheduleResult, deadline: jnp.ndarray,
               objective: str) -> jnp.ndarray:
    ms = res.makespan.astype(jnp.float32)
    over = jnp.maximum(ms - deadline.astype(jnp.float32), 0.0)
    if objective == "makespan":
        return ms
    if objective == "carbon":
        return res.carbon + DEADLINE_PENALTY * over
    if objective == "energy":
        return (res.energy + ENERGY_CARBON_TIEBREAK * res.carbon
                + DEADLINE_PENALTY * over)
    raise ValueError(f"unknown objective {objective!r}")


@functools.partial(jax.jit,
                   static_argnames=("objective", "machine_rule", "sweeps"))
def fitness_fn(inst: PackedInstance, cum: jnp.ndarray, deadline: jnp.ndarray,
               prio: jnp.ndarray, assign: jnp.ndarray, objective: str,
               machine_rule: str, sweeps: int) -> jnp.ndarray:
    res = decode_full(inst, cum, deadline, prio, assign,
                      objective=objective, machine_rule=machine_rule,
                      sweeps=sweeps)
    return fitness_of(res, deadline, objective)


def random_allowed_assign(key: jax.Array, inst: PackedInstance,
                          shape: tuple[int, ...] = ()) -> jnp.ndarray:
    """Uniform random machine among each task's allowed set."""
    g = jax.random.gumbel(key, shape + (inst.T, inst.M))
    return jnp.argmax(jnp.where(inst.allowed, g, -jnp.inf), axis=-1).astype(jnp.int32)
