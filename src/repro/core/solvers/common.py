"""Shared pieces for the population solvers: candidate decoding + fitness.

A candidate is ``(prio[T] float32, assign[T] int32)``.  Decoding = SGS
(+ carbon timing sweep for the carbon/energy objectives); fitness = the
objective plus a penalty proportional to the shared validator's violation
mass (:func:`repro.core.validate.total_violations`, Eqs. 4-8 + budget), so
the constrained problem (makespan <= S * OPT) is handled by the same
unconstrained search.  SGS output is feasible for Eqs. 4-8 by construction,
so for plain solves only the budget term can fire — but routing the penalty
through the validator means *any* constraint a decode path might miss (e.g.
a frozen-prefix instance transform) is priced by the same source of truth
the tests check.

Padded instances (mixed-shape scenario batches from
``repro.scenarios.batching``) decode unchanged: padded tasks schedule
instantly at zero duration, padded machines are never ``allowed`` so
neither SGS machine rules nor :func:`random_allowed_assign` can pick them,
and both the objectives and the validator mask padding out.

The paper's energy objective uses carbon as a tiny tie-break weight
(Section 3.2, "Optimizing for energy usage vs carbon emissions") — we use
1e-6 gCO2/kWh-scale weight, below the smallest energy quantum (one epoch of
the smallest server = 0.0625 kWh).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.decoder import sgs, timing_sweep
from repro.core.instance import PackedInstance
from repro.core.objectives import Objectives, energy, evaluate, utilization
from repro.core.validate import total_violations
from repro.kernels import ops

OBJECTIVES = ("makespan", "carbon", "energy")
VIOLATION_PENALTY = 1e5      # fitness units per unit of validator mass
ENERGY_CARBON_TIEBREAK = 1e-6


class ScheduleResult(NamedTuple):
    start: jnp.ndarray
    assign: jnp.ndarray
    makespan: jnp.ndarray
    energy: jnp.ndarray
    carbon: jnp.ndarray
    utilization: jnp.ndarray


@functools.partial(jax.jit,
                   static_argnames=("objective", "machine_rule", "sweeps"))
def decode_full(inst: PackedInstance, cum: jnp.ndarray, deadline: jnp.ndarray,
                prio: jnp.ndarray, assign: jnp.ndarray,
                objective: str = "carbon", machine_rule: str = "fixed",
                sweeps: int = 2,
                frozen: jnp.ndarray | None = None) -> ScheduleResult:
    """Candidate -> feasible schedule + objective values.

    ``frozen`` (optional bool [T]) marks already-executing tasks the timing
    sweep must not move (rolling replans); SGS placement of frozen tasks is
    pinned upstream via the instance transform + priority band (see
    :mod:`repro.core.solvers.rolling`).
    """
    dec = sgs(inst, prio, assign, machine_rule=machine_rule)
    start = dec.start
    if objective != "makespan" and sweeps > 0:
        start = timing_sweep(inst, start, dec.assign, cum, deadline, sweeps,
                             frozen=frozen)
    obj: Objectives = evaluate(inst, start, dec.assign, cum)
    return ScheduleResult(start, dec.assign, obj.makespan, obj.energy,
                          obj.carbon, utilization(inst, start, dec.assign))


def fitness_of(inst: PackedInstance, res: ScheduleResult,
               deadline: jnp.ndarray, objective: str) -> jnp.ndarray:
    """Objective value + validator-priced infeasibility penalty.

    The penalty term is the shared validator's scalar violation mass
    (arrival/precedence/overlap epochs, weighted disallowed assignments,
    epochs past ``deadline``) — zero iff the schedule is feasible, so the
    unconstrained search and the feasibility tests agree on what counts.
    """
    if objective == "makespan":
        return res.makespan.astype(jnp.float32)
    pen = VIOLATION_PENALTY * total_violations(
        inst, res.start, res.assign, deadline).astype(jnp.float32)
    if objective == "carbon":
        return res.carbon + pen
    if objective == "energy":
        return res.energy + ENERGY_CARBON_TIEBREAK * res.carbon + pen
    raise ValueError(f"unknown objective {objective!r}")


@functools.partial(jax.jit,
                   static_argnames=("objective", "machine_rule", "sweeps"))
def fitness_fn(inst: PackedInstance, cum: jnp.ndarray, deadline: jnp.ndarray,
               prio: jnp.ndarray, assign: jnp.ndarray, objective: str,
               machine_rule: str, sweeps: int,
               frozen: jnp.ndarray | None = None) -> jnp.ndarray:
    res = decode_full(inst, cum, deadline, prio, assign,
                      objective=objective, machine_rule=machine_rule,
                      sweeps=sweeps, frozen=frozen)
    return fitness_of(inst, res, deadline, objective)


def population_fitness(inst: PackedInstance, cum: jnp.ndarray,
                       deadline: jnp.ndarray, prio: jnp.ndarray,
                       assign: jnp.ndarray, objective: str,
                       machine_rule: str, sweeps: int,
                       frozen: jnp.ndarray | None = None,
                       use_kernels: bool | None = None) -> jnp.ndarray:
    """Fitness of a whole candidate population.  prio/assign [Pop, T] -> [Pop].

    The SA/GA hot loop: every proposal evaluation, init evaluation and
    migration re-evaluation goes through here.  Two paths, **bit-exact
    equal** (the contract ``tests/test_kernels.py`` property-tests):

    * jnp path — literally ``vmap(fitness_fn)``, the golden-locked
      reference;
    * kernel path (``use_kernels`` / ``REPRO_KERNELS``, resolved by
      :func:`repro.kernels.ops.kernels_enabled`) — decode (SGS + timing
      sweep) stays vmapped jnp, but the carbon trace integral runs once
      for the whole population in the Pallas kernel
      (:func:`repro.kernels.ops.population_carbon`) instead of Pop
      separate gather chains.

    The makespan objective never touches the trace, so it always takes
    the jnp path.  Meant to be called from inside the solvers' jitted
    scope with ``use_kernels`` static (the branch resolves at trace time;
    NB flipping ``REPRO_KERNELS`` after a solver cached its trace has no
    effect on that cache — pass the argument in tests).
    """
    if objective != "makespan" and ops.kernels_enabled(use_kernels):
        def _decode(p, a):
            dec = sgs(inst, p, a, machine_rule=machine_rule)
            start = dec.start
            if sweeps > 0:
                start = timing_sweep(inst, start, dec.assign, cum, deadline,
                                     sweeps, frozen=frozen)
            return start, dec.assign

        starts, assigns = jax.vmap(_decode)(prio, assign)
        carb = ops.population_carbon(inst, starts, assigns, cum)
        pen = VIOLATION_PENALTY * jax.vmap(
            lambda s, a: total_violations(inst, s, a, deadline)
        )(starts, assigns).astype(jnp.float32)
        if objective == "carbon":
            return carb + pen
        if objective == "energy":
            en = jax.vmap(lambda a: energy(inst, a))(assigns)
            return en + ENERGY_CARBON_TIEBREAK * carb + pen
        raise ValueError(f"unknown objective {objective!r}")
    return jax.vmap(lambda p, a: fitness_fn(
        inst, cum, deadline, p, a, objective, machine_rule, sweeps,
        frozen=frozen))(prio, assign)


def random_allowed_assign(key: jax.Array, inst: PackedInstance,
                          shape: tuple[int, ...] = ()) -> jnp.ndarray:
    """Uniform random machine among each task's allowed set."""
    g = jax.random.gumbel(key, shape + (inst.T, inst.M))
    return jnp.argmax(jnp.where(inst.allowed, g, -jnp.inf), axis=-1).astype(jnp.int32)
