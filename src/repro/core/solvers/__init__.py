from repro.core.solvers.common import ScheduleResult, fitness_fn, decode_full
from repro.core.solvers.annealing import solve_sa
from repro.core.solvers.genetic import solve_ga
from repro.core.solvers.bilevel import BilevelResult, solve_bilevel, solve_bilevel_batch
from repro.core.solvers.online import online_carbon_gated, online_greedy
from repro.core.solvers.online_jax import (OnlineSchedule, SweepResult,
                                           online_carbon_gated_jax,
                                           online_greedy_jax, policy_grid,
                                           simulate_online, sweep_policies)
from repro.core.solvers.rolling import (MPCConfig, MPCResult, solve_mpc,
                                        solve_mpc_batch)

__all__ = [
    "ScheduleResult", "fitness_fn", "decode_full", "solve_sa", "solve_ga",
    "BilevelResult", "solve_bilevel", "solve_bilevel_batch",
    "online_carbon_gated", "online_greedy",
    "OnlineSchedule", "SweepResult", "online_carbon_gated_jax",
    "online_greedy_jax", "policy_grid", "simulate_online", "sweep_policies",
    "MPCConfig", "MPCResult", "solve_mpc", "solve_mpc_batch",
]
