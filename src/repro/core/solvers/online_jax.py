"""Batched online dispatch as one shape-static XLA program.

The paper's §4 poses *online* carbon-aware scheduling as future work: jobs
are seen only at arrival (Eq. 4's release dates become information
constraints), yet schedules must still satisfy the Appendix A feasibility
system — precedence (Eq. 5), machine validity (Eq. 6) and no-overlap
(Eq. 8) — while a stretch budget caps makespan the way the bi-level
``S x OPT`` deadline does offline.  :mod:`repro.core.solvers.online` answers
that question with a sequential numpy event loop: the *reference oracle*,
one instance at a time.

This module is the same dispatch semantics as an epoch-driven
``lax.scan``: one scan step per epoch updates (ready set, machine free
times, carbon gate) for *all* tasks at once, so the whole simulation — and
therefore a full sweep of batched instances x gate policies — runs as a
single compiled program with no host round-trips.  It ``vmap``s along two
axes:

* **instances** — stacked :class:`~repro.core.instance.PackedInstance`
  batches from :func:`~repro.core.instance.stack_packed` (or, for
  mixed-shape scenario batches, :func:`repro.scenarios.batching.pack_aligned`
  — task *and* machine padding are inert per the PackedInstance padding
  contract: every machine choice below masks on ``allowed``, so padded
  columns are unselectable and padded vs. unpadded dispatch is bit-exact on
  the real tasks), each with its own carbon-intensity forecast window;
* **policies** — a flat grid of gate knobs ``(theta, window, stretch)``
  (see :func:`policy_grid`), the online analogue of the paper's S-sweep.

Exact-match construction (property-tested against the numpy oracle):

* the downstream-critical-path gate is a reverse ``fori_loop`` over the
  topological task order, mirroring ``upward_rank`` in
  :mod:`repro.core.decoder`;
* the ``theta``-quantile gate threshold is precomputed for every epoch with
  a masked sort + the same linear interpolation ``np.quantile`` uses
  (including the truncated window at the end of the forecast);
* within an epoch, tasks are dispatched in topological index order by an
  inner ``scan`` — scheduling a task can only *remove* options inside the
  same epoch (machines become busy, never free; predecessors finish at
  ``t + dur > t``), so a single ordered pass reproduces the oracle's
  fixpoint loop.

Caveats for bit-exact parity with the numpy loop: the greedy baseline must
complete within ``n_epochs - 1`` epochs (check ``OnlineSchedule.scheduled``)
and ``stretch`` should be a binary-exact float (1.25, 1.5, 2.0, ...) so
``int(stretch * makespan)`` truncates identically in float32.

Feasibility of every emitted schedule is checked by the shared validator,
:mod:`repro.core.validate` (Eqs. 4-8 + stretch budget).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.instance import PackedInstance
from repro.core.objectives import makespan
from repro.obs.trace import traced_xla_call

BIG = jnp.int32(1 << 20)

# Tie-break slack on the quantile gate: intensity must exceed the threshold
# by more than this to count as dirty (guards the == case against float
# noise; shared by the hard gate and the soft relaxation in repro.learn).
GATE_EPS = 1e-9


class OnlineSchedule(NamedTuple):
    start: jnp.ndarray      # int32 [T]
    assign: jnp.ndarray     # int32 [T]
    scheduled: jnp.ndarray  # bool  [T] — dispatched within the horizon


class DispatchState(NamedTuple):
    """Progress of the epoch-driven dispatcher on one instance.

    The carry of :func:`simulate_online`'s epoch loop, made first-class so a
    *streaming* caller (:mod:`repro.stream`) can hold one state per lane and
    advance the whole pool one epoch at a time with :func:`dispatch_epoch` —
    inserting and evicting jobs between epochs the way the serve engine
    inserts and evicts decode lanes between token steps.

    The task-side fields and the machine axis split apart as
    (:class:`LaneState`, ``mfree``) for callers whose machines are *not* owned
    by one instance — the shared-fleet streaming pool threads one global
    ``mfree`` through every lane's :func:`dispatch_epoch_shared` while each
    lane keeps its own :class:`LaneState`.
    """

    scheduled: jnp.ndarray  # bool  [T] — placed on a machine
    comp: jnp.ndarray       # int32 [T] — completion epoch (where scheduled)
    mfree: jnp.ndarray      # int32 [M] — next epoch each machine is free
    start: jnp.ndarray      # int32 [T]
    assign: jnp.ndarray     # int32 [T]

    def schedule(self) -> OnlineSchedule:
        return OnlineSchedule(self.start, self.assign, self.scheduled)

    def split(self) -> tuple["LaneState", jnp.ndarray]:
        """(task-side state, machine free-times) — the shared-fleet view."""
        return LaneState(self.scheduled, self.comp, self.start,
                         self.assign), self.mfree


class LaneState(NamedTuple):
    """Task-side half of :class:`DispatchState` — no machine axis.

    What one streaming *lane* owns when the fleet is shared: its tasks'
    placement progress.  Machine free-times live outside (pool-global for a
    shared fleet, per-lane ``[L, M]`` for partitioned lanes) and are threaded
    through :func:`dispatch_epoch_shared` explicitly.
    """

    scheduled: jnp.ndarray  # bool  [T]
    comp: jnp.ndarray       # int32 [T]
    start: jnp.ndarray      # int32 [T]
    assign: jnp.ndarray     # int32 [T]

    def merge(self, mfree: jnp.ndarray) -> DispatchState:
        return DispatchState(self.scheduled, self.comp, mfree,
                             self.start, self.assign)


def init_lane_state(T: int) -> LaneState:
    """All-zeros task-side state (nothing scheduled)."""
    return LaneState(jnp.zeros((T,), bool), jnp.zeros((T,), jnp.int32),
                     jnp.zeros((T,), jnp.int32), jnp.zeros((T,), jnp.int32))


def init_dispatch_state(T: int, M: int) -> DispatchState:
    """The all-zeros state every simulation starts from (and the inert state
    a padding lane carries: nothing scheduled, every machine free)."""
    return init_lane_state(T).merge(jnp.zeros((M,), jnp.int32))


class SweepResult(NamedTuple):
    """Output of :func:`sweep_policies` (leading axes: B instances, P policies)."""

    greedy: OnlineSchedule         # [B, ...] carbon-agnostic baseline
    gated: OnlineSchedule          # [B, P, ...] one per policy
    greedy_makespan: jnp.ndarray   # int32 [B]
    budget: jnp.ndarray            # int32 [B, P] = int(stretch * greedy_makespan)


@jax.jit
def downstream_critical_path(inst: PackedInstance) -> jnp.ndarray:
    """Min-duration downstream critical path per task, incl. itself.

    The carbon gate lets a ready task wait only while ``t + 1 + cp[t]`` still
    fits the stretch budget, so waiting can never make the budget
    unreachable.  Tasks are topologically indexed, so a reverse ``fori_loop``
    suffices (mirrors ``upward_rank`` in :mod:`repro.core.decoder`).
    """
    T = inst.T
    dmin = jnp.min(jnp.where(inst.allowed, inst.dur, BIG), axis=1)
    succ = inst.pred.T & inst.task_mask[None, :]   # succ[t, v]: t -> v edge

    def body(i, cp):
        t = T - 1 - i
        best = jnp.max(jnp.where(succ[t], cp, 0))
        return cp.at[t].set(jnp.where(inst.task_mask[t], dmin[t] + best, 0))

    return jax.lax.fori_loop(0, T, body, jnp.zeros((T,), jnp.int32))


def sorted_windows(intensity: jnp.ndarray, window: jnp.ndarray,
                   max_window: int):
    """Per-epoch forecast windows, sorted — the expensive half of the gate.

    Invalid slots (past ``window`` or past the forecast end) become ``+inf``
    and sort to the back; the valid count ``n[t]`` tells the quantile how far
    to interpolate.  Depends on ``window`` but *not* ``theta``, so sweeps
    sort once per (instance, window) and reuse across thetas and stretches —
    and the gate-policy *learner* (:mod:`repro.learn`) reuses one sort across
    every gradient step.
    """
    E = intensity.shape[0]
    off = jnp.arange(max_window)
    idx = jnp.arange(E)[:, None] + off[None, :]               # [E, W]
    valid = (off[None, :] < window) & (idx < E)
    vals = jnp.where(valid, intensity[jnp.clip(idx, 0, E - 1)], jnp.inf)
    return jnp.sort(vals, axis=1), valid.sum(1)


def quantile_threshold(sv: jnp.ndarray, n: jnp.ndarray,
                       theta: jnp.ndarray) -> jnp.ndarray:
    """Interpolated ``theta``-quantile of each sorted window -> thresh [E].

    Replicates ``np.quantile``'s linear interpolation.  ``theta`` may be a
    scalar or a per-epoch ``[E]`` vector (forecast-conditioned gates); either
    way the map is piecewise-linear in ``theta``, so ``jax.grad`` through it
    is exact almost everywhere — the property :mod:`repro.learn` builds on.
    """
    vi = theta.astype(jnp.float32) * (n - 1).astype(jnp.float32)
    lo = jnp.floor(vi)
    gamma = vi - lo
    lo_i = lo.astype(jnp.int32)
    hi_i = jnp.minimum(lo_i + 1, n - 1)
    a = jnp.take_along_axis(sv, lo_i[:, None], axis=1)[:, 0]
    b = jnp.take_along_axis(sv, hi_i[:, None], axis=1)[:, 0]
    diff = b - a
    # np.quantile's _lerp switches formula at gamma >= 0.5 for accuracy.
    return jnp.where(gamma >= 0.5, b - diff * (1.0 - gamma),
                     a + diff * gamma)


def _quantile_dirty(intensity: jnp.ndarray, sv: jnp.ndarray, n: jnp.ndarray,
                    theta: jnp.ndarray) -> jnp.ndarray:
    """Interpolated ``theta``-quantile over the sorted windows -> dirty mask."""
    return intensity > quantile_threshold(sv, n, theta) + GATE_EPS


@functools.partial(jax.jit, static_argnames=("max_window", "use_kernels"))
def dirty_mask(intensity: jnp.ndarray, theta: jnp.ndarray,
               window: jnp.ndarray, max_window: int,
               use_kernels: bool | None = None) -> jnp.ndarray:
    """``dirty[t] = intensity[t] > quantile(intensity[t:t+window], theta)``.

    Replicates ``np.quantile``'s linear interpolation — including the
    truncated window near the end of the forecast — via a masked sort.
    ``theta`` and ``window`` are traced, so a policy grid vmaps over them;
    only ``max_window`` (the sort width) is static.

    ``use_kernels`` (or ``REPRO_KERNELS``, resolved by
    :func:`repro.kernels.ops.kernels_enabled`) swaps the masked sort for
    the fused Pallas pass :func:`repro.kernels.ops.gate_threshold` —
    **bit-exact equal** thresholds, so the mask is identical either way.
    The ``GATE_EPS`` comparison stays here on both paths.  (The sweep
    path keeps the jnp sort: its per-(instance, window) sort is *reused*
    across thetas/stretches, a different trade.)
    """
    from repro.kernels import ops  # deferred: keep core importable alone
    if ops.kernels_enabled(use_kernels):
        thr = ops.gate_threshold(intensity, theta, window, max_window)
        return intensity > thr + GATE_EPS
    sv, n = sorted_windows(intensity, window, max_window)
    return _quantile_dirty(intensity, sv, n, theta)


def dispatch_epoch_shared(inst: PackedInstance, lane: LaneState,
                          mfree: jnp.ndarray, dirty_t: jnp.ndarray,
                          budget: jnp.ndarray, t: jnp.ndarray,
                          machine_rule: str = "earliest_finish",
                          cp: jnp.ndarray | None = None,
                          preds: jnp.ndarray | None = None
                          ) -> tuple[LaneState, jnp.ndarray]:
    """One epoch of the online dispatcher with an *external* machine axis.

    The body of :func:`dispatch_epoch` with the machine free-times threaded
    in and out explicitly instead of riding inside the state: placements made
    here consume ``mfree`` that the *next* caller of this function sees.
    That is the shared-fleet streaming contract — the pool tick ``lax.scan``s
    this over lanes in priority order, so an earlier lane's placements shrink
    the machine options of later lanes *within the same epoch*.  With a
    per-lane ``mfree`` it degenerates to the partitioned :func:`dispatch_epoch`
    (which delegates here), keeping one epoch body for both fleet modes.

    ``cp`` (:func:`downstream_critical_path`) and ``preds`` (the masked
    predecessor matrix) are recomputed from ``inst`` when not supplied;
    loop-callers pass them in to hoist the computation out of the loop.

    At most ``M`` tasks can be placed per epoch (each placement occupies one
    machine; machines never free mid-epoch since durations are >= 1), and
    placements only *shrink* later tasks' options — so M rounds of "place
    the lowest-indexed eligible task" reproduce the oracle's index-order
    pass with M instead of T sequential steps.
    """
    if machine_rule not in ("earliest_finish", "min_energy"):
        raise ValueError(f"unknown machine_rule {machine_rule!r}")
    if cp is None:
        cp = downstream_critical_path(inst)
    if preds is None:
        preds = inst.pred & inst.task_mask[None, :]
    # Epoch-invariant parts of eligibility: a predecessor placed *this*
    # epoch completes at t + dur > t, so it blocks successors exactly
    # like an unscheduled one — blocked needn't be recomputed per round.
    blocked = jnp.any(preds & (~lane.scheduled | (lane.comp > t))[None, :],
                      axis=1)
    waiting = dirty_t & (t + 1 + cp <= budget)
    base = (inst.task_mask & (inst.arrival <= t) & ~blocked & ~waiting)

    def round_body(_, carry):
        scheduled, comp, mfree, start, assign = carry
        free = inst.allowed & (mfree <= t)[None, :]            # [T, M]
        elig = base & ~scheduled & jnp.any(free, axis=1)
        tk = jnp.argmax(elig).astype(jnp.int32)  # lowest eligible index
        place = elig[tk]
        durs = inst.dur[tk]
        cost = inst.power * durs.astype(jnp.float32)
        if machine_rule == "earliest_finish":
            dmin = jnp.min(jnp.where(free[tk], durs, BIG))
            cand = free[tk] & (durs == dmin)
            m = jnp.argmin(jnp.where(cand, cost, jnp.inf)).astype(jnp.int32)
        else:  # min_energy
            cmin = jnp.min(jnp.where(free[tk], cost, jnp.inf))
            cand = free[tk] & (cost == cmin)
            m = jnp.argmin(jnp.where(cand, durs, BIG)).astype(jnp.int32)
        c = t + durs[m]
        return (scheduled.at[tk].set(scheduled[tk] | place),
                comp.at[tk].set(jnp.where(place, c, comp[tk])),
                mfree.at[m].set(jnp.where(place, c, mfree[m])),
                start.at[tk].set(jnp.where(place, t, start[tk])),
                assign.at[tk].set(jnp.where(place, m, assign[tk])))

    scheduled, comp, mfree, start, assign = jax.lax.fori_loop(
        0, inst.M, round_body,
        (lane.scheduled, lane.comp, mfree, lane.start, lane.assign))
    return LaneState(scheduled, comp, start, assign), mfree


def dispatch_epoch(inst: PackedInstance, state: DispatchState,
                   dirty_t: jnp.ndarray, budget: jnp.ndarray, t: jnp.ndarray,
                   machine_rule: str = "earliest_finish",
                   cp: jnp.ndarray | None = None,
                   preds: jnp.ndarray | None = None) -> DispatchState:
    """One epoch of the online dispatcher — the pool-step entry point.

    Advances ``state`` across epoch ``t``: every task that has arrived, has
    all predecessors complete, passes the gate (``dirty_t`` False, or waiting
    would break ``budget``) and finds a free allowed machine is placed.
    Applying this for ``t = 0 .. n_epochs - 2`` from
    :func:`init_dispatch_state` reproduces :func:`simulate_online`
    **bit-exactly** (it *is* that loop's body, hoisted) — which is how the
    streaming engine (:mod:`repro.stream`) runs one jitted step over a whole
    pool of lanes per tick while inserting/evicting jobs between ticks, and
    why its closed-batch dispatch matches the batched path.

    The epoch body itself lives in :func:`dispatch_epoch_shared`; this
    wrapper owns the machines (``state.mfree`` is this instance's fleet).
    Streaming pools that share one fleet across lanes call the shared form
    directly with a pool-global ``mfree``.
    """
    lane, mfree = state.split()
    lane, mfree = dispatch_epoch_shared(inst, lane, mfree, dirty_t, budget,
                                        t, machine_rule=machine_rule, cp=cp,
                                        preds=preds)
    return lane.merge(mfree)


@functools.partial(jax.jit, static_argnames=("n_epochs", "machine_rule"))
def simulate_online(inst: PackedInstance, dirty: jnp.ndarray,
                    budget: jnp.ndarray, n_epochs: int,
                    machine_rule: str = "earliest_finish",
                    state0: DispatchState | None = None) -> OnlineSchedule:
    """Run the event-driven dispatcher for epochs ``0 .. n_epochs - 2``.

    ``dirty[t]`` gates ready tasks at epoch ``t`` (all-False == greedy);
    ``budget`` is the stretch cap on ``t + 1 + critical_path`` while waiting.
    Semantics match ``online._simulate`` exactly: a task is dispatched at the
    first epoch where it has arrived, its predecessors have completed, the
    gate is open (or waiting would break the budget) and an allowed machine
    is free — on the free machine minimizing, lexicographically,
    ``(duration, power * duration, index)`` under ``"earliest_finish"`` or
    ``(power * duration, duration, index)`` under ``"min_energy"`` (the
    ROADMAP's min-energy dispatch; both keys are exact in float32 for the
    menu's quarter-kW powers, so numpy/JAX parity survives the dtype gap).

    ``state0`` (default: :func:`init_dispatch_state`, an idle fleet) seeds
    the simulation — pass a state with non-zero ``mfree`` to dispatch onto a
    *warm* fleet whose machines are already busy until given epochs.  The
    shared-fleet streaming admission solves its greedy stretch baseline this
    way, so deadlines reflect real contention rather than an empty fleet.

    The loop body is :func:`dispatch_epoch`; streaming callers apply it one
    epoch at a time over a lane pool instead.
    """
    if machine_rule not in ("earliest_finish", "min_energy"):
        raise ValueError(f"unknown machine_rule {machine_rule!r}")
    cp = downstream_critical_path(inst)
    preds = inst.pred & inst.task_mask[None, :]
    if state0 is None:
        state0 = init_dispatch_state(inst.T, inst.M)

    # Epochs past the last placement are no-ops in the oracle, so a
    # while_loop that exits once every real task is scheduled (vmap masks
    # finished lanes) visits the same epochs 0 .. n_epochs - 2 semantics-wise
    # while skipping the dead tail — the hot-path win for batched sweeps.
    def cond(carry):
        t, state = carry
        return (t < n_epochs - 1) & ~jnp.all(state.scheduled | ~inst.task_mask)

    def body(carry):
        t, state = carry
        return t + 1, dispatch_epoch(inst, state, dirty[t], budget, t,
                                     machine_rule=machine_rule, cp=cp,
                                     preds=preds)

    _, state = jax.lax.while_loop(cond, body, (jnp.int32(0), state0))
    return state.schedule()


def online_greedy_jax(inst: PackedInstance, n_epochs: int,
                      machine_rule: str = "earliest_finish") -> OnlineSchedule:
    """Carbon-agnostic baseline (gate always open) over a static horizon."""
    return simulate_online(inst, jnp.zeros((n_epochs,), bool), jnp.int32(0),
                           n_epochs=n_epochs, machine_rule=machine_rule)


def online_carbon_gated_jax(inst: PackedInstance, intensity,
                            theta: float = 0.5, window: int = 96,
                            stretch: float = 1.5,
                            machine_rule: str = "earliest_finish",
                            soft: bool = False, temp: float = 0.05,
                            use_kernels: bool | None = None,
                            state0: DispatchState | None = None):
    """Single-instance gated dispatch (mirrors ``online_carbon_gated``).

    Runs the greedy baseline first to set ``budget = int(stretch * makespan)``
    (same ``machine_rule``, so the budget is relative to the rule's own
    baseline), then the gated simulation over the forecast horizon.

    ``soft=True`` returns the differentiable relaxation instead — a
    :class:`repro.learn.relax.SoftDispatch` whose ``hard`` field is exactly
    this function's ``soft=False`` schedule (same threshold kernel, same
    simulator) and whose soft fields carry ``jax.grad``-able start times at
    temperature ``temp``.  The relaxation contract (temp -> 0 == hard gate)
    lives in :mod:`repro.learn`.

    ``use_kernels`` forwards to :func:`dirty_mask` (Pallas gate threshold;
    bit-exact equal mask, identical schedule).

    ``state0`` dispatches onto a warm fleet (see :func:`simulate_online`):
    both the greedy baseline and the gated run start from it, so the stretch
    budget is relative to what an uncontended greedy could do *on that
    fleet* — the shared-fleet admission view.  Not supported with ``soft``.
    """
    intensity = jnp.asarray(intensity)
    n_epochs = int(intensity.shape[0])
    if soft:
        if state0 is not None:
            raise ValueError("state0 is not supported on the soft path")
        from repro.learn.relax import soft_dispatch   # local: avoids cycle
        return soft_dispatch(inst, intensity, jnp.float32(theta),
                             jnp.int32(window), jnp.float32(stretch),
                             max_window=int(window), temp=temp,
                             machine_rule=machine_rule)
    g = simulate_online(inst, jnp.zeros((n_epochs,), bool), jnp.int32(0),
                        n_epochs=n_epochs, machine_rule=machine_rule,
                        state0=state0)
    ms0 = makespan(inst, g.start, g.assign)
    budget = (jnp.float32(stretch) * ms0.astype(jnp.float32)).astype(jnp.int32)
    dirty = dirty_mask(intensity, jnp.float32(theta), jnp.int32(window),
                       max_window=int(window), use_kernels=use_kernels)
    return simulate_online(inst, dirty, budget, n_epochs=n_epochs,
                           machine_rule=machine_rule, state0=state0)


def policy_grid(thetas: Sequence[float], windows: Sequence[int],
                stretches: Sequence[float]):
    """Outer product of gate knobs, flattened to three aligned [P] arrays."""
    th, wi, sx = np.meshgrid(np.asarray(thetas, np.float32),
                             np.asarray(windows, np.int32),
                             np.asarray(stretches, np.float32),
                             indexing="ij")
    return (jnp.asarray(th.ravel()), jnp.asarray(wi.ravel()),
            jnp.asarray(sx.ravel()))


@functools.partial(jax.jit,
                   static_argnames=("n_epochs", "max_window", "machine_rule"))
def _sweep(batch: PackedInstance, intensity: jnp.ndarray,
           thetas: jnp.ndarray, windows: jnp.ndarray, stretches: jnp.ndarray,
           n_epochs: int, max_window: int,
           machine_rule: str = "earliest_finish") -> SweepResult:
    def per_instance(inst, inten):
        g = simulate_online(inst, jnp.zeros((n_epochs,), bool), jnp.int32(0),
                            n_epochs=n_epochs, machine_rule=machine_rule)
        ms0 = makespan(inst, g.start, g.assign)

        # window is the expensive axis (the masked sort); keep it outermost
        # so thetas and stretches reuse each sort.
        def per_window(wi):
            sv, n = sorted_windows(inten, wi, max_window)

            def per_theta(th):
                dirty = _quantile_dirty(inten, sv, n, th)

                def per_stretch(sx):
                    budget = (sx * ms0.astype(jnp.float32)).astype(jnp.int32)
                    return simulate_online(inst, dirty, budget,
                                           n_epochs=n_epochs,
                                           machine_rule=machine_rule), budget

                return jax.vmap(per_stretch)(stretches)

            return jax.vmap(per_theta)(thetas)

        gated, budgets = jax.vmap(per_window)(windows)   # axes [W, Th, S, ...]

        def flat(x):  # -> theta-major [P, ...], matching policy_grid order
            x = jnp.moveaxis(x, 1, 0)                    # [Th, W, S, ...]
            return x.reshape((-1,) + x.shape[3:])

        return g, jax.tree.map(flat, gated), ms0, flat(budgets)

    g, gated, ms0, budgets = jax.vmap(per_instance)(batch, intensity)
    return SweepResult(g, gated, ms0, budgets)


def sweep_policies(batch: PackedInstance, intensity, thetas, windows,
                   stretches,
                   machine_rule: str = "earliest_finish") -> SweepResult:
    """Batched instances x policy grid, one XLA program.

    ``batch``: stacked instances [B, ...]; ``intensity``: per-instance
    forecast [B, E]; ``thetas``/``windows``/``stretches``: the three *axes*
    of the gate-policy grid.  Gated results carry a flattened policy axis of
    size ``P = len(thetas) * len(windows) * len(stretches)`` in the same
    theta-major order :func:`policy_grid` enumerates, so
    ``policy_grid(thetas, windows, stretches)`` labels the P rows.  The
    greedy baseline runs once per instance and every gated run reuses its
    makespan for the budget; window-sorts are shared across thetas/stretches.
    """
    intensity = jnp.asarray(intensity)
    windows = np.asarray(windows, np.int32)
    # traced_xla_call: with REPRO_TRACE unset this IS a direct _sweep call;
    # when tracing, the host records the call's wall-clock span (compile vs
    # warm) around the jitted program — never inside it (repro.obs).
    return traced_xla_call(
        "online_jax.sweep", _sweep, batch, intensity,
        jnp.asarray(thetas, jnp.float32), jnp.asarray(windows),
        jnp.asarray(stretches, jnp.float32),
        n_epochs=int(intensity.shape[-1]),
        max_window=int(windows.max()), machine_rule=machine_rule)
