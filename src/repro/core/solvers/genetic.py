"""Genetic-algorithm solver over SGS encodings (ablation partner to SA).

Continuous priority vectors make crossover trivial (uniform gene mix keeps
any blend decodable — SGS repairs everything into a feasible schedule), so
no precedence-repair operator is needed.  Tournament selection + elitism.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.decoder import upward_rank
from repro.core.instance import PackedInstance
from repro.core.solvers import common
from repro.core.solvers.annealing import SolveOut


class GAConfig(NamedTuple):
    pop: int = 128
    gens: int = 120
    sweeps: int = 2
    sigma: float = 3.0
    tourn: int = 4           # tournament size
    p_cross: float = 0.7
    p_mut_prio: float = 0.25
    p_mut_mach: float = 0.25
    elite: int = 4


@functools.partial(jax.jit,
                   static_argnames=("objective", "machine_rule", "cfg",
                                    "use_kernels"))
def solve_ga(inst: PackedInstance, cum: jnp.ndarray, deadline: jnp.ndarray,
             key: jax.Array, objective: str = "carbon",
             machine_rule: str = "fixed", cfg: GAConfig = GAConfig(),
             prio_init: jnp.ndarray | None = None,
             assign_init: jnp.ndarray | None = None,
             frozen: jnp.ndarray | None = None,
             use_kernels: bool | None = None) -> SolveOut:
    """``use_kernels`` selects the Pallas fitness path (bit-exact equal to
    the jnp path); ``None`` defers to ``REPRO_KERNELS`` / the backend
    default — see :func:`repro.core.solvers.common.population_fitness`."""
    T = inst.T
    # Frozen tasks (rolling replans) keep their exact priorities: init noise
    # and mutations are masked, and crossover mixes identical frozen genes.
    free = (jnp.ones((T,), bool) if frozen is None else ~frozen)
    sweeps = 0 if objective == "makespan" else cfg.sweeps
    fit_v = lambda p, a: common.population_fitness(  # noqa: E731
        inst, cum, deadline, p, a, objective, machine_rule, sweeps,
        frozen=frozen, use_kernels=use_kernels)

    k_init, k_assign, k_run = jax.random.split(key, 3)
    base = upward_rank(inst) if prio_init is None else prio_init
    prio = base[None, :] + cfg.sigma * jax.random.normal(
        k_init, (cfg.pop, T)) * free
    prio = prio.at[0].set(base)
    if assign_init is None:
        assign = common.random_allowed_assign(k_assign, inst, (cfg.pop,))
    else:
        assign = jnp.broadcast_to(assign_init, (cfg.pop, T)).astype(jnp.int32)
    fit = fit_v(prio, assign)

    def gen(carry, _):
        key, prio, assign, fit = carry
        key, k1, k2, k3, k4, k5, k6, k7 = jax.random.split(key, 8)

        # Tournament selection of two parent pools.
        idx = jax.random.randint(k1, (2, cfg.pop, cfg.tourn), 0, cfg.pop)
        tf = fit[idx]                                    # [2, pop, tourn]
        winners = jnp.take_along_axis(
            idx, jnp.argmin(tf, axis=-1)[..., None], -1)[..., 0]  # [2, pop]
        pa, pb = winners

        # Uniform crossover on priorities and machines.
        do_c = jax.random.bernoulli(k2, cfg.p_cross, (cfg.pop, 1))
        gene = jax.random.bernoulli(k3, 0.5, (cfg.pop, T))
        child_p = jnp.where(gene & do_c, prio[pb], prio[pa])
        child_a = jnp.where(gene & do_c, assign[pb], assign[pa])

        # Mutation.
        mut_p = jax.random.bernoulli(k4, cfg.p_mut_prio, (cfg.pop, 1)) & \
            jax.random.bernoulli(k5, 2.0 / T, (cfg.pop, T)) & free
        child_p = child_p + mut_p * cfg.sigma * jax.random.normal(
            k5, (cfg.pop, T))
        mut_m = jax.random.bernoulli(k6, cfg.p_mut_mach, (cfg.pop, 1)) & \
            (jax.random.randint(k7, (cfg.pop, 1), 0, T)
             == jnp.arange(T)[None, :])
        rnd_m = common.random_allowed_assign(k7, inst, (cfg.pop,))
        child_a = jnp.where(mut_m, rnd_m, child_a)

        child_f = fit_v(child_p, child_a)

        # Elitism: keep the cfg.elite best of the old population.
        order = jnp.argsort(fit)
        elite_slots = jnp.arange(cfg.pop) < cfg.elite
        new_p = jnp.where(elite_slots[:, None], prio[order], child_p)
        new_a = jnp.where(elite_slots[:, None], assign[order], child_a)
        new_f = jnp.where(elite_slots, fit[order], child_f)
        return (key, new_p, new_a, new_f), None

    (_, prio, assign, fit), _ = jax.lax.scan(
        gen, (k_run, prio, assign, fit), None, length=cfg.gens)
    i = jnp.argmin(fit)
    return SolveOut(prio[i], assign[i], fit[i])
