"""Schedule-generation-scheme (SGS) decoders in JAX.

The paper solves the FJSP with CP-SAT.  On a TPU we instead search over a
*decodable encoding*: a candidate is a priority vector ``prio[T]`` (which
task to place next) plus, optionally, an explicit machine assignment
``assign[T]``.  :func:`sgs` turns a candidate into a feasible schedule with a
``lax.scan`` over tasks; :func:`timing_sweep` then shifts tasks later inside
their slack windows to chase low-carbon periods (the carbon-greedy timing
pass).  Both are shape-static and vmap over populations and batched
instances — that data-parallel search is the TPU-native replacement for the
paper's sequential CP solver (DESIGN.md §3).

Feasibility invariants (property-tested against the shared validator,
:mod:`repro.core.validate`): every decoded schedule respects arrivals
(Eq. 4), DAG precedence (Eq. 5), machine validity (Eq. 6) and per-machine
no-overlap (Eq. 8) — by construction; :func:`timing_sweep` additionally
never exceeds its deadline and never increases carbon.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.instance import PackedInstance
from repro.core.objectives import task_durations

BIG = jnp.int32(1 << 28)

MACHINE_RULES = ("fixed", "earliest_finish", "min_energy")


class DecodedSchedule(NamedTuple):
    start: jnp.ndarray    # int32 [T]
    assign: jnp.ndarray   # int32 [T]
    seq_key: jnp.ndarray  # int32 [T] placement order (for timing sweeps)


@functools.partial(jax.jit, static_argnames=("machine_rule",))
def sgs(inst: PackedInstance, prio: jnp.ndarray,
        assign: jnp.ndarray | None = None,
        machine_rule: str = "earliest_finish") -> DecodedSchedule:
    """Serial SGS: place the highest-priority *ready* task at its earliest
    feasible start, T times.

    machine_rule:
      * ``"fixed"``            — use ``assign`` verbatim (it must be allowed).
      * ``"earliest_finish"``  — greedy: machine minimizing completion time.
      * ``"min_energy"``       — greedy: machine minimizing P_m * p_{t,m},
                                  finish time as tie-break.

    For any feasible schedule S there is a priority order (S's start order)
    under which earliest-start SGS with S's assignment starts every task no
    later than S does — so the encoding's image contains a makespan-optimal
    schedule (see DESIGN.md §3).
    """
    if machine_rule not in MACHINE_RULES:
        raise ValueError(f"unknown machine_rule {machine_rule!r}")
    T, M = inst.T, inst.M
    real = inst.task_mask
    pred_real = inst.pred & real[None, :]
    if assign is None:
        assign = jnp.zeros((T,), jnp.int32)

    def body(state, i):
        scheduled, comp, mfree, start, aout, seq = state
        pending = jnp.any(pred_real & ~scheduled[None, :], axis=1)
        ready = ~scheduled & ~pending
        t = jnp.argmax(jnp.where(ready, prio, -jnp.inf))
        pred_comp = jnp.max(jnp.where(pred_real[t], comp, 0))
        base = jnp.maximum(inst.arrival[t], pred_comp)
        est_m = jnp.maximum(base, mfree)               # [M]
        dur_t = inst.dur[t]                            # [M]
        fin_m = est_m + dur_t
        ok = inst.allowed[t]
        if machine_rule == "fixed":
            m = assign[t]
        elif machine_rule == "earliest_finish":
            m = jnp.argmin(jnp.where(ok, fin_m, BIG)).astype(jnp.int32)
        else:  # min_energy
            cost = inst.power * dur_t.astype(jnp.float32)
            key = jnp.where(ok, cost * 65536.0 + fin_m.astype(jnp.float32),
                            jnp.float32(3e38))
            m = jnp.argmin(key).astype(jnp.int32)
        s = est_m[m]
        c = s + dur_t[m]
        return (scheduled.at[t].set(True),
                comp.at[t].set(c),
                mfree.at[m].set(jnp.maximum(mfree[m], c)),
                start.at[t].set(s),
                aout.at[t].set(m),
                seq.at[t].set(i)), None

    init = (jnp.zeros((T,), bool), jnp.zeros((T,), jnp.int32),
            jnp.zeros((M,), jnp.int32), jnp.zeros((T,), jnp.int32),
            jnp.zeros((T,), jnp.int32), jnp.zeros((T,), jnp.int32))
    (_, _, _, start, aout, seq), _ = jax.lax.scan(
        body, init, jnp.arange(T, dtype=jnp.int32))
    return DecodedSchedule(start, aout, seq)


@functools.partial(jax.jit, static_argnames=("sweeps",))
def timing_sweep(inst: PackedInstance, start: jnp.ndarray,
                 assign: jnp.ndarray, cum: jnp.ndarray,
                 deadline: jnp.ndarray, sweeps: int = 2,
                 frozen: jnp.ndarray | None = None) -> jnp.ndarray:
    """Carbon-greedy timing pass.

    Keeps sequencing (per-machine order and DAG order) fixed and pushes each
    task *later* into its slack window to the start minimizing its own
    emissions ``cum[s+d] - cum[s]``, never exceeding ``deadline``.  Processing
    tasks in descending start order makes each task's successors (DAG and
    machine) final before the task itself is placed, so a sweep preserves
    feasibility; extra sweeps exploit slack opened by earlier sweeps.

    ``frozen`` (optional bool [T]) pins tasks in place: a frozen task is
    never moved, but still constrains its neighbours — the rolling replanner
    (:mod:`repro.core.solvers.rolling`) freezes tasks that have already
    started executing, which cannot be shifted retroactively.

    With fixed sequences this is coordinate descent on the separable
    start-time-cost problem — cheap, monotone (never increases carbon), and
    exact in the common case of a task whose window covers a clean valley.
    """
    T = inst.T
    H = cum.shape[0] - 1
    d = task_durations(inst, assign)
    real = inst.task_mask
    sweepable = real if frozen is None else real & ~frozen
    svec = jnp.arange(H + 1, dtype=jnp.int32)
    # cost_at[t, s] lookup pieces: delta(s; d) = cum[s+d] - cum[s].
    same_m = (assign[:, None] == assign[None, :]) & real[None, :]
    succ = inst.pred.T & real[None, :]          # succ[t, v]: t -> v edge

    def one_sweep(start):
        # Freeze the sequence key for this sweep: (start, idx) descending.
        key = start * jnp.int32(T) + jnp.arange(T, dtype=jnp.int32)
        order = jnp.argsort(-jnp.where(real, key, -BIG))  # pads last

        def body(start_cur, t):
            dt = d[t]
            succ_cap = jnp.min(jnp.where(succ[t], start_cur, BIG))
            after = same_m[t] & (key > key[t])
            mnext_cap = jnp.min(jnp.where(after, start_cur, BIG))
            hi = jnp.minimum(jnp.minimum(succ_cap, mnext_cap),
                             deadline.astype(jnp.int32)) - dt
            lo = start_cur[t]
            cost = cum[jnp.minimum(svec + dt, H)] - cum[svec]
            cost = jnp.where((svec >= lo) & (svec <= hi), cost, jnp.inf)
            s_star = jnp.argmin(cost).astype(jnp.int32)
            movable = sweepable[t] & (hi >= lo)
            new_s = jnp.where(movable, s_star, start_cur[t])
            return start_cur.at[t].set(new_s), None

        start, _ = jax.lax.scan(body, start, order)
        return start

    for _ in range(sweeps):
        start = one_sweep(start)
    return start


@jax.jit
def upward_rank(inst: PackedInstance) -> jnp.ndarray:
    """HEFT-style upward rank: mean duration + longest path to a sink.

    Used as the priority initialization (critical-path-first); candidates add
    noise around it.  Tasks are topologically indexed, so a reverse
    ``fori_loop`` suffices.
    """
    T = inst.T
    mdur = jnp.where(inst.allowed, inst.dur, 0).sum(1) / \
        jnp.maximum(inst.allowed.sum(1), 1)
    succ = inst.pred.T & inst.task_mask[None, :]   # succ[t, v]

    def body(i, rank):
        t = T - 1 - i
        best_succ = jnp.max(jnp.where(succ[t], rank, 0.0))
        return rank.at[t].set(mdur[t] + best_succ)

    rank = jax.lax.fori_loop(0, T, body, jnp.zeros((T,), jnp.float32))
    return jnp.where(inst.task_mask, rank, -1e9)
