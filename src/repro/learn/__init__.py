"""Differentiable gate-policy learning for the online dispatcher.

The offline bi-level bound (paper §3) and the fixed ``(theta, window,
stretch)`` grid of the online gate (§4 / PR 1) bracket the achievable
carbon savings; this package closes the gap by *learning* the gate
threshold with gradients — per scenario family, per fleet, and optionally
conditioned on the forecast's per-lead uncertainty bands:

    relax  — the differentiable relaxation: sigmoid gate over the shared
             sorted-window quantile threshold, expected-wait epoch scan,
             DAG-propagated soft starts (``soft_dispatch``)
    loss   — carbon-under-makespan-budget objective: straight-through hard
             forward values, soft gradients; budget penalty routed through
             the shared validator (``validate.total_violations``)
    train  — one-XLA-program Adam loop (``repro.optim.adamw``, no optax):
             ``lax.scan`` over steps, ``vmap`` over ``pack_aligned``
             instance batches, geometric temperature annealing

**Relaxation contract** (property-tested across every scenario family x
fleet in ``tests/test_learn.py``): as ``temp -> 0`` the relaxation *is* the
hard gate — ``soft_dispatch``'s ``hard`` schedule is bit-exact with
``online_carbon_gated_jax`` at every temperature (same threshold kernel,
same simulator; the relaxation only adds gradient structure around it), and
the sigmoid mask converges pointwise to the boolean quantile gate, so
``soft.dirty > 0.5`` equals the hard mask for every ``temp``.  Training
metrics with ``straight_through=True`` are therefore always reported in
exact hard-dispatch units; only gradients use the relaxation.
"""
from repro.learn.loss import GateLossTerms, gate_loss
from repro.learn.relax import (SoftDispatch, expected_wait, soft_dispatch,
                               soft_gate, soft_starts)
from repro.learn.train import (LearnConfig, TrainResult, evaluate_theta,
                               greedy_reference, logit, train_gate)

__all__ = [
    "GateLossTerms", "gate_loss",
    "SoftDispatch", "expected_wait", "soft_dispatch", "soft_gate",
    "soft_starts",
    "LearnConfig", "TrainResult", "evaluate_theta", "greedy_reference",
    "logit", "train_gate",
]
