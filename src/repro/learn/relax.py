"""Differentiable relaxation of the gated online dispatcher.

The hard gate (:mod:`repro.core.solvers.online_jax`) is a step function of
``theta``: an epoch is *dirty* iff its intensity exceeds the interpolated
``theta``-quantile of its forecast window, and a ready task waits while the
current epoch is dirty (budget permitting).  Neither the mask nor the
integer dispatch admits a gradient.  This module relaxes exactly the two
discrete pieces and nothing else:

* **gate** — :func:`soft_gate` replaces the ``intensity > thresh`` step with
  ``sigmoid((intensity - thresh - GATE_EPS) / temp)``, sharing the sorted
  windows and interpolated quantile threshold with the hard gate
  (:func:`~repro.core.solvers.online_jax.sorted_windows` /
  :func:`~repro.core.solvers.online_jax.quantile_threshold`), so the two
  gates disagree only inside an ``O(temp)`` band around the threshold and
  coincide as ``temp -> 0``;
* **waiting** — :func:`expected_wait` treats the soft mask as per-epoch
  waiting probabilities: ``W[e] = dirty[e] * (1 + W[e+1])`` (one reverse
  ``lax.scan`` over epochs) is the expected number of epochs a task ready at
  ``e`` waits before the gate opens, which at ``temp -> 0`` is exactly the
  hard gate's run of consecutive dirty epochs; :func:`soft_starts` then
  propagates fractional start times through the DAG (topological
  ``fori_loop``, ``max`` over predecessor completions) with the same
  budget cap the hard dispatcher enforces (``waiting`` only while
  ``t + 1 + cp <= budget``).

Machine contention is *not* relaxed: soft starts assume a free machine, the
accuracy of which grows with fleet slack — the regime where gating matters.
The **straight-through** composition in :mod:`repro.learn.loss` therefore
evaluates forward values on the true hard dispatch (contention and all) and
takes gradients through the soft starts.

:func:`soft_dispatch` bundles the pieces: its ``hard`` field is bit-exact
with ``online_carbon_gated_jax`` (same threshold kernel, same simulator —
property-tested across every scenario family x fleet), and its soft fields
are ``jax.grad``-able in ``theta`` (and in per-epoch theta vectors, the
forecast-conditioned case).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.instance import PackedInstance
from repro.core.objectives import makespan
from repro.core.solvers.online_jax import (GATE_EPS, OnlineSchedule,
                                           downstream_critical_path,
                                           online_greedy_jax,
                                           quantile_threshold,
                                           simulate_online, sorted_windows)
from repro.core.validate import task_durations


class SoftDispatch(NamedTuple):
    """Hard forward schedule + differentiable relaxation around it."""

    hard: OnlineSchedule     # exact gated dispatch (forward values)
    greedy: OnlineSchedule   # carbon-agnostic baseline (budget reference)
    start: jnp.ndarray       # float32 [T] soft starts (jax.grad-able)
    dirty: jnp.ndarray       # float32 [E] sigmoid-relaxed dirty mask
    budget: jnp.ndarray      # int32 scalar = int(stretch * greedy makespan)


def soft_gate(intensity: jnp.ndarray, sv: jnp.ndarray, n: jnp.ndarray,
              theta: jnp.ndarray, temp: jnp.ndarray):
    """Sigmoid-relaxed dirty mask over precomputed sorted windows.

    Returns ``(soft, hard)``: ``soft`` is
    ``sigmoid((intensity - thresh - GATE_EPS) / (temp * std(intensity)))``
    and ``hard`` the exact boolean gate from the same threshold, so
    ``soft > 0.5`` equals ``hard`` for every ``temp`` and ``soft -> hard``
    pointwise as ``temp -> 0``.  The margin is normalized by the trace's
    std so ``temp`` is scale-free ("smear the gate over ``temp`` trace-stds
    around the threshold") — raw gCO2/kWh margins would make any fixed
    temperature schedule trace-dependent.  ``theta`` may be scalar or
    per-epoch ``[E]``.
    """
    thresh = quantile_threshold(sv, n, theta)
    margin = intensity - thresh - GATE_EPS
    scale = jax.lax.stop_gradient(jnp.maximum(jnp.std(intensity), 1e-6))
    soft = jax.nn.sigmoid(margin / jnp.maximum(temp * scale, 1e-8))
    return soft, margin > 0


def expected_wait(soft_dirty: jnp.ndarray) -> jnp.ndarray:
    """Expected gate-waiting epochs from each epoch, ``W[e]``, float32 [E].

    ``W[e] = dirty[e] * (1 + W[e+1])`` (reverse ``lax.scan``): with hard
    0/1 masks this counts the run of consecutive dirty epochs starting at
    ``e``; with soft masks it is the expectation under independent per-epoch
    waiting probabilities.  Gradients flow through the whole scan.
    """
    def step(w_next, a):
        w = a * (1.0 + w_next)
        return w, w

    _, ws = jax.lax.scan(step, jnp.zeros((), soft_dirty.dtype), soft_dirty,
                         reverse=True)
    return ws


def soft_starts(inst: PackedInstance, wait: jnp.ndarray, dur: jnp.ndarray,
                cp: jnp.ndarray, budget: jnp.ndarray) -> jnp.ndarray:
    """Fractional start times through the DAG, float32 [T].

    Topological recursion (tasks are topologically indexed, so one
    ``fori_loop`` pass suffices): a task becomes ready at
    ``r = max(arrival, max over preds of soft completion)``, then waits the
    expected gate delay ``wait`` interpolated at ``r``, capped by the same
    budget rule the hard dispatcher enforces — waiting is only allowed while
    ``t + 1 + cp <= budget``, so the waiting allowance from ``r`` is
    ``max(budget - cp - r, 0)``.  ``dur`` are the (stop-gradient) durations
    on the hard dispatch's chosen machines; machine contention is not
    modeled (see module docstring).
    """
    T = inst.T
    E = wait.shape[0]
    ftype = wait.dtype               # float32 normally; float64 under x64
    grid = jnp.arange(E, dtype=ftype)
    dreal = dur.astype(ftype)
    allow_from = budget.astype(ftype) - cp.astype(ftype)
    preds = inst.pred & inst.task_mask[None, :]
    arrival = inst.arrival.astype(ftype)

    def body(t, s):
        comp = s + dreal
        r = jnp.maximum(arrival[t], jnp.max(jnp.where(preds[t], comp, 0.0)))
        w = jnp.interp(jnp.clip(r, 0.0, grid[-1]), grid, wait)
        st = r + jnp.minimum(w, jnp.maximum(allow_from[t] - r, 0.0))
        return s.at[t].set(jnp.where(inst.task_mask[t], st, 0.0))

    return jax.lax.fori_loop(0, T, body, jnp.zeros((T,), ftype))


@functools.partial(jax.jit,
                   static_argnames=("max_window", "machine_rule"))
def soft_dispatch(inst: PackedInstance, intensity: jnp.ndarray,
                  theta: jnp.ndarray, window: jnp.ndarray,
                  stretch: jnp.ndarray, max_window: int,
                  temp: float = 0.05,
                  machine_rule: str = "earliest_finish") -> SoftDispatch:
    """Gated dispatch with a differentiable relaxation attached.

    Forward semantics are `online_carbon_gated_jax`'s, bit for bit: greedy
    baseline fixes ``budget = int(stretch * makespan)``, the hard quantile
    gate masks epochs, ``simulate_online`` dispatches.  On top, the returned
    ``start``/``dirty`` fields carry the temperature-``temp`` relaxation of
    the gate decision, differentiable in ``theta`` (scalar or per-epoch).
    """
    intensity = jnp.asarray(intensity, jnp.float32)
    n_epochs = int(intensity.shape[0])
    g = online_greedy_jax(inst, n_epochs, machine_rule=machine_rule)
    ms0 = makespan(inst, g.start, g.assign)
    budget = (jnp.asarray(stretch, jnp.float32)
              * ms0.astype(jnp.float32)).astype(jnp.int32)
    sv, n = sorted_windows(intensity, jnp.asarray(window, jnp.int32),
                           max_window)
    soft, hard_mask = soft_gate(intensity, sv, n, theta,
                                jnp.asarray(temp, jnp.float32))
    hard = simulate_online(inst, hard_mask, budget, n_epochs=n_epochs,
                           machine_rule=machine_rule)
    dur = task_durations(inst, hard.assign)
    cp = downstream_critical_path(inst)
    start = soft_starts(inst, expected_wait(soft), dur, cp, budget)
    return SoftDispatch(hard=hard, greedy=g, start=start, dirty=soft,
                        budget=budget)
