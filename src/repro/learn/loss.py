"""Carbon-under-makespan-budget loss for gate-policy learning.

One scalar objective per (instance, theta): normalized carbon of the gated
dispatch plus a budget-violation penalty, built so that

* **forward values are honest** — with ``straight_through=True`` (the
  training default) the carbon term is evaluated at the *hard* dispatch's
  integer starts (machine contention and all) and the penalty is the shared
  validator's integer violation mass
  (:func:`repro.core.validate.total_violations` with the stretch budget as
  deadline), so the loss curve reads in the same units as the benchmarks;
* **gradients are useful** — both terms take their ``theta``-gradient
  through the soft relaxation (:mod:`repro.learn.relax`): the carbon term
  through :func:`~repro.core.objectives.soft_carbon`'s interpolated trace
  (``d carbon / d start = P * (intensity at end - intensity at start)``),
  the penalty through the soft starts' budget overshoot ``relu(comp -
  budget)`` — the differentiable twin of the validator's budget mass.

With ``straight_through=False`` the loss is evaluated entirely at the soft
starts and is therefore (piecewise) smooth in ``theta`` — that is the form
the gradient-vs-finite-difference property test checks.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import validate
from repro.core.instance import PackedInstance
from repro.core.objectives import soft_carbon
from repro.core.solvers.online_jax import (downstream_critical_path,
                                           simulate_online)
from repro.core.validate import task_durations
from repro.learn.relax import expected_wait, soft_gate, soft_starts


class GateLossTerms(NamedTuple):
    """Per-instance loss pieces (all float32 scalars)."""

    carbon: jnp.ndarray    # gCO2 of the gated dispatch (grad via relaxation)
    penalty: jnp.ndarray   # budget-violation mass (grad via soft overshoot)
    soft_start: jnp.ndarray  # float32 [T] — the relaxed starts (diagnostics)


def gate_loss(inst: PackedInstance, cum: jnp.ndarray,
              intensity: jnp.ndarray, sv: jnp.ndarray, n: jnp.ndarray,
              theta: jnp.ndarray, budget: jnp.ndarray, temp: jnp.ndarray,
              n_epochs: int, straight_through: bool = True,
              machine_rule: str = "earliest_finish") -> GateLossTerms:
    """Loss terms for one instance at one (possibly per-epoch) ``theta``.

    ``sv``/``n`` are the precomputed sorted forecast windows (shared across
    every gradient step — sort once, train many); ``budget`` is the integer
    stretch budget from the greedy baseline.  Returns carbon and penalty
    terms whose forward/backward split is described in the module docstring.
    """
    soft, hard_mask = soft_gate(intensity, sv, n, theta, temp)
    hard = simulate_online(inst, hard_mask, budget, n_epochs=n_epochs,
                           machine_rule=machine_rule)
    dur = task_durations(inst, hard.assign)
    cp = downstream_critical_path(inst)
    s_soft = soft_starts(inst, expected_wait(soft), dur, cp, budget)

    bud = budget.astype(jnp.float32)
    over = s_soft + dur.astype(jnp.float32) - bud
    pen_soft = jnp.sum(jnp.where(inst.task_mask, jnp.maximum(over, 0.0), 0.0))

    c_soft = soft_carbon(inst, s_soft, hard.assign, cum)
    if straight_through:
        # Value-level straight-through: forward values come from the hard
        # dispatch (exact carbon at integer starts; the validator's integer
        # budget mass), gradients from the full soft terms.  Splicing at the
        # *value* level keeps the gradient identical to the FD-verified soft
        # gradient — splicing at the start level would evaluate the local
        # trace slope at hard starts the relaxation never visited, which on
        # an oscillating intensity trace is sign-unstable.
        c_hard = soft_carbon(inst, hard.start.astype(jnp.float32),
                             hard.assign, cum)      # == objectives.carbon
        c = c_soft + jax.lax.stop_gradient(c_hard - c_soft)
        pen_hard = validate.total_violations(
            inst, hard.start, hard.assign, deadline=budget).astype(jnp.float32)
        pen = pen_soft + jax.lax.stop_gradient(pen_hard - pen_soft)
    else:
        c = c_soft
        pen = pen_soft
    return GateLossTerms(carbon=c, penalty=pen, soft_start=s_soft)
