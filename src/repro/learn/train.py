"""Gate-policy training: one XLA program per (family x fleet) grid.

The trainable object is tiny — per *group* (a scenario family x fleet cell,
or any other partition of the instance batch) a logistic-parametrized gate
policy ``theta(e) = sigmoid(base_g + slope_g * feat[e])``:

* with ``feats = None`` the slope axis is inert (zero features, zero
  gradient) and each group learns one scalar ``theta`` — the learned
  counterpart of the fixed ``(theta, window, stretch)`` grid;
* with ``feats`` set to per-epoch forecast features (the per-lead
  uncertainty bands of :func:`repro.forecast.rolling.theta_band_features`)
  each group learns a *forecast-conditioned* theta profile.

The whole optimization is one jitted program: ``lax.scan`` over training
steps (gradients flow through the epoch scan of the relaxation inside each
step), ``vmap`` over the stacked :func:`~repro.scenarios.batching.
pack_aligned` instances, Adam from :mod:`repro.optim.adamw` (no optax),
temperature annealed geometrically from ``temp0`` to ``temp1`` so the
relaxation tightens toward the hard gate as training converges.
Everything is deterministic — no PRNG anywhere — which is what the golden
regression (``tests/test_learn_golden.py``) locks.

Cross-instance reductions are *canonically associated*: the loss and its
gradient are computed per row (each row's gradient seeded with the exact
``1/B`` cotangent a batched ``jnp.mean`` backward would emit) and summed
over rows by an explicitly sequential scan (:func:`seq_sum`) whose
dependent adds no compiler pass can reassociate.  The floats this produces
are the point: :func:`repro.shard.train.train_sharded` runs the identical
per-row program on instance shards, gathers the per-row pieces back into
row order and applies the same ordered reduction — so sharded training is
bit-exact with this single-device learner at every device count, instead
of drifting with XLA's batch-size- and partitioning-dependent reduce
associations.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.instance import PackedInstance
from repro.core.objectives import carbon, makespan
from repro.core.solvers.online_jax import (_quantile_dirty,
                                           online_greedy_jax,
                                           simulate_online, sorted_windows)
from repro.learn.loss import gate_loss
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update


class LearnConfig(NamedTuple):
    """Training knobs (hashable — used as a jit-static argument)."""

    steps: int = 150            # gradient steps (the scanned axis)
    lr: float = 0.08
    temp0: float = 0.5          # relaxation temperature at step 0 ...
    temp1: float = 0.02         # ... annealed geometrically to this
    lam: float = 0.2            # budget-penalty weight
    straight_through: bool = True
    machine_rule: str = "earliest_finish"


class TrainResult(NamedTuple):
    raw: jnp.ndarray           # float32 [G, 2] — (base, slope) logits
    theta: jnp.ndarray         # float32 [G] — sigmoid(base), the flat theta
    loss_curve: jnp.ndarray    # float32 [steps] — mean training loss
    carbon_curve: jnp.ndarray  # float32 [steps] — mean carbon ratio (hard)
    theta_curve: jnp.ndarray   # float32 [steps, G]


def logit(p) -> jnp.ndarray:
    p = jnp.clip(jnp.asarray(p, jnp.float32), 1e-4, 1.0 - 1e-4)
    return jnp.log(p) - jnp.log1p(-p)


def _anneal(cfg: LearnConfig, k: jnp.ndarray) -> jnp.ndarray:
    frac = k.astype(jnp.float32) / max(cfg.steps - 1, 1)
    return jnp.float32(cfg.temp0) * (
        jnp.float32(cfg.temp1) / jnp.float32(cfg.temp0)) ** frac


def greedy_reference(batch: PackedInstance, cum: jnp.ndarray, n_epochs: int,
                     machine_rule: str = "earliest_finish"):
    """Per-instance greedy baseline: (makespan [B], carbon [B]).

    Delegates to the dispatcher's own
    :func:`~repro.core.solvers.online_jax.online_greedy_jax`, so the
    learner's budgets and savings are always relative to the exact
    reference the fixed-grid sweeps use.
    """
    def one(inst, cm):
        g = online_greedy_jax(inst, n_epochs, machine_rule=machine_rule)
        ms = makespan(inst, g.start, g.assign)
        return ms, carbon(inst, g.start, g.assign, cm)

    return jax.vmap(one)(batch, cum)


def seq_sum(x: jnp.ndarray) -> jnp.ndarray:
    """Sum over the leading axis in strict index order.

    A ``lax.scan`` of dependent adds — no compiler pipeline can reassociate
    it, unlike ``jnp.sum``/``jnp.mean`` whose reduce association varies
    with batch size and with XLA's manual-partitioning pass.  The canonical
    cross-row reduction shared by :func:`_train` and
    :func:`repro.shard.train.train_sharded` (see module docstring).
    """
    zero = jnp.zeros(x.shape[1:], x.dtype)
    return jax.lax.scan(lambda a, v: (a + v, None), zero, x)[0]


def per_row_loss(raw, temp, inst, cm, it, sv, n, gid, feat, bud, bc, mn,
                 inv_b, cfg: LearnConfig, n_epochs: int):
    """One row's contribution to the training loss.

    Returns the loss term scaled by ``inv_b`` (= ``1/B`` as float32) so
    that ``jax.grad`` of it seeds the row's backward with exactly the
    cotangent a batched ``jnp.mean`` would, and the per-row raw pieces
    ``(carbon, penalty)`` as aux for the value path.
    """
    th = jax.nn.sigmoid(raw[gid, 0] + raw[gid, 1] * feat)        # [E]
    terms = gate_loss(inst, cm, it, sv, n, th, bud, temp, n_epochs,
                      cfg.straight_through, cfg.machine_rule)
    loss = terms.carbon / bc + cfg.lam * (terms.penalty / mn)
    return loss * inv_b, (terms.carbon, terms.penalty)


def train_opt_cfg(cfg: LearnConfig) -> AdamWConfig:
    """The learner's Adam schedule (one definition for both train paths)."""
    return AdamWConfig(lr=cfg.lr, warmup_steps=max(1, cfg.steps // 10),
                       total_steps=cfg.steps, min_lr_frac=0.1,
                       weight_decay=0.0, clip_norm=1.0)


def build_train_step(cfg: LearnConfig, opt_cfg: AdamWConfig, n_epochs: int,
                     inv_b, row_args, reduce_rows, value_norms):
    """One Adam step of the gate learner — the single copy of the update
    math shared by :func:`_train` and :func:`repro.shard.train.
    train_sharded`, so the bit-exact sharded==single-device contract rests
    on *one* definition rather than twin code.

    ``row_args``: the per-row gradient inputs ``(batch, cum, intensity,
    sv, n, group_of, feats, budget, bc, mn)`` — full batch on the
    single-device path, the local row shard under shard_map;
    ``reduce_rows``: maps per-row arrays to full-batch row order (identity
    on one device; all_gather + slice-off-padding sharded);
    ``value_norms``: the full-batch ``(base_c, ms_norm)`` normalizers for
    the recorded curves.
    """
    per_row = functools.partial(per_row_loss, cfg=cfg, n_epochs=n_epochs)
    per_row_grads = jax.vmap(
        jax.grad(per_row, has_aux=True),
        in_axes=(None, None, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, None))
    bc_full, mn_full = value_norms

    def step(carry, k):
        params, state = carry
        temp = _anneal(cfg, k)
        g, (c_row, p_row) = per_row_grads(
            params["raw"], temp, *row_args, inv_b)
        grads = seq_sum(reduce_rows(g))                 # canonical row order
        ratio = reduce_rows(c_row) / bc_full
        pen = reduce_rows(p_row) / mn_full
        loss = seq_sum(ratio + cfg.lam * pen) * inv_b
        ratio_m = seq_sum(ratio) * inv_b
        params, state, _ = adamw_update(params, {"raw": grads}, state,
                                        opt_cfg)
        return (params, state), (loss, ratio_m,
                                 jax.nn.sigmoid(params["raw"][:, 0]))

    return step


def run_train_scan(step, raw0, opt_cfg: AdamWConfig, steps: int):
    """Scan ``step`` over the training steps from a fresh Adam state."""
    params = {"raw": raw0}
    state = adamw_init(params, opt_cfg)
    (params, _), ys = jax.lax.scan(
        step, (params, state), jnp.arange(steps, dtype=jnp.int32))
    return params["raw"], ys


@functools.partial(jax.jit,
                   static_argnames=("cfg", "max_window", "n_epochs"))
def _train(batch: PackedInstance, intensity, cum, group_of, window, budget,
           base_carbon, ms0, feats, raw0, cfg: LearnConfig, max_window: int,
           n_epochs: int) -> TrainResult:
    sv, n = jax.vmap(lambda i, w: sorted_windows(i, w, max_window))(
        intensity, window)
    base_c = jnp.maximum(base_carbon, 1e-6)
    ms_norm = jnp.maximum(ms0.astype(jnp.float32), 1.0)
    inv_b = jnp.float32(1.0) / jnp.float32(int(intensity.shape[0]))

    opt_cfg = train_opt_cfg(cfg)
    step = build_train_step(
        cfg, opt_cfg, n_epochs, inv_b,
        row_args=(batch, cum, intensity, sv, n, group_of, feats, budget,
                  base_c, ms_norm),
        reduce_rows=lambda x: x, value_norms=(base_c, ms_norm))
    raw, (losses, ratios, thetas) = run_train_scan(step, raw0, opt_cfg,
                                                   cfg.steps)
    return TrainResult(raw=raw, theta=jax.nn.sigmoid(raw[:, 0]),
                       loss_curve=losses, carbon_curve=ratios,
                       theta_curve=thetas)


def train_gate(batch: PackedInstance, intensity, cum, group_of,
               window, stretch: float, theta0,
               cfg: LearnConfig = LearnConfig(),
               feats=None, baseline=None) -> TrainResult:
    """Learn per-group gate thetas on a stacked instance batch.

    ``batch``/``intensity``/``cum``: stacked ``[B, ...]`` instances with
    their forecast windows and cumulative traces; ``group_of [B]`` maps each
    instance to its parameter group (0..G-1, G from ``theta0``'s length);
    ``window [B]`` is each instance's gate window; ``stretch`` the shared
    stretch budget (per-group budgets: call once per stretch — budgets are
    relative to each instance's own greedy baseline either way); ``theta0
    [G]`` the initialization (e.g. the best fixed-grid theta per group);
    ``feats [B, E]`` optional per-epoch features for forecast-conditioned
    thetas; ``baseline`` an optional precomputed ``(greedy_makespan [B],
    greedy_carbon [B])`` pair from a sweep that already dispatched the
    greedy baseline (omitted, it is computed here via
    :func:`greedy_reference`).  Deterministic; one jitted program.
    """
    intensity = jnp.asarray(intensity, jnp.float32)
    n_epochs = int(intensity.shape[-1])
    window = np.asarray(window, np.int32)
    max_window = int(window.max())
    ms0, base_c = (baseline if baseline is not None else
                   greedy_reference(batch, jnp.asarray(cum), n_epochs,
                                    cfg.machine_rule))
    ms0 = jnp.asarray(ms0, jnp.int32)
    base_c = jnp.asarray(base_c, jnp.float32)
    budget = (jnp.float32(stretch) * ms0.astype(jnp.float32)).astype(
        jnp.int32)
    theta0 = jnp.asarray(theta0, jnp.float32)
    raw0 = jnp.stack([logit(theta0), jnp.zeros_like(theta0)], axis=1)
    if feats is None:
        feats = jnp.zeros(intensity.shape, jnp.float32)
    # Host-side trace boundary (repro.obs): a direct _train call unless
    # tracing is enabled, in which case the wall-clock span is recorded
    # around (never inside) the jitted program — values are identical.
    from repro.obs.trace import traced_xla_call
    return traced_xla_call(
        "learn.train", _train, batch, intensity, jnp.asarray(cum),
        jnp.asarray(group_of), jnp.asarray(window), budget, base_c, ms0,
        jnp.asarray(feats, jnp.float32), raw0, cfg, max_window, n_epochs)


@functools.partial(jax.jit,
                   static_argnames=("max_window", "n_epochs",
                                    "machine_rule"))
def _hard_eval(batch, intensity, cum, theta, window, budget, max_window: int,
               n_epochs: int, machine_rule: str):
    def one(inst, inten, cm, th, wi, bud):
        sv, n = sorted_windows(inten, wi, max_window)
        dirty = _quantile_dirty(inten, sv, n, th)
        sch = simulate_online(inst, dirty, bud, n_epochs=n_epochs,
                              machine_rule=machine_rule)
        return (carbon(inst, sch.start, sch.assign, cm),
                makespan(inst, sch.start, sch.assign),
                jnp.all(sch.scheduled | ~inst.task_mask))

    return jax.vmap(one)(batch, intensity, cum, theta, window, budget)


def evaluate_theta(batch: PackedInstance, intensity, cum, theta, window,
                   stretch: float,
                   machine_rule: str = "earliest_finish", baseline=None):
    """Hard-dispatch evaluation of learned thetas (no relaxation anywhere).

    ``theta``: per-instance scalar ``[B]`` or per-epoch ``[B, E]``.  Returns
    ``(savings [B], gated_carbon [B], base_carbon [B], makespan_ratio [B])``
    — the same metrics the fixed-grid sweep reports, so learned and fixed
    policies compare apples to apples.  ``baseline``: optional precomputed
    ``(greedy_makespan [B], greedy_carbon [B])``, as in :func:`train_gate`.
    """
    intensity = jnp.asarray(intensity, jnp.float32)
    n_epochs = int(intensity.shape[-1])
    window = np.asarray(window, np.int32)
    ms0, base_c = (baseline if baseline is not None else
                   greedy_reference(batch, jnp.asarray(cum), n_epochs,
                                    machine_rule))
    ms0 = jnp.asarray(ms0, jnp.int32)
    base_c = jnp.asarray(base_c, jnp.float32)
    budget = (jnp.float32(stretch) * ms0.astype(jnp.float32)).astype(
        jnp.int32)
    from repro.obs.trace import traced_xla_call
    gated_c, gated_ms, done = traced_xla_call(
        "learn.hard_eval", _hard_eval,
        batch, intensity, jnp.asarray(cum), jnp.asarray(theta, jnp.float32),
        jnp.asarray(window), budget, int(window.max()), n_epochs,
        machine_rule)
    if not bool(jnp.all(done)):
        raise AssertionError(
            "gated dispatch incomplete at evaluation — raise the horizon")
    savings = 1.0 - gated_c / jnp.maximum(base_c, 1e-6)
    ms_ratio = (gated_ms.astype(jnp.float32)
                / jnp.maximum(ms0.astype(jnp.float32), 1.0))
    return savings, gated_c, base_c, ms_ratio
