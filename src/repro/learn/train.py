"""Gate-policy training: one XLA program per (family x fleet) grid.

The trainable object is tiny — per *group* (a scenario family x fleet cell,
or any other partition of the instance batch) a logistic-parametrized gate
policy ``theta(e) = sigmoid(base_g + slope_g * feat[e])``:

* with ``feats = None`` the slope axis is inert (zero features, zero
  gradient) and each group learns one scalar ``theta`` — the learned
  counterpart of the fixed ``(theta, window, stretch)`` grid;
* with ``feats`` set to per-epoch forecast features (the per-lead
  uncertainty bands of :func:`repro.forecast.rolling.theta_band_features`)
  each group learns a *forecast-conditioned* theta profile.

The whole optimization is one jitted program: ``lax.scan`` over training
steps (gradients flow through the epoch scan of the relaxation inside each
step), ``vmap`` over the stacked :func:`~repro.scenarios.batching.
pack_aligned` instances, Adam from :mod:`repro.optim.adamw` (no optax),
temperature annealed geometrically from ``temp0`` to ``temp1`` so the
relaxation tightens toward the hard gate as training converges.
Everything is deterministic — no PRNG anywhere — which is what the golden
regression (``tests/test_learn_golden.py``) locks.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.instance import PackedInstance
from repro.core.objectives import carbon, makespan
from repro.core.solvers.online_jax import (_quantile_dirty,
                                           online_greedy_jax,
                                           simulate_online, sorted_windows)
from repro.learn.loss import gate_loss
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update


class LearnConfig(NamedTuple):
    """Training knobs (hashable — used as a jit-static argument)."""

    steps: int = 150            # gradient steps (the scanned axis)
    lr: float = 0.08
    temp0: float = 0.5          # relaxation temperature at step 0 ...
    temp1: float = 0.02         # ... annealed geometrically to this
    lam: float = 0.2            # budget-penalty weight
    straight_through: bool = True
    machine_rule: str = "earliest_finish"


class TrainResult(NamedTuple):
    raw: jnp.ndarray           # float32 [G, 2] — (base, slope) logits
    theta: jnp.ndarray         # float32 [G] — sigmoid(base), the flat theta
    loss_curve: jnp.ndarray    # float32 [steps] — mean training loss
    carbon_curve: jnp.ndarray  # float32 [steps] — mean carbon ratio (hard)
    theta_curve: jnp.ndarray   # float32 [steps, G]


def logit(p) -> jnp.ndarray:
    p = jnp.clip(jnp.asarray(p, jnp.float32), 1e-4, 1.0 - 1e-4)
    return jnp.log(p) - jnp.log1p(-p)


def _anneal(cfg: LearnConfig, k: jnp.ndarray) -> jnp.ndarray:
    frac = k.astype(jnp.float32) / max(cfg.steps - 1, 1)
    return jnp.float32(cfg.temp0) * (
        jnp.float32(cfg.temp1) / jnp.float32(cfg.temp0)) ** frac


def greedy_reference(batch: PackedInstance, cum: jnp.ndarray, n_epochs: int,
                     machine_rule: str = "earliest_finish"):
    """Per-instance greedy baseline: (makespan [B], carbon [B]).

    Delegates to the dispatcher's own
    :func:`~repro.core.solvers.online_jax.online_greedy_jax`, so the
    learner's budgets and savings are always relative to the exact
    reference the fixed-grid sweeps use.
    """
    def one(inst, cm):
        g = online_greedy_jax(inst, n_epochs, machine_rule=machine_rule)
        ms = makespan(inst, g.start, g.assign)
        return ms, carbon(inst, g.start, g.assign, cm)

    return jax.vmap(one)(batch, cum)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "max_window", "n_epochs"))
def _train(batch: PackedInstance, intensity, cum, group_of, window, budget,
           base_carbon, ms0, feats, raw0, cfg: LearnConfig, max_window: int,
           n_epochs: int) -> TrainResult:
    sv, n = jax.vmap(lambda i, w: sorted_windows(i, w, max_window))(
        intensity, window)
    base_c = jnp.maximum(base_carbon, 1e-6)
    ms_norm = jnp.maximum(ms0.astype(jnp.float32), 1.0)

    def loss_fn(raw, temp):
        base = raw[:, 0][group_of]                    # [B]
        slope = raw[:, 1][group_of]
        th = jax.nn.sigmoid(base[:, None] + slope[:, None] * feats)  # [B, E]

        def per_inst(inst, cm, it, s, nn, t, bud):
            return gate_loss(inst, cm, it, s, nn, t, bud, temp, n_epochs,
                             cfg.straight_through, cfg.machine_rule)

        terms = jax.vmap(per_inst)(batch, cum, intensity, sv, n, th, budget)
        ratio = terms.carbon / base_c
        pen = terms.penalty / ms_norm
        return jnp.mean(ratio + cfg.lam * pen), jnp.mean(ratio)

    opt_cfg = AdamWConfig(lr=cfg.lr, warmup_steps=max(1, cfg.steps // 10),
                          total_steps=cfg.steps, min_lr_frac=0.1,
                          weight_decay=0.0, clip_norm=1.0)
    params = {"raw": raw0}
    state = adamw_init(params, opt_cfg)

    def step(carry, k):
        params, state = carry
        temp = _anneal(cfg, k)
        (loss, ratio), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params["raw"], temp)
        params, state, _ = adamw_update(params, {"raw": grads}, state,
                                        opt_cfg)
        return (params, state), (loss, ratio,
                                 jax.nn.sigmoid(params["raw"][:, 0]))

    (params, _), (losses, ratios, thetas) = jax.lax.scan(
        step, (params, state), jnp.arange(cfg.steps, dtype=jnp.int32))
    raw = params["raw"]
    return TrainResult(raw=raw, theta=jax.nn.sigmoid(raw[:, 0]),
                       loss_curve=losses, carbon_curve=ratios,
                       theta_curve=thetas)


def train_gate(batch: PackedInstance, intensity, cum, group_of,
               window, stretch: float, theta0,
               cfg: LearnConfig = LearnConfig(),
               feats=None, baseline=None) -> TrainResult:
    """Learn per-group gate thetas on a stacked instance batch.

    ``batch``/``intensity``/``cum``: stacked ``[B, ...]`` instances with
    their forecast windows and cumulative traces; ``group_of [B]`` maps each
    instance to its parameter group (0..G-1, G from ``theta0``'s length);
    ``window [B]`` is each instance's gate window; ``stretch`` the shared
    stretch budget (per-group budgets: call once per stretch — budgets are
    relative to each instance's own greedy baseline either way); ``theta0
    [G]`` the initialization (e.g. the best fixed-grid theta per group);
    ``feats [B, E]`` optional per-epoch features for forecast-conditioned
    thetas; ``baseline`` an optional precomputed ``(greedy_makespan [B],
    greedy_carbon [B])`` pair from a sweep that already dispatched the
    greedy baseline (omitted, it is computed here via
    :func:`greedy_reference`).  Deterministic; one jitted program.
    """
    intensity = jnp.asarray(intensity, jnp.float32)
    n_epochs = int(intensity.shape[-1])
    window = np.asarray(window, np.int32)
    max_window = int(window.max())
    ms0, base_c = (baseline if baseline is not None else
                   greedy_reference(batch, jnp.asarray(cum), n_epochs,
                                    cfg.machine_rule))
    ms0 = jnp.asarray(ms0, jnp.int32)
    base_c = jnp.asarray(base_c, jnp.float32)
    budget = (jnp.float32(stretch) * ms0.astype(jnp.float32)).astype(
        jnp.int32)
    theta0 = jnp.asarray(theta0, jnp.float32)
    raw0 = jnp.stack([logit(theta0), jnp.zeros_like(theta0)], axis=1)
    if feats is None:
        feats = jnp.zeros(intensity.shape, jnp.float32)
    return _train(batch, intensity, jnp.asarray(cum), jnp.asarray(group_of),
                  jnp.asarray(window), budget, base_c, ms0,
                  jnp.asarray(feats, jnp.float32), raw0, cfg, max_window,
                  n_epochs)


@functools.partial(jax.jit,
                   static_argnames=("max_window", "n_epochs",
                                    "machine_rule"))
def _hard_eval(batch, intensity, cum, theta, window, budget, max_window: int,
               n_epochs: int, machine_rule: str):
    def one(inst, inten, cm, th, wi, bud):
        sv, n = sorted_windows(inten, wi, max_window)
        dirty = _quantile_dirty(inten, sv, n, th)
        sch = simulate_online(inst, dirty, bud, n_epochs=n_epochs,
                              machine_rule=machine_rule)
        return (carbon(inst, sch.start, sch.assign, cm),
                makespan(inst, sch.start, sch.assign),
                jnp.all(sch.scheduled | ~inst.task_mask))

    return jax.vmap(one)(batch, intensity, cum, theta, window, budget)


def evaluate_theta(batch: PackedInstance, intensity, cum, theta, window,
                   stretch: float,
                   machine_rule: str = "earliest_finish", baseline=None):
    """Hard-dispatch evaluation of learned thetas (no relaxation anywhere).

    ``theta``: per-instance scalar ``[B]`` or per-epoch ``[B, E]``.  Returns
    ``(savings [B], gated_carbon [B], base_carbon [B], makespan_ratio [B])``
    — the same metrics the fixed-grid sweep reports, so learned and fixed
    policies compare apples to apples.  ``baseline``: optional precomputed
    ``(greedy_makespan [B], greedy_carbon [B])``, as in :func:`train_gate`.
    """
    intensity = jnp.asarray(intensity, jnp.float32)
    n_epochs = int(intensity.shape[-1])
    window = np.asarray(window, np.int32)
    ms0, base_c = (baseline if baseline is not None else
                   greedy_reference(batch, jnp.asarray(cum), n_epochs,
                                    machine_rule))
    ms0 = jnp.asarray(ms0, jnp.int32)
    base_c = jnp.asarray(base_c, jnp.float32)
    budget = (jnp.float32(stretch) * ms0.astype(jnp.float32)).astype(
        jnp.int32)
    gated_c, gated_ms, done = _hard_eval(
        batch, intensity, jnp.asarray(cum), jnp.asarray(theta, jnp.float32),
        jnp.asarray(window), budget, int(window.max()), n_epochs,
        machine_rule)
    if not bool(jnp.all(done)):
        raise AssertionError(
            "gated dispatch incomplete at evaluation — raise the horizon")
    savings = 1.0 - gated_c / jnp.maximum(base_c, 1e-6)
    ms_ratio = (gated_ms.astype(jnp.float32)
                / jnp.maximum(ms0.astype(jnp.float32), 1.0))
    return savings, gated_c, base_c, ms_ratio
