from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               cosine_lr)
from repro.optim.compress import (CompressState, compress_init,
                                  compressed_grads)

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_lr",
           "CompressState", "compress_init", "compressed_grads"]
