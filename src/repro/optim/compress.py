"""Int8 gradient compression with error feedback.

At 1000+-node scale the cross-pod data-parallel all-reduce is the slowest
collective (DCN, not ICI).  Compressing pod-boundary gradients to int8 with
an error-feedback accumulator cuts those bytes 4x at negligible quality
cost (the residual is re-injected next step, so the compression error is
a delayed — not lost — signal).

Mechanics: grads are quantized per-tensor (symmetric, max-abs scaling),
dequantized immediately (this container cannot run a real DCN reduce), and
the quantization residual is carried in ``CompressState``.  On hardware the
int8 payload is what crosses the pod boundary; the roofline collective
term for the multi-pod mesh is scaled accordingly (see launch/roofline.py).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class CompressState(NamedTuple):
    residual: Any   # error-feedback accumulator, same tree as grads


def compress_init(params) -> CompressState:
    return CompressState(jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def _q8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_grads(grads, state: CompressState
                     ) -> tuple[Any, CompressState, dict]:
    """Returns (dequantized grads, new state, metrics)."""
    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, scale = _q8(x)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), x - deq

    out = jax.tree.map(one, grads, state.residual)
    leaves, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    deq = jax.tree.unflatten(treedef, [l[0] for l in leaves])
    res = jax.tree.unflatten(treedef, [l[1] for l in leaves])
    err = sum(jnp.sum(jnp.square(r)) for r in jax.tree.leaves(res))
    return deq, CompressState(res), {"compress_residual_sq": err}
