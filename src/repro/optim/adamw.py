"""AdamW with cosine schedule, global-norm clipping and sharded state.

Functional: ``state = adamw_init(params)``; ``params, state =
adamw_update(params, grads, state, cfg)``.  Moments inherit the parameter
tree's sharding (ZeRO: with fsdp rules the params — and therefore m/v —
shard over the data axes; the dry-run verifies the resulting memory).
``moment_dtype=bfloat16`` halves optimizer HBM (a §Perf lever for the
1T-param cell); master params stay fp32.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: Any = jnp.float32


class OptState(NamedTuple):
    m: Any
    v: Any
    step: jnp.ndarray


def cosine_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def adamw_init(params, cfg: AdamWConfig = AdamWConfig()) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)  # noqa: E731
    return OptState(m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params),
                    step=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state: OptState, cfg: AdamWConfig
                 ) -> tuple[Any, OptState, dict]:
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = cosine_lr(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m32 = cfg.b1 * m32 + (1 - cfg.b1) * g
        v32 = cfg.b2 * v32 + (1 - cfg.b2) * g * g
        u = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        # Decoupled weight decay on matrices only (ndim >= 2).
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (u + wd * p32)
        return (p32.astype(p.dtype), m32.astype(cfg.moment_dtype),
                v32.astype(cfg.moment_dtype))

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    leaves, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    new_p = jax.tree.unflatten(treedef, [l[0] for l in leaves])
    new_m = jax.tree.unflatten(treedef, [l[1] for l in leaves])
    new_v = jax.tree.unflatten(treedef, [l[2] for l in leaves])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(new_m, new_v, step), metrics
