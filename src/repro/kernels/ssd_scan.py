"""Pallas TPU kernel: Mamba2 SSD chunk scan (one (batch, head) per row).

Grid = (B*H, n_chunks) with chunks innermost (sequential): the recurrent
state [P, N] lives in VMEM scratch and is carried across chunk iterations,
so the whole sequence is processed with one HBM pass over x/dt/B/C and no
state materialization — the TPU-native form of the SSD algorithm's
"chunkwise-parallel + inter-chunk recurrence" split (the quadratic
intra-chunk term runs on the MXU, the state update on the VPU).

Layout notes: dt is passed as [BH, S, 1] (lane-broadcastable), B/C as
[BG, S, N] with the head->group fold done by the BlockSpec index map
(``h // heads_per_group``) — group-shared B/C stream once per group.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, h_ref, hout_ref, *,
            chunk: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0]                                    # scalar A (<0) this head
    x = x_ref[...].astype(jnp.float32)              # [Q, P]
    dt = dt_ref[...].astype(jnp.float32)            # [Q, 1]
    Bm = b_ref[...].astype(jnp.float32)             # [Q, N]
    Cm = c_ref[...].astype(jnp.float32)             # [Q, N]

    dA = dt[:, 0] * a                               # [Q]
    cum = jnp.cumsum(dA)                            # [Q] inclusive

    # Intra-chunk quadratic term: y_i += sum_{j<=i} e^{cum_i-cum_j} dt_j
    #                                     (C_i.B_j) x_j
    seg = cum[:, None] - cum[None, :]               # [Q, Q]
    iq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(jq <= iq, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    M = scores * L * dt[None, :, 0]
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # Inter-chunk: y_i += e^{cum_i} C_i . h_in ; then update the state.
    h_in = h_ref[...]                               # [P, N]
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, h_in, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    decay_end = jnp.exp(cum[-1] - cum) * dt[:, 0]   # [Q]
    upd = jax.lax.dot_general(x * decay_end[:, None], Bm,
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # [P, N]
    h_ref[...] = h_in * jnp.exp(cum[-1]) + upd

    y_ref[...] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _flush():
        hout_ref[...] = h_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                    Bm: jnp.ndarray, Cm: jnp.ndarray, chunk: int = 64,
                    *, interpret: bool):
    """x [B,S,H,P]; dt [B,S,H] (post-softplus); A [H] (<0);
    Bm/Cm [B,S,G,N].  Returns (y [B,S,H,P], h_final [B,H,P,N]).

    ``interpret`` is **required**: callers go through
    :mod:`repro.kernels.ops`, where the backend-aware default lives."""
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Q = min(chunk, S)
    assert S % Q == 0, "pad seq to chunk multiple"
    nc = S // Q

    xf = x.transpose(0, 2, 1, 3).reshape(Bsz * H, S, P)
    dtf = dt.transpose(0, 2, 1).reshape(Bsz * H, S, 1)
    bf = Bm.transpose(0, 2, 1, 3).reshape(Bsz * G, S, N)
    cf = Cm.transpose(0, 2, 1, 3).reshape(Bsz * G, S, N)
    af = jnp.broadcast_to(A.astype(jnp.float32)[None], (Bsz, H)
                          ).reshape(Bsz * H, 1)

    kernel = functools.partial(_kernel, chunk=Q, n_chunks=nc)
    y, h_fin = pl.pallas_call(
        kernel,
        grid=(Bsz * H, nc),
        in_specs=[
            pl.BlockSpec((None, 1), lambda bh, ci: (bh, 0)),
            pl.BlockSpec((None, Q, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, Q, 1), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, Q, N),
                         lambda bh, ci, r=rep: (bh // r, ci, 0)),
            pl.BlockSpec((None, Q, N),
                         lambda bh, ci, r=rep: (bh // r, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, Q, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, P, N), lambda bh, ci: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz * H, S, P), x.dtype),
            jax.ShapeDtypeStruct((Bsz * H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(af, xf, dtf, bf, cf)
    return (y.reshape(Bsz, H, S, P).transpose(0, 2, 1, 3),
            h_fin.reshape(Bsz, H, P, N))
