"""Pallas TPU kernel: fused sorted-window quantile gate threshold.

The online dispatcher's second measured hot spot (after population
fitness) is the carbon gate: for every epoch ``t``, the ``theta``-quantile
of the forecast window ``intensity[t : t + window]`` decides whether ready
tasks wait (:func:`repro.core.solvers.online_jax.sorted_windows` +
:func:`~repro.core.solvers.online_jax.quantile_threshold`).  The jnp path
materializes and sorts an ``[E, W]`` window matrix in HBM; this kernel
fuses window construction, selection and the quantile interpolation into
one pass over the horizon with the windows resident in VMEM — the ``[E,
W]`` matrix never exists outside a block.

No sort: the interpolated quantile needs only *two order statistics* per
window (``floor(theta * (n-1))`` and its successor), so the kernel selects
them by stable rank counting —

    rank[w] = #{u : x[u] < x[w]}  +  #{u < w : x[u] == x[w]}

— an O(W^2) compare-and-count per window that is pure VPU work (W <= 128
lanes), needs no sort network, and *selects* values rather than computing
with them.  Selection makes the bit-exactness contract provable: the
chosen order statistics are bitwise the values ``jnp.sort`` would place at
those positions (stable ranks are a permutation; ties share one value).
The kernel therefore returns ``(a, b, n)`` — the two selected statistics
and the valid count — and the *wrapper*
(:func:`repro.kernels.ops.gate_threshold`) applies ``np.quantile``'s lerp
in the identical expression shape :func:`quantile_threshold` uses, so
both lower to the same XLA elementwise graph (same fused-multiply-add
decisions) and kernel == jnp path bit-for-bit — the contract
``tests/test_kernels.py`` property-tests.  (Computing the lerp *inside*
the kernel came out one ulp off on some windows: the Pallas interpreter
and the jnp graph made different mul+add contraction choices.)

Windows are shifted slices of the horizon, so each epoch block loads one
``[be + W]`` stretch of the VMEM-resident trace and builds its ``[be, W]``
window block from static sub-slices — no gathers anywhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _kernel(int_ref, theta_ref, win_ref, a_ref, b_ref, n_ref, *,
            n_epochs: int, max_window: int, block_epochs: int, w_pad: int):
    """One epoch block: intensity (full, padded) -> (a, b, n) [be] each.

    int_ref: [Ipad] f32; theta_ref: [be] f32; win_ref: [1] i32 (the traced
    window length); a/b: the ``floor(theta*(n-1))``-th and successor order
    statistics of each window; n: its valid count.
    """
    be = block_epochs
    t0 = pl.multiple_of(pl.program_id(0) * be, be)
    window = win_ref[0]

    # Window block [be, Wp]: row i = intensity[t0+i : t0+i+Wp] — static
    # sub-slices of one VMEM-resident trace, shifted by one per row.
    win = jnp.stack([int_ref[pl.ds(t0 + i, w_pad)] for i in range(be)])
    off = jax.lax.broadcasted_iota(jnp.int32, (be, w_pad), 1)
    epoch = jax.lax.broadcasted_iota(jnp.int32, (be, w_pad), 0) + t0
    valid = (off < window) & (off < max_window) & (epoch + off < n_epochs)
    win = jnp.where(valid, win, jnp.inf)          # invalid slots sort last
    n = jnp.sum(valid.astype(jnp.int32), axis=1)  # [be]

    # Selection indices — the exact index arithmetic of quantile_threshold
    # (vi is one multiply and floor is exact, so lo_i/hi_i are bitwise the
    # indices the jnp path gathers at; the *lerp* happens in the wrapper).
    vi = theta_ref[...].astype(jnp.float32) * (n - 1).astype(jnp.float32)
    lo_i = jnp.floor(vi).astype(jnp.int32)
    hi_i = jnp.minimum(lo_i + 1, n - 1)

    # Stable rank of every slot; valid slots get a permutation of 0..n-1
    # (ties broken by position), +inf slots rank >= n — never selected.
    x_w = win[:, :, None]                          # [be, Wp(w), 1]
    x_u = win[:, None, :]                          # [be, 1, Wp(u)]
    before = (jax.lax.broadcasted_iota(jnp.int32, (w_pad, w_pad), 1)
              < jax.lax.broadcasted_iota(jnp.int32, (w_pad, w_pad), 0))
    rank = (jnp.sum((x_u < x_w).astype(jnp.int32), axis=2)
            + jnp.sum(((x_u == x_w) & before[None]).astype(jnp.int32),
                      axis=2))                     # [be, Wp]

    # Select the two order statistics (exactly one slot matches each rank;
    # summing the zeros is the identity, so the selection is exact).
    a_ref[...] = jnp.sum(jnp.where(rank == lo_i[:, None], win, 0.0), axis=1)
    b_ref[...] = jnp.sum(jnp.where(rank == hi_i[:, None], win, 0.0), axis=1)
    n_ref[...] = n


@functools.partial(jax.jit, static_argnames=("max_window", "block_epochs",
                                             "interpret"))
def gate_quantile_stats_pallas(intensity: jnp.ndarray, theta: jnp.ndarray,
                               window: jnp.ndarray, *, max_window: int,
                               interpret: bool, block_epochs: int = 8
                               ) -> tuple[jnp.ndarray, jnp.ndarray,
                                          jnp.ndarray]:
    """intensity [E] f32; theta [E] f32 (per-epoch — broadcast a scalar
    upstream); window scalar/[1] i32 (traced; capped by ``max_window``,
    the static width, exactly like the jnp path's array width caps it).
    Returns ``(a, b, n)``, each [E]: the two order statistics
    ``np.quantile``'s lerp interpolates between (bitwise the values
    ``sorted_windows``' sort would place at those positions) and the valid
    window length.  The wrapper (:func:`repro.kernels.ops.gate_threshold`)
    finishes the lerp in :func:`quantile_threshold`'s exact expression.

    ``interpret`` is **required**: callers go through
    :mod:`repro.kernels.ops`, where the backend-aware default lives.

    Epochs past the horizon (block padding) select from all-invalid
    windows; they are sliced off before returning.
    """
    E = intensity.shape[0]
    be = block_epochs
    Ep = -(-E // be) * be
    Wp = -(-max_window // LANE) * LANE
    Ipad = -(-(Ep + Wp) // LANE) * LANE

    intp = jnp.pad(intensity.astype(jnp.float32), (0, Ipad - E))
    thetap = jnp.pad(theta.astype(jnp.float32), (0, Ep - E))
    win1 = jnp.reshape(window.astype(jnp.int32), (1,))

    kernel = functools.partial(_kernel, n_epochs=E, max_window=max_window,
                               block_epochs=be, w_pad=Wp)
    a, b, n = pl.pallas_call(
        kernel,
        grid=(Ep // be,),
        in_specs=[
            pl.BlockSpec((Ipad,), lambda p: (0,)),
            pl.BlockSpec((be,), lambda p: (p,)),
            pl.BlockSpec((1,), lambda p: (0,)),
        ],
        out_specs=[pl.BlockSpec((be,), lambda p: (p,))] * 3,
        out_shape=[jax.ShapeDtypeStruct((Ep,), jnp.float32),
                   jax.ShapeDtypeStruct((Ep,), jnp.float32),
                   jax.ShapeDtypeStruct((Ep,), jnp.int32)],
        interpret=interpret,
    )(intp, thetap, win1)
    return a[:E], b[:E], n[:E]
