"""Pallas TPU kernel: causal flash attention (GQA via index-map folding).

Tiling (the TPU adaptation of the CUDA flash algorithm — VMEM/MXU instead
of shared-memory/warps): grid = (B*H, nq, nk) with the kv dim innermost
(sequential); q tiles [bq, dh] stay resident across the kv sweep while
m/l/acc live in VMEM scratch.  GQA never materializes repeated K/V: the
k/v BlockSpec index maps fold the query-head index onto its kv head
(``h // group``), so each kv block is streamed once per group from HBM.

Causal + sliding-window masking is positional (iota compare) on diagonal
tiles only; fully-masked tiles are skipped with ``pl.when`` — on hardware
the MXU issue is predicated away, matching the unrolled-triangle jnp path
the dry-run lowers (see models/attention.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, block_q: int, block_k: int, n_k: int,
            causal: bool, window: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = qi * block_q
    k_lo = ki * block_k
    # Tile-level skip: fully above the diagonal / fully below the window.
    live = jnp.bool_(True)
    if causal:
        live = k_lo <= q_lo + block_q - 1
        if window:
            live = jnp.logical_and(
                live, k_lo + block_k - 1 >= q_lo - window + 1)

    @pl.when(live)
    def _compute():
        q = q_ref[...].astype(jnp.float32)            # [bq, dh]
        k = k_ref[...].astype(jnp.float32)            # [bk, dh]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale     # [bq, bk]
        if causal or window:
            qpos = q_lo + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = k_lo + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            mask = kpos <= qpos if causal else jnp.full(
                (block_q, block_k), True)
            if window:
                mask = jnp.logical_and(mask, kpos > qpos - window)
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _flush():
        o_ref[...] = (acc_ref[...]
                      / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           causal: bool = True, window: int = 0,
                           block_q: int = 256, block_k: int = 256,
                           *, interpret: bool) -> jnp.ndarray:
    """q [B, H, Sq, dh]; k, v [B, KVH, Skv, dh] (H % KVH == 0).

    Returns [B, H, Sq, dh] in q.dtype.  ``interpret`` is **required**:
    callers go through :mod:`repro.kernels.ops`, where the backend-aware
    default lives (``interpret=True`` validates the kernel body on CPU;
    ``interpret=False`` compiles for TPU).
    """
    B, H, Sq, dh = q.shape
    KVH, Skv = k.shape[1], k.shape[2]
    group = H // KVH
    scale = 1.0 / math.sqrt(dh)
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, "pad seq to block multiples"
    nq, nk = Sq // bq, Skv // bk

    qf = q.reshape(B * H, Sq, dh)
    kf = k.reshape(B * KVH, Skv, dh)
    vf = v.reshape(B * KVH, Skv, dh)

    kernel = functools.partial(
        _kernel, scale=scale, block_q=bq, block_k=bk, n_k=nk,
        causal=causal, window=window)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((None, bq, dh), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((None, bk, dh),
                         lambda h, qi, ki, g=group: (h // g, ki, 0)),
            pl.BlockSpec((None, bk, dh),
                         lambda h, qi, ki, g=group: (h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, dh), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, dh)
