"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are deliberately the *naive* formulations — full score matrices,
sequential scans — so a kernel bug cannot hide behind a shared trick.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def schedule_carbon_ref(start: jnp.ndarray, dur: jnp.ndarray,
                        power: jnp.ndarray, cum: jnp.ndarray) -> jnp.ndarray:
    """start/dur [Pop, T] i32; power [Pop, T] f32; cum [H+1]. -> [Pop]."""
    e = cum.shape[0] - 1
    s0 = jnp.clip(start, 0, e)
    s1 = jnp.clip(start + dur, 0, e)
    return jnp.sum(power * (cum[s1] - cum[s0]), axis=1)


def gate_threshold_ref(intensity: jnp.ndarray, theta: jnp.ndarray,
                       window: jnp.ndarray, max_window: int) -> jnp.ndarray:
    """Per-epoch window quantile via a full [E, W] sort — the naive gate.

    Identical math to ``online_jax.sorted_windows`` + ``quantile_threshold``
    (np.quantile's lerp over a masked sort), restated here so the kernel
    test target doesn't share code with the production jnp path.
    """
    E = intensity.shape[0]
    off = jnp.arange(max_window)
    idx = jnp.arange(E)[:, None] + off[None, :]
    valid = (off[None, :] < window) & (idx < E)
    sv = jnp.sort(jnp.where(valid, intensity[jnp.clip(idx, 0, E - 1)],
                            jnp.inf), axis=1)
    n = valid.sum(1)
    vi = theta.astype(jnp.float32) * (n - 1).astype(jnp.float32)
    lo = jnp.floor(vi)
    gamma = vi - lo
    lo_i = lo.astype(jnp.int32)
    hi_i = jnp.minimum(lo_i + 1, n - 1)
    a = jnp.take_along_axis(sv, lo_i[:, None], axis=1)[:, 0]
    b = jnp.take_along_axis(sv, hi_i[:, None], axis=1)[:, 0]
    diff = b - a
    return jnp.where(gamma >= 0.5, b - diff * (1.0 - gamma),
                     a + diff * gamma)


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True, window: int = 0) -> jnp.ndarray:
    """q [B,H,S,dh]; k,v [B,KVH,Skv,dh]. Full-matrix softmax attention."""
    B, H, Sq, dh = q.shape
    KVH, Skv = k.shape[1], k.shape[2]
    rep = H // KVH
    kk = jnp.repeat(k, rep, axis=1)
    vv = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / jnp.sqrt(jnp.float32(dh))
    if causal or window:
        qpos = jnp.arange(Sq)[:, None]
        kpos = jnp.arange(Skv)[None, :]
        mask = kpos <= qpos if causal else jnp.ones((Sq, Skv), bool)
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)


def ssd_ref(x, dt, A, Bm, Cm):
    """Sequential SSD recurrence (see models/ssm.ssd_ref, re-exported with
    the kernel-facing signature). Returns (y, h_final)."""
    from repro.models.ssm import ssd_ref as _ssd_ref
    return _ssd_ref(x, dt, A, Bm, Cm)
