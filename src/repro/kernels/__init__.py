"""Pallas TPU kernels for the framework's compute hot spots.

  schedule_eval   — batched FJSP schedule carbon evaluation (the paper's
                    solver fitness hot spot)
  flash_attention — causal/windowed GQA flash attention (train/prefill)
  ssd_scan        — Mamba2 SSD chunk scan with VMEM-resident state

Each kernel: ``pl.pallas_call`` + explicit BlockSpec tiling in
``<name>.py``, a jit'd wrapper in ``ops.py``, a naive oracle in ``ref.py``.
Tests sweep shapes/dtypes in ``interpret=True`` mode (CPU executes the
kernel body); on TPU pass ``interpret=False`` (the ``ops`` default).
"""
from repro.kernels.ops import flash_attention, population_carbon, ssd_scan

__all__ = ["flash_attention", "population_carbon", "ssd_scan"]
