"""Pallas TPU kernels for the framework's compute hot spots.

  schedule_eval   — batched FJSP schedule carbon evaluation (the paper's
                    solver fitness hot spot; feeds ``population_carbon``)
  gate_quantile   — fused sorted-window quantile gate threshold (the
                    online dispatcher hot spot; feeds ``gate_threshold``)
  flash_attention — causal/windowed GQA flash attention (train/prefill)
  ssd_scan        — Mamba2 SSD chunk scan with VMEM-resident state

Each kernel: ``pl.pallas_call`` + explicit BlockSpec tiling in
``<name>.py``, a jit'd wrapper in ``ops.py``, a naive oracle in ``ref.py``.
The kernels take ``interpret`` as a *required* keyword; the backend-aware
default (interpret on CPU, compiled on TPU) lives only in ``ops.py`` —
call through the wrappers.  ``ops.kernels_enabled()`` resolves the
``REPRO_KERNELS`` switch the solvers consult; both solver paths are
bit-exact equal (see ``docs/kernels.md``).
"""
from repro.kernels.ops import (flash_attention, gate_threshold,
                               kernels_enabled, population_carbon, ssd_scan)

__all__ = ["flash_attention", "gate_threshold", "kernels_enabled",
           "population_carbon", "ssd_scan"]
