"""jit'd public wrappers around the Pallas kernels.

Each op accepts the framework-native layouts, handles padding/reshaping,
and dispatches to the kernel.  **This module is the single home of the
backend-aware ``interpret`` default** (``interpret=True`` emulates the
kernel on CPU — the validation mode — ``interpret=False`` compiles for
TPU; ``on_tpu()`` picks).  The kernels themselves take ``interpret`` as a
required keyword so a direct call can never silently run the interpreter
on a TPU — go through these wrappers.

``kernels_enabled()`` resolves the ``REPRO_KERNELS`` switch the solvers
consult when deciding between the Pallas fast path and the reference jnp
path.  Both paths are **bit-exact equal** (see ``docs/kernels.md``); the
switch trades nothing but speed.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core.instance import PackedInstance
from repro.core.objectives import task_durations
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gate_quantile import gate_quantile_stats_pallas
from repro.kernels.schedule_eval import schedule_delta_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def kernels_enabled(flag: bool | None = None) -> bool:
    """Resolve the kernel-path switch.

    Explicit argument wins; else the ``REPRO_KERNELS`` env var ("1"/"true"/
    "on"/"yes" → True, "0"/"false"/"off"/"no" → False); else default to the
    kernels exactly where they pay: on TPU.  NB the env var is read at
    *trace* time — flipping it after a jitted solver has cached its trace
    has no effect on that cache; tests and long-lived services should pass
    the explicit ``use_kernels`` argument instead.
    """
    if flag is not None:
        return flag
    env = os.environ.get("REPRO_KERNELS", "").strip().lower()
    if env in ("1", "true", "on", "yes"):
        return True
    if env in ("0", "false", "off", "no"):
        return False
    return on_tpu()


def population_carbon(inst: PackedInstance, starts: jnp.ndarray,
                      assigns: jnp.ndarray, cum: jnp.ndarray,
                      interpret: bool | None = None) -> jnp.ndarray:
    """Carbon of a candidate population. starts/assigns [Pop, T] -> [Pop].

    The solver hot spot (fitness evaluation) as one kernel call: durations
    and powers are pre-gathered per candidate (cheap XLA gathers), the
    trace integral ``cum[e1] - cum[e0]`` runs in the Pallas kernel, and
    the masked power-weighted reduction stays out here in the *same
    expression* :func:`repro.core.objectives.carbon` uses — so this equals
    ``vmap(carbon)`` bit-for-bit (the property ``tests/test_kernels.py``
    locks across scenario families x fleets x machine rules).
    """
    interpret = (not on_tpu()) if interpret is None else interpret
    dur = jax.vmap(lambda a: task_durations(inst, a))(assigns)
    delta = schedule_delta_pallas(starts, dur, cum, interpret=interpret)
    g = inst.power[assigns] * delta
    return jnp.sum(jnp.where(inst.task_mask[None, :], g, 0.0), axis=-1)


def gate_threshold(intensity: jnp.ndarray, theta: jnp.ndarray,
                   window: jnp.ndarray, max_window: int,
                   interpret: bool | None = None) -> jnp.ndarray:
    """Per-epoch quantile gate threshold [E] — the fused replacement for
    ``sorted_windows`` + ``quantile_threshold`` in the online dispatcher.

    ``theta`` may be a scalar or per-epoch [E]; ``window`` is the traced
    window length (dynamic, <= the static ``max_window`` sort width).
    Bit-exact with the jnp pair above: the kernel *selects* the two order
    statistics and the valid count, and the lerp below is op-for-op
    ``quantile_threshold``'s expression (same XLA elementwise graph, same
    fused-multiply-add decisions).  The gate *comparison* against the
    threshold stays in :mod:`repro.core.solvers.online_jax`.
    """
    interpret = (not on_tpu()) if interpret is None else interpret
    theta_vec = jnp.broadcast_to(jnp.asarray(theta, jnp.float32),
                                 intensity.shape)
    a, b, n = gate_quantile_stats_pallas(intensity, theta_vec, window,
                                         max_window=max_window,
                                         interpret=interpret)
    vi = theta_vec.astype(jnp.float32) * (n - 1).astype(jnp.float32)
    gamma = vi - jnp.floor(vi)
    diff = b - a
    # np.quantile's _lerp switches formula at gamma >= 0.5 for accuracy.
    return jnp.where(gamma >= 0.5, b - diff * (1.0 - gamma),
                     a + diff * gamma)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: int = 0,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool | None = None) -> jnp.ndarray:
    """q [B,H,S,dh]; k,v [B,KVH,Skv,dh] -> [B,H,S,dh]."""
    interpret = (not on_tpu()) if interpret is None else interpret
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  block_q=block_q, block_k=block_k,
                                  interpret=interpret)


def ssd_scan(x, dt, A, Bm, Cm, chunk: int = 64,
             interpret: bool | None = None):
    """Chunked SSD with VMEM-resident state. See ssd_scan_pallas."""
    interpret = (not on_tpu()) if interpret is None else interpret
    return ssd_scan_pallas(x, dt, A, Bm, Cm, chunk=chunk,
                           interpret=interpret)
