"""jit'd public wrappers around the Pallas kernels.

Each op accepts the framework-native layouts, handles padding/reshaping,
and dispatches to the kernel (``interpret=True`` on CPU — the validation
mode — and ``interpret=False`` on TPU).  ``on_tpu()`` picks the default.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.instance import PackedInstance
from repro.core.objectives import task_durations
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.schedule_eval import schedule_carbon_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def population_carbon(inst: PackedInstance, starts: jnp.ndarray,
                      assigns: jnp.ndarray, cum: jnp.ndarray,
                      interpret: bool | None = None) -> jnp.ndarray:
    """Carbon of a candidate population. starts/assigns [Pop, T] -> [Pop].

    The solver hot spot (fitness evaluation) as one kernel call: durations
    and powers are pre-gathered per candidate (cheap XLA gathers), the
    trace integral runs in the Pallas kernel.
    """
    interpret = (not on_tpu()) if interpret is None else interpret
    dur = jax.vmap(lambda a: task_durations(inst, a))(assigns)
    power = inst.power[assigns] * inst.task_mask[None, :]
    return schedule_carbon_pallas(starts, dur, power.astype(jnp.float32),
                                  cum, interpret=interpret)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: int = 0,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool | None = None) -> jnp.ndarray:
    """q [B,H,S,dh]; k,v [B,KVH,Skv,dh] -> [B,H,S,dh]."""
    interpret = (not on_tpu()) if interpret is None else interpret
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  block_q=block_q, block_k=block_k,
                                  interpret=interpret)


def ssd_scan(x, dt, A, Bm, Cm, chunk: int = 64,
             interpret: bool | None = None):
    """Chunked SSD with VMEM-resident state. See ssd_scan_pallas."""
    interpret = (not on_tpu()) if interpret is None else interpret
    return ssd_scan_pallas(x, dt, A, Bm, Cm, chunk=chunk,
                           interpret=interpret)
