"""Pallas TPU kernel: batched FJSP schedule carbon evaluation.

The paper's solver hot spot after vectorization is *population fitness*:
for thousands of candidate schedules per instance, integrate each task's
emissions over the carbon trace (Def. 2.3).  With the cumulative-trace
trick each task costs ``P * (cum[s+d] - cum[s])`` — two gathers.  TPUs
hate scalar gathers but love matmuls, so the kernel turns the per-tile
gather into a one-hot x trace product on the MXU/VPU:

    delta[p, t] = sum_h cum[h] * (onehot(e1) - onehot(e0))[p, t, h]

Tiling: grid over population blocks (``bp`` candidates) x task blocks
(``bt`` tasks, lane-aligned); the horizon axis H lives fully in VMEM
(a year of 15-min epochs = 35k floats = 137 KiB — trivially resident).
The [bp*bt, H] one-hot is never materialized — a ``fori_loop`` walks H in
128-wide slabs, comparing a broadcasted iota against e0/e1 and
accumulating, keeping the working set at ``bp*bt*128`` floats.  (An
earlier revision unrolled that walk as a Python loop: a year-long trace
unrolled ~274 einsums into the kernel body and blew up compile time; the
``fori_loop`` emits one body regardless of horizon.)

Bit-exactness (the contract ``repro.kernels.ops.population_carbon`` is
property-tested under): the kernel returns the per-task trace deltas
``cum[e1] - cum[e0]`` and leaves the masked, power-weighted reduction to
the wrapper, which uses the *same expression* as
:func:`repro.core.objectives.carbon`.  Each delta is exact — every slab
product has at most two nonzero terms (+cum[e1], -cum[e0]; IEEE addition
of zeros is the identity and addition is commutative, so the slab
accumulation reproduces a single f32 subtract bit-for-bit) — so the
kernel path equals the jnp gather path bitwise, not just allclose.
Start/end epochs are clamped into ``[0, H]`` exactly as the jnp oracle
clips them; candidates overrunning the trace (routine for infeasible SA
proposals before the penalty prices them) integrate to the trace edge
instead of reading zero padding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _kernel(start_ref, dur_ref, cum_ref, out_ref, *, n_slabs: int,
            horizon: int):
    """One (pop-block, task-block) tile.

    start/dur: [bp, bt] i32; cum: [Hp] (full, VMEM-resident);
    out: [bp, bt] f32 per-task deltas ``cum[e1] - cum[e0]``.
    """
    s0 = jnp.clip(start_ref[...], 0, horizon)             # [bp, bt] i32
    e1 = jnp.clip(start_ref[...] + dur_ref[...], 0, horizon)

    def slab(i, acc):
        h0 = pl.multiple_of(i * LANE, LANE)
        cum_slab = cum_ref[pl.ds(h0, LANE)]               # [LANE]
        idx = jax.lax.broadcasted_iota(jnp.int32, (LANE,), 0) + h0
        # delta contribution: +cum[e1] - cum[e0] via masked slab products.
        m1 = (e1[..., None] == idx).astype(jnp.float32)
        m0 = (s0[..., None] == idx).astype(jnp.float32)
        # <= 2 nonzero terms per (p, t) row -> the dot is exact in f32
        # (HIGHEST keeps the TPU MXU from dropping to bf16 passes).
        return acc + jnp.einsum("pth,h->pt", m1 - m0, cum_slab,
                                preferred_element_type=jnp.float32,
                                precision=jax.lax.Precision.HIGHEST)

    out_ref[...] = jax.lax.fori_loop(
        0, n_slabs, slab, jnp.zeros(s0.shape, jnp.float32))


@functools.partial(jax.jit,
                   static_argnames=("block_pop", "block_task", "interpret"))
def schedule_delta_pallas(start: jnp.ndarray, dur: jnp.ndarray,
                          cum: jnp.ndarray, *, interpret: bool,
                          block_pop: int = 8,
                          block_task: int = 128) -> jnp.ndarray:
    """start/dur [Pop, T] i32; cum [H+1] f32.  Returns the per-task trace
    deltas ``cum[clip(s+d)] - cum[clip(s)]`` as [Pop, T] f32.

    Pads Pop/T to block multiples and H+1 to a lane multiple; end epochs
    are clamped to the real horizon ``H`` (never the padding), matching
    :func:`repro.core.objectives.carbon`'s clipping bit-exactly.

    ``interpret`` is **required**: callers go through
    :mod:`repro.kernels.ops`, where the backend-aware default lives
    (``interpret=True`` emulates the kernel body on CPU — the validation
    mode — ``interpret=False`` compiles for TPU).
    """
    P, T = start.shape
    Pp = -(-P // block_pop) * block_pop
    Tp = -(-T // block_task) * block_task
    H1 = cum.shape[0]
    Hp = -(-H1 // LANE) * LANE

    pad2 = lambda a: jnp.pad(a, ((0, Pp - P), (0, Tp - T)))  # noqa: E731
    startp = pad2(start)
    durp = pad2(dur)
    cump = jnp.pad(cum, (0, Hp - H1))

    grid = (Pp // block_pop, Tp // block_task)
    kernel = functools.partial(_kernel, n_slabs=Hp // LANE, horizon=H1 - 1)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_pop, block_task), lambda p, t: (p, t)),
            pl.BlockSpec((block_pop, block_task), lambda p, t: (p, t)),
            pl.BlockSpec((Hp,), lambda p, t: (0,)),
        ],
        out_specs=pl.BlockSpec((block_pop, block_task), lambda p, t: (p, t)),
        out_shape=jax.ShapeDtypeStruct((Pp, Tp), jnp.float32),
        interpret=interpret,
    )(startp, durp, cump)
    return out[:P, :T]
