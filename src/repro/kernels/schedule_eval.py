"""Pallas TPU kernel: batched FJSP schedule carbon evaluation.

The paper's solver hot spot after vectorization is *population fitness*:
for thousands of candidate schedules per instance, integrate each task's
emissions over the carbon trace (Def. 2.3).  With the cumulative-trace
trick each task costs ``P * (cum[s+d] - cum[s])`` — two gathers.  TPUs
hate scalar gathers but love matmuls, so the kernel turns the per-tile
gather into a one-hot x trace product on the MXU/VPU:

    delta[p, t] = sum_h cum[h] * (onehot(e1) - onehot(e0))[p, t, h]

Tiling: grid over population blocks (``bp`` candidates) x task blocks
(``bt`` tasks, lane-aligned); the horizon axis H lives fully in VMEM
(a year of 15-min epochs = 35k floats = 137 KiB — trivially resident).
Per-tile VMEM: bp*bt*(3 i32/f32 inputs) + the [bp*bt, H] one-hot is never
materialized — the kernel loops over H in 128-wide slabs, comparing a
broadcasted iota against e0/e1 and accumulating, keeping the working set
at ``bp*bt*128`` floats.

Accumulation across task blocks uses the sequential innermost grid dim
(scratch carries the per-candidate partial sums; flushed at the last
task block).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128


def _kernel(start_ref, dur_ref, power_ref, cum_ref, out_ref, acc_ref,
            *, n_task_blocks: int, horizon: int):
    """One (pop-block, task-block) tile.

    start/dur/power: [bp, bt]; cum: [H1] (full); out: [bp]; acc: [bp] f32.
    """
    tb = pl.program_id(1)

    @pl.when(tb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    s0 = start_ref[...]
    e1 = s0 + dur_ref[...]                        # [bp, bt] i32
    pw = power_ref[...]                           # [bp, bt] f32 (0 = masked)

    partial = jnp.zeros(s0.shape, jnp.float32)
    for h0 in range(0, horizon, LANE):
        cum_slab = cum_ref[h0:h0 + LANE]          # [LANE]
        idx = jax.lax.broadcasted_iota(jnp.int32, (LANE,), 0) + h0
        # delta contribution: +cum[e1] - cum[e0] via masked slab sums.
        m1 = (e1[..., None] == idx).astype(jnp.float32)
        m0 = (s0[..., None] == idx).astype(jnp.float32)
        partial += jnp.einsum("pth,h->pt", m1 - m0, cum_slab,
                              preferred_element_type=jnp.float32)
    acc_ref[...] += jnp.sum(partial * pw, axis=1)

    @pl.when(tb == n_task_blocks - 1)
    def _flush():
        out_ref[...] = acc_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("block_pop", "block_task", "interpret"))
def schedule_carbon_pallas(start: jnp.ndarray, dur: jnp.ndarray,
                           power: jnp.ndarray, cum: jnp.ndarray,
                           block_pop: int = 8, block_task: int = 128,
                           interpret: bool = True) -> jnp.ndarray:
    """start/dur [Pop, T] i32; power [Pop, T] f32 (0 for padded/masked
    tasks); cum [H+1] f32.  Returns carbon [Pop] f32.

    Pads Pop/T to block multiples and H+1 to a lane multiple.  ``interpret``
    runs the kernel body on CPU (how tests validate it); on TPU pass
    ``interpret=False``.
    """
    P, T = start.shape
    Pp = -(-P // block_pop) * block_pop
    Tp = -(-T // block_task) * block_task
    H1 = cum.shape[0]
    Hp = -(-H1 // LANE) * LANE

    pad2 = lambda a, v=0: jnp.pad(a, ((0, Pp - P), (0, Tp - T)),  # noqa: E731
                                  constant_values=v)
    startp = pad2(start)
    durp = pad2(dur)
    powerp = pad2(power)          # padded tasks have power 0 -> no effect
    cump = jnp.pad(cum, (0, Hp - H1))

    grid = (Pp // block_pop, Tp // block_task)
    kernel = functools.partial(_kernel, n_task_blocks=grid[1], horizon=Hp)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_pop, block_task), lambda p, t: (p, t)),
            pl.BlockSpec((block_pop, block_task), lambda p, t: (p, t)),
            pl.BlockSpec((block_pop, block_task), lambda p, t: (p, t)),
            pl.BlockSpec((Hp,), lambda p, t: (0,)),
        ],
        out_specs=pl.BlockSpec((block_pop,), lambda p, t: (p,)),
        out_shape=jax.ShapeDtypeStruct((Pp,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_pop,), jnp.float32)],
        interpret=interpret,
    )(startp, durp, powerp, cump)
    return out[:P]
