"""Roofline-derived task durations and energy for ML jobs as FJSP tasks.

This is the (A)<->(B) bridge of DESIGN.md §2: each assigned architecture's
dry-run roofline (FLOPs/bytes/collective seconds per step) prices a
"train N steps of arch X" or "serve N requests of arch X" task on a menu
of heterogeneous TPU slices — the machine classes the paper's scheduler
(repro.core) then places tasks on.

Machine classes mirror the paper's heterogeneous setup (5 power/speed
tiers) but are grounded in v5e slices: speed scales with chip count times
a utilization factor (small slices run at higher MFU — less collective
overhead — exactly the speed/efficiency tension §3.2 of the paper probes).

If a dry-run JSON for the (arch, shape) cell exists the step time comes
from its roofline terms; otherwise from the analytic 6·N·D estimate.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os

from repro.models.common import ArchConfig, SHAPES

PEAK_FLOPS = 197e12           # bf16 / chip
HBM_BW = 819e9                # bytes/s / chip
LINK_BW = 50e9                # bytes/s / link
CHIP_POWER_KW = 0.30          # v5e chip + share of host/interconnect


@dataclasses.dataclass(frozen=True)
class MachineClass:
    name: str
    chips: int
    utilization: float            # achieved fraction of peak (MFU-ish)

    @property
    def power_kw(self) -> float:
        return self.chips * CHIP_POWER_KW

    @property
    def throughput(self) -> float:  # effective FLOP/s
        return self.chips * PEAK_FLOPS * self.utilization


# Five tiers, paper-style: speeds ~ {1/3, 1/2, 1, 4/3, 2} x the 64-chip
# baseline; smaller slices are more efficient per chip.
TPU_V5E_CLASSES: tuple[MachineClass, ...] = (
    MachineClass("v5e-16", 16, 0.55),
    MachineClass("v5e-32", 32, 0.50),
    MachineClass("v5e-64", 64, 0.45),
    MachineClass("v5e-96", 96, 0.42),
    MachineClass("v5e-160", 160, 0.38),
)

_DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")


def _dryrun_step_flops(arch: str, shape: str) -> float | None:
    """Per-chip FLOPs x 256 chips from the single-pod dry-run, if present."""
    path = os.path.join(_DRYRUN_DIR, f"{arch}__{shape}__pod16x16.json")
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok" or "flops" not in rec:
            return None
        return float(rec["flops"]) * 256
    except Exception:
        return None


def step_flops(cfg: ArchConfig, shape: str) -> float:
    """Total FLOPs of one step of the (arch, shape) cell."""
    measured = _dryrun_step_flops(cfg.name, shape)
    if measured is not None:
        return measured
    sc = SHAPES[shape]
    tokens = sc.batch * (sc.seq if sc.kind != "decode" else 1)
    n = cfg.active_param_count()
    mult = 6.0 if sc.kind == "train" else 2.0
    return mult * n * tokens


def task_profile(cfg: ArchConfig, shape: str, n_steps: int,
                 machine: MachineClass, epoch_hours: float = 0.25
                 ) -> tuple[int, float]:
    """(duration_epochs, energy_kwh) of running ``n_steps`` of the cell on
    ``machine`` — the p_{t,m} / E_{t,m} inputs of the paper's Appendix A."""
    work = step_flops(cfg, shape) * n_steps
    seconds = work / machine.throughput
    epochs = max(1, round(seconds / (epoch_hours * 3600)))
    energy = machine.power_kw * epochs * epoch_hours
    return epochs, energy


def baseline_durations(cfg: ArchConfig, shape: str, n_steps: int,
                       classes=TPU_V5E_CLASSES) -> dict[str, int]:
    return {m.name: task_profile(cfg, shape, n_steps, m)[0] for m in classes}
