"""ML batch workloads as FJSP instances (DAG templates over real archs).

Three job templates, mirroring both the paper's Fig. 3 structures and its
motivating examples (§2 "Example Job: Offline Inference"):

  offline_inference : load -> infer (xN shards, fan-out) -> store
  train_pipeline    : data_prep -> train -> eval  (chain; the train task is
                      `n_steps` of a real (arch x shape) cell)
  finetune_sweep    : prep -> {k parallel finetune branches} (branch)

Each task's per-machine duration/energy comes from the roofline energy
model, so the generated instances are paper-shaped (exponential-ish task
lengths, 15-min epochs) but grounded in the actual architectures this
framework trains/serves.  ``make_cluster_instance`` returns a standard
:class:`repro.core.instance.Instance`, so every solver in ``repro.core``
(and the executor's re-solve) consumes it unchanged.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.cluster.energy_model import (MachineClass, TPU_V5E_CLASSES,
                                        task_profile)
from repro.configs import ARCHS
from repro.core.instance import Instance, Job
from repro.models.common import ArchConfig

TEMPLATES = ("offline_inference", "train_pipeline", "finetune_sweep")


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    template: str
    arch: str
    shape: str
    n_steps: int              # steps of the core (train/infer) tasks
    arrival: int = 0          # epoch


def _template_tasks(spec: WorkloadSpec, rng: np.random.Generator
                    ) -> tuple[tuple[int, ...], tuple[tuple[int, int], ...],
                               list[float]]:
    """Returns (core_steps per task, edges, io_scale per task).

    io_scale < 1 marks light CPU-ish stages (load/store/eval) whose
    duration doesn't scale with the accelerator's speed tier.
    """
    if spec.template == "offline_inference":
        shards = int(rng.integers(2, 5))
        steps = [0] + [spec.n_steps] * shards + [0]
        edges = [(0, i) for i in range(1, shards + 1)] + \
                [(i, shards + 1) for i in range(1, shards + 1)]
        io = [0.3] + [1.0] * shards + [0.3]
        return tuple(steps), tuple(edges), io
    if spec.template == "train_pipeline":
        steps = [0, spec.n_steps, max(spec.n_steps // 8, 1)]
        return tuple(steps), ((0, 1), (1, 2)), [0.3, 1.0, 1.0]
    if spec.template == "finetune_sweep":
        k = int(rng.integers(2, 4))
        steps = [0] + [spec.n_steps] * k
        return tuple(steps), tuple((0, i) for i in range(1, k + 1)), \
            [0.3] + [1.0] * k
    raise ValueError(f"unknown template {spec.template!r}")


def make_cluster_instance(specs: list[WorkloadSpec],
                          classes: tuple[MachineClass, ...] = TPU_V5E_CLASSES,
                          seed: int = 0) -> Instance:
    """Build an FJSP Instance whose baseline durations are epochs on the
    *middle* class; the Instance speed table rescales per tier (the same
    mechanism as the paper's heterogeneous setup)."""
    rng = np.random.default_rng(seed)
    base = classes[len(classes) // 2]
    jobs = []
    for spec in specs:
        cfg: ArchConfig = ARCHS[spec.arch]
        core_epochs, _ = task_profile(cfg, spec.shape, spec.n_steps, base)
        steps, edges, io = _template_tasks(spec, rng)
        durs = []
        for s, scale in zip(steps, io):
            if s == 0:        # IO/prep stage: short, speed-independent-ish
                durs.append(max(1, int(round(core_epochs * scale * 0.2))))
            else:
                d = task_profile(cfg, spec.shape, s, base)[0]
                durs.append(max(1, d))
        jobs.append(Job(arrival=spec.arrival,
                        base_durations=tuple(durs), edges=edges))
    speeds = tuple(m.throughput / base.throughput for m in classes)
    powers = tuple(m.power_kw for m in classes)
    return Instance(jobs=tuple(jobs), powers_kw=powers, speeds=speeds)


def sample_daily_batch(rng: np.random.Generator, n_jobs: int = 8,
                       arrival_horizon: int = 96) -> list[WorkloadSpec]:
    """A day's batch: random mix of templates over the smaller archs."""
    small = ["qwen1.5-0.5b", "mamba2-370m", "hymba-1.5b", "minitron-4b",
             "whisper-base"]
    out = []
    for _ in range(n_jobs):
        out.append(WorkloadSpec(
            template=TEMPLATES[rng.integers(len(TEMPLATES))],
            arch=small[rng.integers(len(small))],
            shape="train_4k",
            n_steps=int(rng.integers(50, 400)),
            arrival=int(rng.integers(0, arrival_horizon))))
    return out
