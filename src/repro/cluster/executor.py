"""Simulated cluster executor: faults, stragglers, elastic re-solve.

Runs a solved schedule epoch by epoch and exercises the fault-tolerance
story the 1000-node posture requires:

* **Machine failure** — at a configured (or sampled) epoch a machine dies.
  Tasks running there lose progress since their last checkpoint; the
  executor *re-solves* the remaining DAG from the current epoch on the
  surviving machines (elastic scaling) using the same bi-level carbon
  solver that produced the original plan — the paper's scheduler doubles
  as the recovery planner.
* **Checkpoint/restart** — ML tasks checkpoint every ``ckpt_epochs``; a
  restarted task re-runs only the un-checkpointed suffix (matching the
  Trainer's resume path at the job level).
* **Stragglers** — a task exceeding ``straggler_factor`` x its expected
  duration is duplicate-issued on the earliest-free machine; the first
  copy to finish wins (speculative execution, Graham-style list fallback).

The report compares planned vs. achieved makespan/carbon/energy, so tests
can assert recovery overhead bounds.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import validate
from repro.core.instance import EPOCH_HOURS, PackedInstance
from repro.core.solvers.annealing import SAConfig
from repro.core.solvers.bilevel import solve_bilevel


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    fail_machine: int = -1          # -1: no failure
    fail_epoch: int = 0
    straggle_task: int = -1         # task index that runs slow
    straggle_factor: float = 1.0    # its actual/expected duration ratio


@dataclasses.dataclass
class ExecutionReport:
    planned_makespan: int
    achieved_makespan: int
    planned_carbon: float
    achieved_carbon: float
    achieved_energy: float
    n_resolves: int
    n_restarts: int
    n_speculative: int

    @property
    def recovery_overhead(self) -> float:
        return (self.achieved_makespan / max(self.planned_makespan, 1)) - 1.0


class ClusterExecutor:
    def __init__(self, inst: PackedInstance, cum: jnp.ndarray,
                 ckpt_epochs: int = 4, straggler_threshold: float = 1.5,
                 stretch: float = 1.5, seed: int = 0):
        self.inst = inst
        self.cum = np.asarray(cum, np.float64)
        self.ckpt_epochs = ckpt_epochs
        self.straggler_threshold = straggler_threshold
        self.stretch = stretch
        self.key = jax.random.key(seed)

    # -- planning ------------------------------------------------------------
    def plan(self) -> dict:
        res = solve_bilevel(self.inst, jnp.asarray(self.cum, jnp.float32),
                            self.key, objective="carbon",
                            stretch=self.stretch,
                            cfg1=SAConfig(pop=64, iters=60),
                            cfg2=SAConfig(pop=64, iters=60))
        return {"start": np.asarray(res.optimized.start),
                "assign": np.asarray(res.optimized.assign),
                "makespan": int(res.optimized.makespan),
                "carbon": float(res.optimized.carbon)}

    # -- simulation ----------------------------------------------------------
    def execute(self, plan: dict, fault: FaultPlan = FaultPlan()
                ) -> ExecutionReport:
        inst = self.inst
        T = inst.T
        dur = np.asarray(inst.dur)
        power = np.asarray(inst.power)
        mask = np.asarray(inst.task_mask)
        pred = np.asarray(inst.pred)
        arrival = np.asarray(inst.arrival)
        M = dur.shape[1]

        start = plan["start"].copy().astype(np.int64)
        assign = plan["assign"].copy().astype(np.int64)
        exp_dur = dur[np.arange(T), assign].astype(np.int64)
        act_dur = exp_dur.copy()
        if fault.straggle_task >= 0:
            act_dur[fault.straggle_task] = int(np.ceil(
                exp_dur[fault.straggle_task] * fault.straggle_factor))

        done = np.zeros(T, bool)
        done[~mask] = True
        progress = np.zeros(T, np.int64)     # epochs completed (checkpointed)
        running: dict[int, tuple[int, int]] = {}   # task -> (machine, since)
        spec_copy: dict[int, tuple[int, int]] = {}  # speculative duplicates
        alive = np.ones(M, bool)
        carbon = 0.0
        energy = 0.0
        n_resolves = n_restarts = n_spec = 0
        t = 0
        horizon = len(self.cum) - 1

        def ready(tk: int) -> bool:
            return (mask[tk] and not done[tk] and tk not in running
                    and arrival[tk] <= t
                    and all(done[u] for u in range(T) if pred[tk, u]))

        while not done[mask].all() and t < horizon - 1:
            # 1. machine failure event
            if fault.fail_machine >= 0 and t == fault.fail_epoch and \
                    alive[fault.fail_machine]:
                alive[fault.fail_machine] = False
                lost = [tk for tk, (m, _) in running.items()
                        if m == fault.fail_machine]
                for tk in lost:
                    del running[tk]
                    # restart from last checkpoint
                    progress[tk] = (progress[tk] // self.ckpt_epochs) \
                        * self.ckpt_epochs
                    n_restarts += 1
                # elastic re-solve of the remaining DAG on survivors
                start, assign = self._resolve(t, done, progress, alive,
                                              assign)
                n_resolves += 1

            # 2. start tasks scheduled for <= t
            for tk in range(T):
                if ready(tk) and start[tk] <= t and alive[assign[tk]] and \
                        not any(m == assign[tk] for m, _ in running.values()):
                    running[tk] = (int(assign[tk]), t)

            # 3. advance one epoch: accrue energy/carbon, progress
            inten = self.cum[min(t + 1, horizon)] - self.cum[min(t, horizon)]
            for tk, (m, _) in list(running.items()):
                energy += power[m] * EPOCH_HOURS
                carbon += power[m] * inten
                progress[tk] += 1
                need = act_dur[tk] if tk not in spec_copy else exp_dur[tk]
                if progress[tk] >= need:
                    done[tk] = True
                    del running[tk]
                    spec_copy.pop(tk, None)
                elif (tk not in spec_copy
                      and progress[tk] > self.straggler_threshold
                      * exp_dur[tk]):
                    free = [mm for mm in range(M) if alive[mm]
                            and mm != m and not any(
                                rm == mm for rm, _ in running.values())]
                    if free:
                        spec_copy[tk] = (free[0], t)   # duplicate-issue
                        act_dur[tk] = progress[tk] + exp_dur[tk] // 2
                        n_spec += 1
            t += 1

        return ExecutionReport(
            planned_makespan=plan["makespan"],
            achieved_makespan=t,
            planned_carbon=plan["carbon"],
            achieved_carbon=float(carbon),
            achieved_energy=float(energy),
            n_resolves=n_resolves, n_restarts=n_restarts,
            n_speculative=n_spec)

    # -- elastic re-solve ------------------------------------------------------
    def _resolve(self, t: int, done: np.ndarray, progress: np.ndarray,
                 alive: np.ndarray, assign: np.ndarray
                 ) -> tuple[np.ndarray, np.ndarray]:
        """Re-plan the unfinished tasks from epoch ``t`` on live machines:
        completed work is modeled by shrinking remaining durations; dead
        machines are disallowed.

        Every re-solve is validated in-line through the shared feasibility
        source (:func:`repro.core.validate.total_violations`, Eqs. 4-8 on
        the transformed instance) before the executor trusts it — a
        recovery plan that silently violated precedence or placed work on
        a dead machine would corrupt the rest of the simulation.
        """
        inst = self.inst
        dur = np.asarray(inst.dur).copy()
        mask = np.asarray(inst.task_mask)
        T = inst.T
        rem = np.maximum(
            dur[np.arange(T), assign] - progress, 1)
        scale = rem / np.maximum(dur[np.arange(T), assign], 1)
        dur = np.maximum((dur * scale[:, None]).astype(np.int32), 1)
        dur[done & mask] = 1
        allowed = np.asarray(inst.allowed) & alive[None, :]
        arrival = np.maximum(np.asarray(inst.arrival), t)
        arrival[done & mask] = t
        new_inst = PackedInstance(
            dur=jnp.asarray(dur), allowed=jnp.asarray(allowed),
            pred=inst.pred, arrival=jnp.asarray(arrival.astype(np.int32)),
            job=inst.job, task_mask=inst.task_mask, power=inst.power)
        self.key, k = jax.random.split(self.key)
        res = solve_bilevel(new_inst, jnp.asarray(self.cum, jnp.float32),
                            k, objective="carbon", stretch=self.stretch,
                            cfg1=SAConfig(pop=32, iters=40),
                            cfg2=SAConfig(pop=32, iters=40))
        start = np.asarray(res.optimized.start).astype(np.int64)
        new_assign = np.asarray(res.optimized.assign).astype(np.int64)
        v = int(validate.total_violations(
            new_inst, jnp.asarray(start.astype(np.int32)),
            jnp.asarray(new_assign.astype(np.int32))))
        if v != 0:
            raise RuntimeError(
                f"elastic re-solve at epoch {t} produced an infeasible "
                f"schedule (violation mass {v}) — refusing to execute it")
        return start, new_assign
