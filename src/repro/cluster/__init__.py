from repro.cluster.energy_model import (MachineClass, TPU_V5E_CLASSES,
                                        task_profile)
from repro.cluster.executor import ClusterExecutor, ExecutionReport
from repro.cluster.workloads import WorkloadSpec, make_cluster_instance

__all__ = ["MachineClass", "TPU_V5E_CLASSES", "task_profile",
           "ClusterExecutor", "ExecutionReport", "WorkloadSpec",
           "make_cluster_instance"]
