"""Mixture-of-Experts FFN with shard_map expert parallelism.

Dispatch is sort-free scatter-to-capacity (MaxText-style "dropping" MoE):
each device holds ``E_loc = E / model`` experts and the *full* token set of
its data shard (activations are replicated over the tensor axis, the
standard TP region invariant).  Every device therefore dispatches locally —
no all-to-all — computes its experts' FFN on a ``[E_loc, C, D]`` capacity
buffer, scatters results back to token order, and a single ``psum`` over
``"model"`` combines the k expert contributions (the same all-reduce a
dense TP MLP needs, so MoE costs one collective, not three).

With ``zero_stage >= 3`` the expert weights additionally arrive sharded on
their ``D`` dim over the data axes and are all-gathered on entry (explicit
ZeRO-3; the gather bytes show up in the roofline collective term).

``moe_ref`` is the exact dense oracle (every expert on every token) used by
tests; with a capacity factor large enough to avoid drops the EP path must
match it to bf16 tolerance.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ArchConfig
from repro.models.layers import activation, cast
from repro.models.params import ParamDef
from repro.models.parallel import ParallelCfg
# The jax.shard_map / jax.experimental.shard_map API bridge lives with the
# instance-axis sharding layer; the EP psum makes this body's output fully
# replicated, which the bridge's disabled checker can't prove (see there).
from repro.shard.compat import shard_map_compat as _shard_map


def moe_defs(cfg: ArchConfig) -> dict:
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    glu = 2 if cfg.act.endswith("_glu") else 1
    defs = {
        "router": ParamDef((D, E), ("embed", None), init="scaled"),
        # Expert weights carry their own logical name for the d_model dim
        # ("expert_embed") so ZeRO can shard the expert bank over data
        # without touching the dense layers (zero_stage=2, the kimi mode).
        "w_in": ParamDef((E, D, glu, F),
                         ("expert", "expert_embed", None, "expert_mlp"),
                         init="scaled"),
        "w_out": ParamDef((E, F, D), ("expert", "expert_mlp",
                                      "expert_embed"), init="scaled"),
    }
    if cfg.n_shared_experts:
        S = cfg.n_shared_experts
        defs["shared_in"] = ParamDef((D, glu, S * F), ("embed", None, "mlp"),
                                     init="scaled")
        defs["shared_out"] = ParamDef((S * F, D), ("mlp", "embed"),
                                      init="scaled")
    return defs


def _capacity(n_tokens: int, k: int, n_experts: int, factor: float) -> int:
    c = int(math.ceil(factor * k * n_tokens / n_experts))
    return max(4, -(-c // 4) * 4)


def _route(x2d: jnp.ndarray, router: jnp.ndarray, k: int):
    """x2d [N, D] -> (ids [N,k] int32, weights [N,k] f32, probs [N,E] f32)."""
    logits = jnp.einsum("nd,de->ne", x2d, cast(router),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return ids.astype(jnp.int32), w, probs


def _expert_ffn(buf: jnp.ndarray, w_in: jnp.ndarray, w_out: jnp.ndarray,
                act: str) -> jnp.ndarray:
    """buf [E, C, D] -> [E, C, D] through each expert's FFN."""
    h = jnp.einsum("ecd,edgf->ecgf", buf, w_in,
                   preferred_element_type=jnp.float32)
    h = activation(h, act).astype(buf.dtype)
    return jnp.einsum("ecf,efd->ecd", h, w_out)


def _dispatch_compute(x2d, ids, wgt, w_in, w_out, *, e_first: jnp.ndarray,
                      e_local: int, capacity: int, act: str) -> jnp.ndarray:
    """Scatter tokens routed to experts [e_first, e_first+e_local) into a
    capacity buffer, run the FFNs, scatter back. Returns [N, D] (partial —
    only this device's experts' contributions)."""
    N, D = x2d.shape
    k = ids.shape[1]
    flat_e = ids.reshape(-1) - e_first                       # [N*k]
    tok = jnp.repeat(jnp.arange(N, dtype=jnp.int32), k)
    in_range = (flat_e >= 0) & (flat_e < e_local)
    le = jnp.where(in_range, flat_e, e_local)                # drop bucket
    # Rank of each slot within its expert (exclusive running count).
    onehot = jax.nn.one_hot(le, e_local + 1, dtype=jnp.int32)
    rank = (jnp.cumsum(onehot, axis=0) - onehot)[jnp.arange(le.shape[0]), le]
    keep = in_range & (rank < capacity)
    dest = jnp.where(keep, le * capacity + rank, e_local * capacity)
    buf = jnp.zeros((e_local * capacity + 1, D), x2d.dtype)
    buf = buf.at[dest].add(jnp.where(keep[:, None], x2d[tok], 0))
    out_buf = _expert_ffn(buf[:-1].reshape(e_local, capacity, D),
                          w_in, w_out, act)
    y_slot = out_buf.reshape(e_local * capacity, D)[
        jnp.minimum(dest, e_local * capacity - 1)]
    y_slot = jnp.where(keep[:, None], y_slot, 0) * wgt.reshape(-1)[:, None]
    y = jnp.zeros_like(x2d).at[tok].add(y_slot.astype(x2d.dtype))
    return y


def aux_loss(probs: jnp.ndarray, ids: jnp.ndarray, n_experts: int
             ) -> jnp.ndarray:
    """Switch-style load-balancing loss: E * <f_e, p_e>."""
    pe = probs.reshape(-1, n_experts).mean(0)
    fe = jnp.zeros(n_experts).at[ids.reshape(-1)].add(1.0)
    fe = fe / jnp.maximum(fe.sum(), 1.0)
    return n_experts * jnp.sum(pe * fe)


def moe_apply(p: dict, x: jnp.ndarray, cfg: ArchConfig, par: ParallelCfg
              ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x [B, S, D] -> (y [B, S, D], aux_loss scalar)."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    x2d = x.reshape(-1, D)
    ids, wgt, probs = _route(x2d, p["router"], k)
    aux = aux_loss(probs, ids, E)

    msize = par.model_axis_size
    if par.mesh is None or not par.moe_ep or msize == 1:
        cap = _capacity(x2d.shape[0], k, E, cfg.capacity_factor)
        y = _dispatch_compute(
            x2d, ids, wgt, cast(p["w_in"]), cast(p["w_out"]),
            e_first=jnp.int32(0), e_local=E, capacity=cap, act=cfg.act)
    else:
        y = _moe_ep(x2d, ids, wgt, p["w_in"], p["w_out"], cfg, par)
    y = y.reshape(B, S, D)

    if cfg.n_shared_experts:
        h = jnp.einsum("bsd,dgf->bsgf", x, cast(p["shared_in"]))
        h = activation(h, cfg.act).astype(x.dtype)
        y = y + jnp.einsum("bsf,fd->bsd", h, cast(p["shared_out"]))
    return y, aux


def _moe_ep(x2d, ids, wgt, w_in, w_out, cfg: ArchConfig, par: ParallelCfg):
    """shard_map expert-parallel path (see module docstring)."""
    mesh = par.mesh
    E, k = cfg.n_experts, cfg.experts_per_token
    e_local = E // par.model_axis_size
    rules = par.effective_rules()
    fsdp = rules.mesh_axes("expert_embed")   # None unless zero_stage >= 2
    bt = par.batch_axes or None
    tok_spec = P(bt, None)
    w_in_spec = P("model", fsdp, None, None)
    w_out_spec = P("model", None, fsdp)

    n_shard = x2d.shape[0] // math.prod(
        mesh.shape[a] for a in (par.batch_axes or ()))
    cap = _capacity(n_shard, k, E, cfg.capacity_factor)

    def body(x_loc, ids_loc, wgt_loc, w_in_loc, w_out_loc):
        # Cast BEFORE the ZeRO-3 gather: the all-gather then moves bf16,
        # not fp32 — half the wire bytes (§Perf, kimi iteration 1).
        w_in_loc, w_out_loc = cast(w_in_loc), cast(w_out_loc)
        if fsdp is not None:
            w_in_loc = jax.lax.all_gather(w_in_loc, fsdp, axis=1, tiled=True)
            w_out_loc = jax.lax.all_gather(w_out_loc, fsdp, axis=2,
                                           tiled=True)
        e_first = jax.lax.axis_index("model") * e_local
        y = _dispatch_compute(
            x_loc, ids_loc, wgt_loc, w_in_loc, w_out_loc,
            e_first=e_first, e_local=e_local, capacity=cap, act=cfg.act)
        return jax.lax.psum(y, "model")

    fn = _shard_map(
        body, mesh=mesh,
        in_specs=(tok_spec, tok_spec, tok_spec, w_in_spec, w_out_spec),
        out_specs=tok_spec)
    return fn(x2d, ids, wgt, w_in, w_out)


def moe_ref(p: dict, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """Dense oracle: every expert on every token, exact top-k combine."""
    B, S, D = x.shape
    x2d = x.reshape(-1, D)
    ids, wgt, _ = _route(x2d, p["router"], cfg.experts_per_token)
    h = jnp.einsum("nd,edgf->negf", x2d, cast(p["w_in"]))
    h = activation(h, cfg.act).astype(x2d.dtype)
    y_all = jnp.einsum("nef,efd->ned", h, cast(p["w_out"]))  # [N, E, D]
    sel = jnp.take_along_axis(y_all, ids[..., None], axis=1)  # [N, k, D]
    y = (sel * wgt[..., None].astype(sel.dtype)).sum(1)
    if cfg.n_shared_experts:
        hs = jnp.einsum("nd,dgf->ngf", x2d, cast(p["shared_in"]))
        hs = activation(hs, cfg.act).astype(x2d.dtype)
        y = y + jnp.einsum("nf,fd->nd", hs, cast(p["shared_out"]))
    return y.reshape(B, S, D)
