"""Architecture config schema, shape suite, and input specs.

Every assigned architecture is an :class:`ArchConfig`; ``configs/<id>.py``
instantiates the exact published dims.  ``reduced()`` shrinks any config to a
CPU-smoke-testable size of the same family.  ``input_specs`` builds the
``jax.ShapeDtypeStruct`` stand-ins consumed by the multi-pod dry-run (no
device allocation ever happens for the full configs).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

FAMILIES = ("dense", "moe", "ssm", "hybrid", "encdec")


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int                # query heads (0 for pure ssm)
    n_kv_heads: int
    d_ff: int                   # dense MLP width, or per-expert width for moe
    vocab_size: int
    head_dim: int = 128
    qkv_bias: bool = False
    qk_norm: bool = False
    tie_embeddings: bool = False
    act: str = "silu_glu"       # silu_glu | gelu | relu2
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    pos: str = "rope"           # rope | sinusoidal
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_groups: int = 1
    ssm_chunk: int = 256
    # --- hybrid / attention variants ---
    attn_window: int = 0        # 0 = full causal; >0 = sliding window
    # --- encoder-decoder / modality frontends (STUBS per assignment) ---
    n_encoder_layers: int = 0
    frontend: str = "none"      # none | audio_stub | vision_stub
    n_frontend_tokens: int = 0  # patch/frame embeddings prepended (vlm)
    # --- numerics / padding ---
    vocab_pad_multiple: int = 2048
    notes: str = ""

    # ----- derived -----
    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def d_inner(self) -> int:   # ssm inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_state else 0

    @property
    def conv_dim(self) -> int:
        # mamba2 conv covers x + B + C streams
        return self.d_inner + 2 * self.ssm_groups * self.ssm_state

    @property
    def attn_dim(self) -> int:  # hybrid splits d_model work between mixers
        return self.n_heads * self.head_dim

    def param_count(self) -> int:
        """Analytic parameter count (used for 6*N*D roofline checks)."""
        D, V = self.d_model, self.padded_vocab
        n = V * D  # embed
        if not self.tie_embeddings:
            n += V * D
        per_layer = 0
        if self.n_heads:
            q = D * self.n_heads * self.head_dim
            kv = 2 * D * self.n_kv_heads * self.head_dim
            o = self.n_heads * self.head_dim * D
            per_layer += q + kv + o
            if self.qkv_bias:
                per_layer += (self.n_heads + 2 * self.n_kv_heads) * self.head_dim
        if self.family == "moe":
            glu = 3 if self.act == "silu_glu" else 2
            per_layer += self.n_experts * glu * D * self.d_ff
            per_layer += self.n_shared_experts * glu * D * self.d_ff
            per_layer += D * self.n_experts  # router
        elif self.d_ff:
            glu = 3 if self.act == "silu_glu" else 2
            per_layer += glu * D * self.d_ff
        if self.ssm_state:
            di, G, N, H = self.d_inner, self.ssm_groups, self.ssm_state, self.ssm_heads
            per_layer += D * (2 * di + 2 * G * N + H)   # in_proj
            per_layer += self.ssm_conv * self.conv_dim  # conv
            per_layer += 2 * H + di                     # A_log, D, dt_bias-ish
            per_layer += di * D                         # out_proj
        per_layer += 2 * D  # norms
        layers = self.n_layers + self.n_encoder_layers
        n += layers * per_layer
        if self.n_encoder_layers:  # cross-attention in decoder layers
            n += self.n_layers * (2 * D * self.n_kv_heads * self.head_dim
                                  + 2 * D * self.n_heads * self.head_dim)
        return n

    def active_param_count(self) -> int:
        """Params touched per token (= param_count for non-MoE)."""
        if self.family != "moe":
            return self.param_count()
        glu = 3 if self.act == "silu_glu" else 2
        routed_all = self.n_layers * self.n_experts * glu * self.d_model * self.d_ff
        routed_active = self.n_layers * self.experts_per_token * glu * \
            self.d_model * self.d_ff
        return self.param_count() - routed_all + routed_active

    def reduced(self) -> "ArchConfig":
        """Same-family tiny config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=2,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            d_model=128,
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            vocab_pad_multiple=64,
            n_experts=min(self.n_experts, 8),
            experts_per_token=min(self.experts_per_token, 2),
            ssm_state=min(self.ssm_state, 16),
            ssm_headdim=32 if self.ssm_state else self.ssm_headdim,
            ssm_chunk=32,
            attn_window=min(self.attn_window, 64) if self.attn_window else 0,
            n_frontend_tokens=min(self.n_frontend_tokens, 16),
        )


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    kind: str        # train | prefill | decode
    seq: int
    batch: int


SHAPES: dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCfg("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCfg("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCfg("long_500k", "decode", 524288, 1),
}


def supports_shape(cfg: ArchConfig, shape: str) -> tuple[bool, str]:
    """(supported, reason-if-not). long_500k needs sub-quadratic attention."""
    sc = SHAPES[shape]
    if sc.name == "long_500k":
        subq = cfg.family == "ssm" or (cfg.ssm_state and cfg.attn_window) \
            or (cfg.attn_window and cfg.family != "encdec")
        if not subq:
            return False, ("pure full-attention arch: 512k dense KV decode is "
                           "quadratic-cost; skipped per assignment")
    return True, ""


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; nothing is allocated).
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: str | ShapeCfg,
                scale_batch: int = 1) -> dict[str, Any]:
    """Model inputs for one (arch x shape) cell.

    ``train``  : token/label batch (modality frontends supply precomputed
                 embeddings — the STUB mandated by the assignment).
    ``prefill``: request batch of ``seq`` tokens.
    ``decode`` : one new token against a ``seq``-long cache (``serve_step``).
    ``scale_batch`` divides the global batch (for reduced smoke runs).
    """
    sc = SHAPES[shape] if isinstance(shape, str) else shape
    B = max(sc.batch // scale_batch, 1)
    S = sc.seq
    D = cfg.d_model
    i32, bf16 = jnp.int32, jnp.bfloat16

    if sc.kind == "train":
        specs: dict[str, Any] = {}
        if cfg.frontend == "vision_stub":
            P = cfg.n_frontend_tokens
            specs["patch_embeds"] = _sds((B, P, D), bf16)
            specs["tokens"] = _sds((B, S - P), i32)
            specs["labels"] = _sds((B, S - P), i32)
        elif cfg.family == "encdec":
            # audio_stub: precomputed frame embeddings for the encoder.
            specs["frame_embeds"] = _sds((B, S, D), bf16)
            specs["tokens"] = _sds((B, S), i32)
            specs["labels"] = _sds((B, S), i32)
        else:
            specs["tokens"] = _sds((B, S), i32)
            specs["labels"] = _sds((B, S), i32)
        return specs

    if sc.kind == "prefill":
        if cfg.frontend == "vision_stub":
            P = cfg.n_frontend_tokens
            return {"patch_embeds": _sds((B, P, D), bf16),
                    "tokens": _sds((B, S - P), i32)}
        if cfg.family == "encdec":
            return {"frame_embeds": _sds((B, S, D), bf16),
                    "tokens": _sds((B, S), i32)}
        return {"tokens": _sds((B, S), i32)}

    # decode: one-step serve with caches sized for S.
    specs = {"token": _sds((B, 1), i32), "pos": _sds((), i32)}
    L = cfg.n_layers
    if cfg.n_heads and cfg.n_kv_heads:
        W = min(cfg.attn_window or S, S)
        specs["k_cache"] = _sds((L, B, W, cfg.n_kv_heads, cfg.head_dim), bf16)
        specs["v_cache"] = _sds((L, B, W, cfg.n_kv_heads, cfg.head_dim), bf16)
    if cfg.ssm_state:
        H, P_, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
        specs["ssm_state"] = _sds((L, B, H, P_, N), jnp.float32)
        specs["conv_state"] = _sds((L, B, cfg.ssm_conv - 1, cfg.conv_dim), bf16)
    if cfg.family == "encdec":
        specs["enc_out"] = _sds((L, B, S, cfg.n_kv_heads, cfg.head_dim), bf16)
        specs["enc_out_v"] = _sds((L, B, S, cfg.n_kv_heads, cfg.head_dim), bf16)
    return specs
