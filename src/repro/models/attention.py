"""Attention: GQA projections + flash-style blockwise computation + KV cache.

Three compute paths, all numerically the online-softmax algorithm:

* :func:`flash_unrolled` — causal path for train/prefill.  Python-unrolled
  q×kv block triangle with *static* slice bounds, so fully-masked block
  pairs are never emitted into the HLO: compiled FLOPs match the causal
  ideal S²/2 (the naive masked formulation wastes 2×; this is a §Perf
  lever that is on by default).
* :func:`flash_scan` — general path (cross-attention, non-causal): nested
  ``lax.scan`` over q/kv blocks, O(block²) live memory.
* :func:`decode_step` — single-token attention against a (ring-buffered)
  KV cache for serve/decode shapes.

The Pallas TPU kernel (``repro.kernels.flash_attention``) implements the
same tiling for the MXU; ``par.use_pallas`` switches to it (validated in
interpret mode against these jnp paths — see tests).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ArchConfig
from repro.models.layers import apply_rope, cast
from repro.models.params import ParamDef
from repro.models.parallel import ParallelCfg, batch_spec, constrain

NEG_INF = jnp.float32(-1e30)


# ---------------------------------------------------------------------------
# Parameter tree.
# ---------------------------------------------------------------------------

def attn_defs(cfg: ArchConfig, cross: bool = False) -> dict:
    D, H, KVH, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    defs = {
        "wq": ParamDef((D, H, dh), ("embed", "heads", "head"), init="scaled"),
        "wk": ParamDef((D, KVH, dh), ("embed", "kv_heads", "head"),
                       init="scaled"),
        "wv": ParamDef((D, KVH, dh), ("embed", "kv_heads", "head"),
                       init="scaled"),
        "wo": ParamDef((H, dh, D), ("heads", "head", "embed"), init="scaled"),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((H, dh), ("heads", "head"), init="zeros")
        defs["bk"] = ParamDef((KVH, dh), ("kv_heads", "head"), init="zeros")
        defs["bv"] = ParamDef((KVH, dh), ("kv_heads", "head"), init="zeros")
    if cfg.qk_norm and not cross:
        defs["q_norm"] = ParamDef((dh,), ("head",), init="ones")
        defs["k_norm"] = ParamDef((dh,), ("head",), init="ones")
    return defs


def _head_rms(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (xf * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Online-softmax block update (shared by both flash paths).
# ---------------------------------------------------------------------------

def _block_update(carry, q_blk, k_blk, v_blk, mask, scale):
    """One (q-block, kv-block) online-softmax step.

    q_blk [B, bq, K, G, h]; k/v_blk [B, bk, K, h]; mask [bq, bk] bool or None.
    carry = (m [B,K,G,bq], l [B,K,G,bq], acc [B,K,G,bq,h]) fp32.
    """
    m, l, acc = carry
    s = jnp.einsum("bqkgh,bvkh->bkgqv", q_blk, k_blk,
                   preferred_element_type=jnp.float32) * scale
    if mask is not None:
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    m_new = jnp.maximum(m, s.max(-1))
    corr = jnp.exp(m - m_new)
    p = jnp.exp(s - m_new[..., None])
    l = l * corr + p.sum(-1)
    pv = jnp.einsum("bkgqv,bvkh->bkgqh", p.astype(v_blk.dtype), v_blk,
                    preferred_element_type=jnp.float32)
    acc = acc * corr[..., None] + pv
    return m_new, l, acc


def _finish(m, l, acc, dtype):
    out = acc / jnp.maximum(l, 1e-30)[..., None]        # [B,K,G,bq,h]
    return out.transpose(0, 3, 1, 2, 4).astype(dtype)   # [B,bq,K,G,h]


def _init_carry(B, K, G, bq, h):
    return (jnp.full((B, K, G, bq), NEG_INF),
            jnp.zeros((B, K, G, bq), jnp.float32),
            jnp.zeros((B, K, G, bq, h), jnp.float32))


# ---------------------------------------------------------------------------
# Causal flash with static block skipping (train / prefill).
# ---------------------------------------------------------------------------

def flash_unrolled(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                   block: int = 2048, window: int = 0,
                   q_offset: int = 0) -> jnp.ndarray:
    """Causal attention. q [B,Sq,K,G,h]; k,v [B,Skv,K,h]; returns like q.

    ``q_offset``: absolute position of q row 0 relative to k row 0 (prefix
    tokens). ``window > 0``: sliding-window causal attention.
    """
    B, Sq, K, G, h = q.shape
    Skv = k.shape[1]
    scale = 1.0 / math.sqrt(h)
    bq = min(block, Sq)
    bk = min(block, Skv)
    nq, nk = -(-Sq // bq), -(-Skv // bk)
    outs = []
    for qi in range(nq):
        q0 = qi * bq
        cq = min(bq, Sq - q0)
        q_blk = jax.lax.slice_in_dim(q, q0, q0 + cq, axis=1)
        q_lo, q_hi = q_offset + q0, q_offset + q0 + cq - 1  # abs pos range
        carry = _init_carry(B, K, G, cq, h)
        for kj in range(nk):
            k0 = kj * bk
            ck = min(bk, Skv - k0)
            k_hi = k0 + ck - 1
            if k0 > q_hi:
                continue                     # fully above the diagonal
            if window and k_hi < q_lo - window + 1:
                continue                     # fully below the window
            k_blk = jax.lax.slice_in_dim(k, k0, k0 + ck, axis=1)
            v_blk = jax.lax.slice_in_dim(v, k0, k0 + ck, axis=1)
            diag = k_hi > q_lo               # needs causal masking
            edge = window and (k0 < q_hi - window + 1)
            mask = None
            if diag or edge:
                qpos = q_lo + jnp.arange(cq)
                kpos = k0 + jnp.arange(ck)
                mask = kpos[None, :] <= qpos[:, None]
                if window:
                    mask &= kpos[None, :] > qpos[:, None] - window
            carry = _block_update(carry, q_blk, k_blk, v_blk, mask, scale)
        outs.append(_finish(*carry, q.dtype))
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


# ---------------------------------------------------------------------------
# General flash via nested scan (cross-attention / non-causal).
# ---------------------------------------------------------------------------

def flash_scan(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
               block_q: int = 1024, block_k: int = 2048) -> jnp.ndarray:
    """Non-causal attention, O(block²) live memory. Shapes as above."""
    B, Sq, K, G, h = q.shape
    Skv = k.shape[1]
    scale = 1.0 / math.sqrt(h)
    bq = math.gcd(min(block_q, Sq), Sq)
    bk = math.gcd(min(block_k, Skv), Skv)
    nq, nk = Sq // bq, Skv // bk
    qs = q.reshape(B, nq, bq, K, G, h).swapaxes(0, 1)
    ks = k.reshape(B, nk, bk, K, h).swapaxes(0, 1)
    vs = v.reshape(B, nk, bk, K, h).swapaxes(0, 1)

    def per_q(_, q_blk):
        def kv_body(carry, kv):
            k_blk, v_blk = kv
            return _block_update(carry, q_blk, k_blk, v_blk, None, scale), None
        carry, _ = jax.lax.scan(kv_body, _init_carry(B, K, G, bq, h),
                                (ks, vs))
        return None, _finish(*carry, q.dtype)

    _, out = jax.lax.scan(per_q, None, qs)              # [nq, B, bq, K, G, h]
    return out.swapaxes(0, 1).reshape(B, Sq, K, G, h)


# ---------------------------------------------------------------------------
# Decode: one new token vs. a KV cache (ring buffer when windowed).
# ---------------------------------------------------------------------------

def decode_step(q: jnp.ndarray, new_k: jnp.ndarray, new_v: jnp.ndarray,
                k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                pos: jnp.ndarray, window: int = 0):
    """q [B,1,K,G,h]; new_k/v [B,1,K,h]; caches [B,W,K,h]; pos int32 scalar
    or per-lane [B] (continuous batching: lanes at different depths).

    Returns (out [B,1,K,G,h], k_cache, v_cache).  With ``window`` the cache
    is a ring buffer of W slots; otherwise W covers the full horizon.
    """
    B, W = k_cache.shape[0], k_cache.shape[1]
    h = q.shape[-1]
    scale = 1.0 / math.sqrt(h)
    pos = jnp.broadcast_to(pos, (B,)).astype(jnp.int32)
    idx = pos % W if window else jnp.minimum(pos, W - 1)
    lane = jnp.arange(B)
    k_cache = k_cache.at[lane, idx].set(new_k[:, 0].astype(k_cache.dtype))
    v_cache = v_cache.at[lane, idx].set(new_v[:, 0].astype(v_cache.dtype))
    slots = jnp.arange(W)
    valid = slots[None, :] <= pos[:, None]               # [B, W]
    if window:
        valid = valid | (pos[:, None] >= W)              # ring full: all live
    s = jnp.einsum("bqkgh,bwkh->bkgqw", q, k_cache,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqw,bwkh->bqkgh", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype), k_cache, v_cache


# ---------------------------------------------------------------------------
# Full attention sub-layer.
# ---------------------------------------------------------------------------

def attn_apply(p: dict, x: jnp.ndarray, cfg: ArchConfig, par: ParallelCfg,
               *, mode: str = "train", pos=None, cache: dict | None = None,
               kv_x: jnp.ndarray | None = None, causal: bool = True,
               q_offset: int = 0, layer_tag: str = ""):
    """GQA attention. mode: train|prefill (full seq) or decode (1 token).

    ``cache``: {"k","v"} [B,W,KVH,dh] (+ "pos" handled by caller) for decode;
    for cross-attention decode, pass precomputed k/v via ``cache`` with
    ``kv_x=None`` and ``mode='cross_cached'``.
    Returns (out [B,S,D], new_cache_or_None).
    """
    H, KVH, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // KVH
    B, S, _ = x.shape

    q = jnp.einsum("bsd,dhk->bshk", x, cast(p["wq"]))
    if "bq" in p:
        q = q + cast(p["bq"])
    src = x if kv_x is None else kv_x
    if mode != "cross_cached":
        k = jnp.einsum("bsd,dhk->bshk", src, cast(p["wk"]))
        v = jnp.einsum("bsd,dhk->bshk", src, cast(p["wv"]))
        if "bk" in p:
            k, v = k + cast(p["bk"]), v + cast(p["bv"])
    if "q_norm" in p:
        q = _head_rms(q, p["q_norm"], cfg.norm_eps)
        if mode != "cross_cached":
            k = _head_rms(k, p["k_norm"], cfg.norm_eps)
    if cfg.pos == "rope" and kv_x is None and mode != "cross_cached":
        qpos = pos if pos is not None else jnp.arange(S) + q_offset
        if qpos.ndim == 0:
            qpos = qpos[None]                        # scalar pos -> [S=1]
        elif qpos.ndim == 1 and mode == "decode":
            qpos = qpos[:, None]                     # per-lane pos -> [B,1]
        q = apply_rope(q, qpos, cfg.rope_theta)
        k = apply_rope(k, qpos, cfg.rope_theta)

    # Shard heads over the tensor axis.
    hspec = batch_spec(par, None, "model", None)
    q = constrain(q, par, hspec)
    qg = q.reshape(B, S, KVH, G, dh)

    new_cache = None
    if mode == "decode":
        out, kc, vc = decode_step(qg, k, v, cache["k"], cache["v"], pos,
                                  window=cfg.attn_window)
        new_cache = {"k": kc, "v": vc}
    elif mode == "cross_cached":
        kc, vc = cache["k"], cache["v"]
        s = jnp.einsum("bqkgh,bwkh->bkgqw", qg, kc,
                       preferred_element_type=jnp.float32) / math.sqrt(dh)
        pr = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgqw,bwkh->bqkgh", pr.astype(vc.dtype), vc
                         ).astype(x.dtype)
    elif not causal:
        out = flash_scan(qg, k, v, block_q=par.attn_block // 2,
                         block_k=par.attn_block)
        if mode == "prefill" and kv_x is not None:
            new_cache = {"k": k, "v": v}           # cross-attn KV for decode
    else:
        out = flash_unrolled(qg, k, v, block=par.attn_block,
                             window=cfg.attn_window, q_offset=q_offset)
        if mode == "prefill" and kv_x is None:
            # Serve prefill: emit the KV cache (ring-ordered when windowed
            # so decode_step's ``pos % W`` indexing lines up).
            W = cfg.attn_window
            if W and S >= W:
                slots = (S - W + jnp.arange(W)) % W
                kc = jnp.zeros((B, W) + k.shape[2:], k.dtype
                               ).at[:, slots].set(k[:, -W:])
                vc = jnp.zeros((B, W) + v.shape[2:], v.dtype
                               ).at[:, slots].set(v[:, -W:])
                new_cache = {"k": kc, "v": vc}
            else:
                new_cache = {"k": k, "v": v}

    out = out.reshape(B, S, H, dh)
    out = constrain(out, par, hspec)
    y = jnp.einsum("bshk,hkd->bsd", out, cast(p["wo"]))
    return y, new_cache
