"""Mamba2 (state-space duality / SSD) mixer — chunked train path + decode.

The SSD algorithm (Dao & Gu, arXiv:2405.21060) splits the sequence into
chunks of ``Q`` steps: within a chunk the recurrence is computed as a
masked quadratic form (MXU-friendly), and a single ``lax.scan`` over chunk
*states* [H, P, N] carries information between chunks — O(S·Q) work with a
constant-size recurrent state, which is why the ssm/hybrid archs are the
ones that run the ``long_500k`` shape.

Projections are stored split (z / x / BC / dt) so tensor-parallel sharding
stays clean: the inner dim (and its heads) shard over ``"model"``, while
the small shared B/C streams stay replicated.  The depthwise conv is two
shift-multiply einsums (one per stream family), not ``conv_general_dilated``
— identical math, trivially shardable.

``repro.kernels.ssd_scan`` is the Pallas TPU kernel for the chunk kernel;
this module is its jnp reference and the dry-run path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ArchConfig
from repro.models.layers import cast
from repro.models.params import ParamDef
from repro.models.parallel import ParallelCfg, batch_spec, constrain


def ssm_defs(cfg: ArchConfig) -> dict:
    D = cfg.d_model
    di, G, N, H = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    K = cfg.ssm_conv
    return {
        "wz": ParamDef((D, di), ("embed", "ssm_inner"), init="scaled"),
        "wx": ParamDef((D, di), ("embed", "ssm_inner"), init="scaled"),
        "wbc": ParamDef((D, 2 * G * N), ("embed", None), init="scaled"),
        "wdt": ParamDef((D, H), ("embed", "ssm_heads"), init="scaled"),
        "conv_x": ParamDef((K, di), ("conv", "ssm_inner"), init="scaled"),
        "conv_bc": ParamDef((K, 2 * G * N), ("conv", None), init="scaled"),
        "conv_bias_x": ParamDef((di,), ("ssm_inner",), init="zeros"),
        "conv_bias_bc": ParamDef((2 * G * N,), (None,), init="zeros"),
        "A_log": ParamDef((H,), ("ssm_heads",), init="zeros"),
        "Dskip": ParamDef((H,), ("ssm_heads",), init="ones"),
        "dt_bias": ParamDef((H,), ("ssm_heads",), init="zeros"),
        "norm": ParamDef((di,), ("ssm_inner",), init="ones"),
        "out": ParamDef((di, D), ("ssm_inner", "embed"), init="scaled"),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray
                 ) -> jnp.ndarray:
    """Depthwise causal conv as K shifted einsums. x [B,S,C], w [K,C]."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    S = x.shape[1]
    out = sum(pad[:, i:i + S] * cast(w)[i] for i in range(K))
    return out + cast(b)


def _segsum(dA: jnp.ndarray) -> jnp.ndarray:
    """dA [..., Q] -> L [..., Q, Q]: L[i,j] = sum_{j<t<=i} dA[t], -inf i<j."""
    Q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                Bm: jnp.ndarray, Cm: jnp.ndarray, chunk: int,
                h0: jnp.ndarray | None = None):
    """Chunked SSD. x [B,S,H,P]; dt [B,S,H] (post-softplus); A [H] (<0);
    Bm, Cm [B,S,G,N]. Returns (y [B,S,H,P], h_final [B,H,P,N])."""
    Bsz, S, H, Pd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(chunk, S)
    if S % Q:                       # pad: dt=0 steps are identity on state
        pad = Q - S % Q
        padf = lambda a: jnp.pad(a, [(0, 0), (0, pad)]  # noqa: E731
                                 + [(0, 0)] * (a.ndim - 2))
        y, h = ssd_chunked(padf(x), padf(dt), A, padf(Bm), padf(Cm), Q, h0)
        return y[:, :S], h
    nc = S // Q
    rep = H // G

    def chunkify(a):
        return a.reshape((Bsz, nc, Q) + a.shape[2:])

    xc, dtc = chunkify(x), chunkify(dt)
    Bc, Cc = chunkify(Bm), chunkify(Cm)
    dA = dtc * A.astype(jnp.float32)                       # [B,nc,Q,H]
    dAh = dA.transpose(0, 1, 3, 2)                         # [B,nc,H,Q]
    cum = jnp.cumsum(dAh, axis=-1)                         # [B,nc,H,Q]

    # --- intra-chunk (quadratic) term ---
    L = jnp.exp(_segsum(dAh))                              # [B,nc,H,Q,Q]
    Bh = jnp.repeat(Bc, rep, axis=3)                       # [B,nc,Q,H,N]
    Ch = jnp.repeat(Cc, rep, axis=3)
    scores = jnp.einsum("bcihn,bcjhn->bchij", Ch, Bh,
                        preferred_element_type=jnp.float32)
    M = scores * L * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]
    # f32 throughout, matching the Pallas kernel (kernels/ssd_scan) and the
    # f32 decode recurrence — a bf16 M here puts prefill's last-position
    # output a bf16 ulp away from the decode continuation of its own state.
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", M, xc.astype(jnp.float32),
                         preferred_element_type=jnp.float32)

    # --- chunk states ---
    decay_to_end = jnp.exp(cum[..., -1:] - cum)            # [B,nc,H,Q]
    wgt = (decay_to_end * dtc.transpose(0, 1, 3, 2)).transpose(0, 1, 3, 2)
    states = jnp.einsum("bcjhn,bcjh,bcjhp->bchpn", Bh, wgt.astype(jnp.float32),
                        xc.astype(jnp.float32))            # [B,nc,H,P,N]

    # --- inter-chunk scan over states ---
    chunk_decay = jnp.exp(cum[..., -1])                    # [B,nc,H]
    init = (jnp.zeros((Bsz, H, Pd, N), jnp.float32) if h0 is None
            else h0.astype(jnp.float32))

    def scan_body(h, inp):
        s_c, dec = inp                                     # [B,H,P,N],[B,H]
        h_out = h                                          # state *entering*
        h = h * dec[..., None, None] + s_c
        return h, h_out

    sc = states.swapaxes(0, 1)                             # [nc,B,H,P,N]
    dc = chunk_decay.swapaxes(0, 1)                        # [nc,B,H]
    h_final, h_in = jax.lax.scan(scan_body, init, (sc, dc))

    # --- inter-chunk contribution: y += C_i · (decay_i * h_in) ---
    in_decay = jnp.exp(cum).transpose(0, 1, 3, 2)          # [B,nc,Q,H]
    h_in = h_in.swapaxes(0, 1)                             # [B,nc,H,P,N]
    y_inter = jnp.einsum("bcihn,bchpn->bcihp", Ch.astype(jnp.float32), h_in,
                         preferred_element_type=jnp.float32)
    y = y_intra + y_inter * in_decay[..., None]
    return y.reshape(Bsz, S, H, Pd).astype(x.dtype), h_final


def ssd_ref(x, dt, A, Bm, Cm, h0=None):
    """Sequential recurrence oracle (tests): step-by-step state update."""
    Bsz, S, H, Pd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    h = (jnp.zeros((Bsz, H, Pd, N), jnp.float32) if h0 is None
         else h0.astype(jnp.float32))
    ys = []
    for t in range(S):
        da = jnp.exp(dt[:, t] * A)                         # [B,H]
        Bt = jnp.repeat(Bm[:, t], rep, axis=1)             # [B,H,N]
        Ct = jnp.repeat(Cm[:, t], rep, axis=1)
        upd = (dt[:, t, :, None, None] * x[:, t, :, :, None].astype(jnp.float32)
               * Bt[:, :, None, :].astype(jnp.float32))
        h = h * da[..., None, None] + upd
        ys.append(jnp.einsum("bhpn,bhn->bhp", h, Ct.astype(jnp.float32)))
    return jnp.stack(ys, 1).astype(x.dtype), h


def _gated_norm(y: jnp.ndarray, z: jnp.ndarray, scale: jnp.ndarray,
                eps: float) -> jnp.ndarray:
    g = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    g = g * jax.lax.rsqrt(jnp.mean(g * g, -1, keepdims=True) + eps)
    return (g * scale.astype(jnp.float32)).astype(y.dtype)


def ssm_apply(p: dict, x: jnp.ndarray, cfg: ArchConfig, par: ParallelCfg,
              *, mode: str = "train", state: dict | None = None):
    """Mamba2 mixer. x [B,S,D]. mode train/prefill: full-seq chunked SSD
    (returns (y, None)); decode: single step against ``state`` =
    {"h": [B,H,P,N] f32, "conv": [B,K-1, di+2GN]} (returns (y, new_state))."""
    Bsz, S, D = x.shape
    di, G, N, H = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    Pd, K = cfg.ssm_headdim, cfg.ssm_conv

    z = jnp.einsum("bsd,de->bse", x, cast(p["wz"]))
    xin = jnp.einsum("bsd,de->bse", x, cast(p["wx"]))
    bc = jnp.einsum("bsd,de->bse", x, cast(p["wbc"]))
    dt = jnp.einsum("bsd,dh->bsh", x, cast(p["wdt"]))
    ispec = batch_spec(par, None, "model")
    z, xin = constrain(z, par, ispec), constrain(xin, par, ispec)

    new_state = None
    if mode == "decode":
        conv_st = state["conv"]                            # [B, K-1, C]
        full = jnp.concatenate([conv_st, jnp.concatenate([xin, bc], -1)], 1)
        w = jnp.concatenate([p["conv_x"], p["conv_bc"]], 1)
        b = jnp.concatenate([p["conv_bias_x"], p["conv_bias_bc"]], 0)
        # Ordered shift-sum, NOT an einsum: bit-identical rounding to
        # _causal_conv's prefill pass, so the conv handoff is exact.
        conv_out = sum(full[:, i] * cast(w)[i] for i in range(K)) + cast(b)
        conv_out = jax.nn.silu(conv_out)[:, None]          # [B,1,C]
        xin, bc = conv_out[..., :di], conv_out[..., di:]
        new_conv = full[:, 1:]
    else:
        if mode == "prefill":                      # pre-conv tail for decode
            new_conv = jnp.concatenate([xin, bc], -1)[:, S - K + 1:]
        xin = jax.nn.silu(_causal_conv(xin, p["conv_x"], p["conv_bias_x"]))
        bc = jax.nn.silu(_causal_conv(bc, p["conv_bc"], p["conv_bias_bc"]))

    Bm = bc[..., :G * N].reshape(Bsz, S, G, N)
    Cm = bc[..., G * N:].reshape(Bsz, S, G, N)
    xh = xin.reshape(Bsz, S, H, Pd)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if mode == "decode":
        da = jnp.exp(dt[:, 0] * A)                         # [B,H]
        rep = H // G
        Bt = jnp.repeat(Bm[:, 0], rep, axis=1)
        Ct = jnp.repeat(Cm[:, 0], rep, axis=1)
        upd = (dt[:, 0, :, None, None]
               * xh[:, 0, :, :, None].astype(jnp.float32)
               * Bt[:, :, None, :].astype(jnp.float32))
        h = state["h"] * da[..., None, None] + upd
        y = jnp.einsum("bhpn,bhn->bhp", h, Ct.astype(jnp.float32))
        y = y[:, None].astype(x.dtype)                     # [B,1,H,P]
        new_state = {"h": h, "conv": new_conv}
    else:
        y, h_final = ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk)
        if mode == "prefill":
            new_state = {"h": h_final, "conv": new_conv}

    y = y + xh * cast(p["Dskip"])[:, None]
    y = y.reshape(Bsz, S, di)
    y = _gated_norm(y, z, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, cast(p["out"]))
    return out, new_state
