"""Per-family transformer block assembly + the scanned layer stack.

One decoder block is built per family:
  dense / vlm : attn -> mlp                      (pre-norm residual)
  moe         : attn -> moe ffn (+ aux loss)
  ssm         : mamba2 mixer only (mamba has no separate FFN)
  hybrid      : parallel attn + mamba heads on the same normed input
                (outputs mean-combined, Hymba-style) -> mlp
  encdec      : self-attn -> cross-attn -> mlp   (whisper decoder);
                encoder blocks are non-causal attn -> mlp.

Layers are stacked with ``lax.scan`` over parameters whose leading axis is
the layer index — HLO size stays O(1) in depth, and the scan body is the
activation-checkpointing (remat) boundary.
"""
from __future__ import annotations

import jax
import jax.ad_checkpoint
import jax.numpy as jnp

from repro.models import attention, moe as moe_mod, ssm as ssm_mod
from repro.models.common import ArchConfig
from repro.models.layers import mlp_apply, mlp_defs, norm_apply, norm_defs
from repro.models.params import ParamDef, tree_map_defs
from repro.models.parallel import ParallelCfg


def stack_defs(defs, n_layers: int):
    """Prepend a ``layer`` axis of size L to every ParamDef in the tree."""
    return tree_map_defs(
        lambda d: ParamDef((n_layers,) + d.shape, ("layer",) + d.logical,
                           init=d.init, dtype=d.dtype, scale=d.scale), defs)


# ---------------------------------------------------------------------------
# Single block (one layer) defs/apply.
# ---------------------------------------------------------------------------

def block_defs(cfg: ArchConfig, encoder: bool = False) -> dict:
    d = {}
    D, kind = cfg.d_model, cfg.norm
    if cfg.family == "ssm":
        d["norm1"] = norm_defs(D, kind)
        d["ssm"] = ssm_mod.ssm_defs(cfg)
        return d
    d["norm1"] = norm_defs(D, kind)
    d["attn"] = attention.attn_defs(cfg)
    if cfg.family == "hybrid":
        d["ssm"] = ssm_mod.ssm_defs(cfg)
    if cfg.family == "encdec" and not encoder:
        d["norm_x"] = norm_defs(D, kind)
        d["cross"] = attention.attn_defs(cfg, cross=True)
    d["norm2"] = norm_defs(D, kind)
    if cfg.family == "moe":
        d["moe"] = moe_mod.moe_defs(cfg)
    elif cfg.d_ff:
        d["mlp"] = mlp_defs(D, cfg.d_ff, cfg.act)
    return d


def block_apply(p: dict, x: jnp.ndarray, cfg: ArchConfig, par: ParallelCfg,
                *, mode: str, pos=None, cache: dict | None = None,
                causal: bool = True, q_offset: int = 0,
                enc: jnp.ndarray | None = None):
    """One decoder/encoder block. Returns (x, new_cache, aux)."""
    aux = jnp.float32(0.0)
    new_cache: dict = {}
    kind, eps = cfg.norm, cfg.norm_eps
    h = norm_apply(p["norm1"], x, kind, eps)

    if cfg.family == "ssm":
        y, st = ssm_mod.ssm_apply(p["ssm"], h, cfg, par, mode=mode,
                                  state=cache)
        if st is not None:
            new_cache.update(st)
        return x + y, new_cache, aux

    attn_cache = {k: cache[k] for k in ("k", "v")} if cache and "k" in cache \
        else None
    y, ac = attention.attn_apply(
        p["attn"], h, cfg, par, mode=mode, pos=pos, cache=attn_cache,
        causal=causal, q_offset=q_offset)
    if par.ar_barrier:
        y = jax.lax.optimization_barrier(y)
    if par.remat == "tp_out":
        y = jax.ad_checkpoint.checkpoint_name(y, "tp_out")
    if ac is not None:
        new_cache.update(ac)

    if cfg.family == "hybrid":
        # Hymba: attention and mamba heads read the SAME normed input in
        # parallel; their (pre-norm) outputs are mean-combined.
        sst = {"h": cache["h"], "conv": cache["conv"]} if cache and "h" in cache else None
        ys, st = ssm_mod.ssm_apply(p["ssm"], h, cfg, par, mode=mode,
                                   state=sst)
        y = 0.5 * (y + ys)
        if st is not None:
            new_cache.update(st)
    x = x + y

    if "cross" in p:
        h = norm_apply(p["norm_x"], x, kind, eps)
        if mode == "decode":
            y, _ = attention.attn_apply(
                p["cross"], h, cfg, par, mode="cross_cached",
                cache={"k": cache["ck"], "v": cache["cv"]})
            new_cache["ck"], new_cache["cv"] = cache["ck"], cache["cv"]
        else:
            y, cc = attention.attn_apply(p["cross"], h, cfg, par, mode=mode,
                                         kv_x=enc, causal=False)
            if cc is not None:
                new_cache["ck"], new_cache["cv"] = cc["k"], cc["v"]
        x = x + y

    h = norm_apply(p["norm2"], x, kind, eps)
    if cfg.family == "moe":
        y, aux = moe_mod.moe_apply(p["moe"], h, cfg, par)
    elif cfg.d_ff:
        y = mlp_apply(p["mlp"], h, cfg.act)
    else:
        y = jnp.zeros_like(x)
    if par.ar_barrier:
        y = jax.lax.optimization_barrier(y)
    if par.remat == "tp_out":
        y = jax.ad_checkpoint.checkpoint_name(y, "tp_out")
    return x + y, new_cache, aux


# ---------------------------------------------------------------------------
# Scanned layer stack.
# ---------------------------------------------------------------------------

def _remat(fn, par: ParallelCfg):
    if par.remat == "none":
        return fn
    if par.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    elif par.remat == "tp_out":
        # Save exactly the tensor-parallel sublayer outputs: their partial
        # sums were all-reduced in the forward pass, and "full" remat would
        # replay those collectives in the backward (6 ARs/layer instead of
        # 4 — §Perf deepseek iteration).  Costs one saved [B,S,D] per
        # sublayer per layer.
        policy = jax.checkpoint_policies.save_only_these_names("tp_out")
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


def stack_apply(stacked: dict, x: jnp.ndarray, cfg: ArchConfig,
                par: ParallelCfg, *, mode: str, n_layers: int, pos=None,
                caches: dict | None = None, causal: bool = True,
                q_offset: int = 0, enc: jnp.ndarray | None = None):
    """Run ``n_layers`` blocks via lax.scan over the stacked param tree.

    ``caches``: dict of [L, ...] arrays for decode (returned updated).
    ``enc``: encoder output broadcast to every decoder layer (encdec train).
    Returns (x, new_caches, aux_total).
    """
    caches = caches if caches is not None else {}

    def body(carry, xs):
        h, aux = carry
        lp, lc = xs
        h, nc, a = block_apply(lp, h, cfg, par, mode=mode, pos=pos,
                               cache=lc, causal=causal, q_offset=q_offset,
                               enc=enc)
        return (h, aux + a), nc

    if mode == "train":
        body = _remat(body, par)
    if par.scan_layers:
        (x, aux), new_caches = jax.lax.scan(
            body, (x, jnp.float32(0.0)), (stacked, caches))
    else:
        aux = jnp.float32(0.0)
        outs = []
        for i in range(n_layers):
            lp = jax.tree.map(lambda a: a[i], stacked)
            lc = jax.tree.map(lambda a: a[i], caches)
            (x, aux), nc = body((x, aux), (lp, lc))
            outs.append(nc)
        new_caches = (jax.tree.map(lambda *a: jnp.stack(a), *outs)
                      if outs and outs[0] else {})
    return x, new_caches, aux
