"""Parallelism configuration threaded through every model apply.

``ParallelCfg`` is hashable (jit-static) and carries the mesh, the
logical-to-mesh sharding rules, and the perf levers the hillclimb iterates
on (attention block size, remat policy, MoE dispatch, sequence sharding).
With ``mesh=None`` every constraint is a no-op and all paths degrade to
single-device jnp — that is the CPU smoke-test mode.
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.params import DEFAULT_RULES, ShardingRules


@dataclasses.dataclass(frozen=True)
class ParallelCfg:
    mesh: Mesh | None = None
    rules: ShardingRules = DEFAULT_RULES
    remat: str = "full"          # full | dots | none  (scan-over-layers policy)
    scan_layers: bool = True
    attn_block: int = 2048       # flash block size (q and kv)
    loss_chunk: int = 1024       # CE loss seq chunk
    moe_ep: bool = True          # shard_map expert parallelism when mesh set
    seq_shard: bool = False      # shard activation seq axis on "model"
    use_pallas: bool = False     # TPU Pallas kernels (tests run interpret)
    zero_stage: int = 0          # 0/1: replicate params over data; 3: fsdp
    ar_barrier: bool = False     # pin TP all-reduces to bf16 (§Perf lever):
    # an optimization_barrier after each TP einsum stops the partitioner
    # from folding downstream f32 converts into the dot, which would make
    # the partial-sum all-reduce run at 2x wire bytes.

    @property
    def batch_axes(self) -> tuple[str, ...]:
        if self.mesh is None:
            return ()
        return tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)

    @property
    def model_axis_size(self) -> int:
        if self.mesh is None or "model" not in self.mesh.axis_names:
            return 1
        return self.mesh.shape["model"]

    def effective_rules(self) -> ShardingRules:
        r = self.rules
        if self.mesh is not None and "pod" in self.mesh.axis_names:
            r = r.replace(batch=("pod", "data"),
                          fsdp=("pod", "data") if self.zero_stage else None)
        if self.zero_stage >= 3:
            # ZeRO-3 posture: embed dim of big weights sharded over data.
            r = r.replace(embed=r.mesh_axes("fsdp"))
        if self.seq_shard:
            r = r.replace(act_seq="model")
        return r


def constrain(x, par: ParallelCfg, spec: P):
    """with_sharding_constraint that no-ops without a mesh (smoke mode)."""
    if par.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(par.mesh, spec))


def batch_spec(par: ParallelCfg, *rest) -> P:
    axes = par.batch_axes
    return P(axes if axes else None, *rest)
