"""Parameter trees with logical sharding axes (MaxText-style).

Every layer builder returns a nested dict of :class:`ParamDef` leaves.  A
``ParamDef`` carries the shape, dtype, an *initializer name* and a tuple of
*logical axis names* — one per dimension.  Logical names are mapped to mesh
axes by a :class:`ShardingRules` table, so re-sharding the whole model (a
perf-hillclimb lever) is a one-line rule change, never a model edit.

Three consumers:
  * ``init_params``  — materialize real arrays (smoke tests / examples).
  * ``param_specs``  — ``jax.ShapeDtypeStruct`` tree (multi-pod dry-run;
                       nothing is allocated).
  * ``param_shardings`` — ``NamedSharding`` tree for pjit in/out shardings.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    logical: tuple[Any, ...]              # logical axis name (or None) per dim
    init: str = "normal"                  # normal | zeros | ones | scaled
    dtype: Any = jnp.float32
    scale: float = 1.0                    # stddev multiplier for normal/scaled

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


# Logical-axis -> mesh-axis rules. A mesh axis may appear at most once per
# param (XLA requirement); `fsdp` composes ("pod","data") on the multi-pod
# mesh so optimizer state shards across every chip (ZeRO-3 posture).
@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: tuple[tuple[str, Any], ...] = (
        ("vocab", "model"),
        ("embed", None),          # d_model replicated by default
        ("heads", "model"),       # attention heads -> tensor parallel
        ("kv_heads", "model"),
        ("mlp", "model"),         # ffn hidden -> tensor parallel
        ("expert", "model"),      # MoE experts -> expert parallel
        ("expert_mlp", None),     # per-expert hidden dim
        ("fsdp", ("data",)),      # ZeRO axis for 2D-sharded big params
        ("layer", None),
        ("seq", None),
        ("ssm_inner", "model"),
        ("ssm_state", None),
        ("conv", None),
        ("batch", ("data",)),     # activation batch axis (single-pod)
        ("act_seq", None),        # activation sequence axis
    )

    def mesh_axes(self, logical: Any):
        for name, ax in self.rules:
            if name == logical:
                return ax
        return None

    def spec(self, logical_axes: tuple[Any, ...]) -> P:
        used: list[Any] = []
        out = []
        for lg in logical_axes:
            ax = self.mesh_axes(lg) if lg is not None else None
            # A mesh axis can only be used once per array.
            if ax is not None:
                flat = ax if isinstance(ax, tuple) else (ax,)
                if any(a in used for a in flat):
                    ax = None
                else:
                    used.extend(flat)
            out.append(ax)
        return P(*out)

    def replace(self, **updates: Any) -> "ShardingRules":
        table = dict(self.rules)
        table.update(updates)
        return ShardingRules(tuple(table.items()))

    def for_multipod(self) -> "ShardingRules":
        """Fold the pod axis into batch + fsdp sharding."""
        return self.replace(batch=("pod", "data"), fsdp=("pod", "data"))


DEFAULT_RULES = ShardingRules()


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def tree_map_defs(fn: Callable[[ParamDef], Any], tree):
    return jax.tree.map(fn, tree, is_leaf=_is_def)


def _fan_in(shape: tuple[int, ...]) -> int:
    # For matmul weights [in, out] (our convention), fan-in = prod of all
    # dims except the last.
    if len(shape) <= 1:
        return max(shape[0] if shape else 1, 1)
    return max(int(np.prod(shape[:-1])), 1)


def init_params(key: jax.Array, tree, dtype_override=None):
    """Materialize a ParamDef tree into real arrays (smoke / examples)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))

    def one(k, d: ParamDef):
        dt = dtype_override or d.dtype
        if d.init == "zeros":
            return jnp.zeros(d.shape, dt)
        if d.init == "ones":
            return jnp.ones(d.shape, dt)
        if d.init == "normal":
            return (d.scale * 0.02 * jax.random.normal(k, d.shape)).astype(dt)
        if d.init == "scaled":  # 1/sqrt(fan_in)
            std = d.scale / math.sqrt(_fan_in(d.shape))
            return (std * jax.random.normal(k, d.shape)).astype(dt)
        raise ValueError(f"unknown init {d.init!r}")

    return jax.tree.unflatten(treedef, [one(k, d) for k, d in zip(keys, leaves)])


def param_specs(tree):
    """ShapeDtypeStruct tree — the dry-run stand-in (no allocation)."""
    return tree_map_defs(lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), tree)


def logical_specs(tree):
    """PartitionSpec-source tree (logical axes per param)."""
    return tree_map_defs(lambda d: d.logical, tree)


def param_pspecs(tree, rules: ShardingRules = DEFAULT_RULES):
    return tree_map_defs(lambda d: rules.spec(d.logical), tree)


def param_shardings(tree, mesh: Mesh, rules: ShardingRules = DEFAULT_RULES):
    return tree_map_defs(lambda d: NamedSharding(mesh, rules.spec(d.logical)),
                         tree)


def count_params(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=_is_def)
    return sum(int(np.prod(l.shape)) for l in leaves)


def sharded_size_bytes(tree, rules: ShardingRules, mesh_shape: dict[str, int]
                       ) -> int:
    """Max per-device bytes of the param tree under `rules` on a mesh of the
    given axis sizes — the napkin-math half of memory_analysis()."""
    total = 0
    for d in jax.tree.leaves(tree, is_leaf=_is_def):
        n = int(np.prod(d.shape))
        shards = 1
        for lg in d.logical:
            ax = rules.mesh_axes(lg) if lg is not None else None
            if ax is None:
                continue
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                shards *= mesh_shape.get(a, 1)
        total += math.ceil(n / shards) * jnp.dtype(d.dtype).itemsize
    return total
