"""Shared building blocks: norms, rotary embeddings, MLPs, embeddings, loss.

Pure-functional: ``*_defs(cfg)`` returns a :class:`~repro.models.params.ParamDef`
tree, ``*_apply(params, x, ...)`` consumes the materialized (or scanned) tree.
All activations run in bf16 with fp32 norms/softmax (the production policy);
parameters are stored fp32 and cast at use.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef

COMPUTE_DTYPE = jnp.bfloat16


def cast(x):
    return x.astype(COMPUTE_DTYPE)


# ---------------------------------------------------------------------------
# Norms.
# ---------------------------------------------------------------------------

def norm_defs(d: int, kind: str = "rmsnorm") -> dict:
    out = {"scale": ParamDef((d,), ("embed",), init="ones")}
    if kind == "layernorm":
        out["bias"] = ParamDef((d,), ("embed",), init="zeros")
    return out


def norm_apply(p: dict, x: jnp.ndarray, kind: str = "rmsnorm",
               eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    else:
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
        xf = (xf - mu) * jax.lax.rsqrt(var + eps)
        xf = xf + p["bias"].astype(jnp.float32)
    return (xf * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings.
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x [..., S, H, dh]; pos [..., S] int32 absolute positions."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # [dh/2]
    ang = pos[..., None].astype(jnp.float32) * freqs    # [..., S, dh/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def sinusoidal_pos(seq: int, d: int, offset: jnp.ndarray | int = 0
                   ) -> jnp.ndarray:
    """Classic transformer sinusoids (whisper-style), bf16 [S, d]."""
    pos = (jnp.arange(seq) + offset)[:, None].astype(jnp.float32)
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32)
                  * (-jnp.log(10000.0) / d))
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe.astype(COMPUTE_DTYPE)


# ---------------------------------------------------------------------------
# MLP (dense FFN): silu-GLU (llama/qwen), gelu (whisper), relu^2 (nemotron).
# ---------------------------------------------------------------------------

def mlp_defs(d: int, f: int, act: str) -> dict:
    glu = act.endswith("_glu")
    out = {"w_in": ParamDef((d, (2 if glu else 1), f),
                            ("embed", None, "mlp"), init="scaled")}
    out["w_out"] = ParamDef((f, d), ("mlp", "embed"), init="scaled")
    return out


def activation(h: jnp.ndarray, act: str) -> jnp.ndarray:
    if act == "silu_glu":
        return jax.nn.silu(h[..., 0, :]) * h[..., 1, :]
    if act == "gelu":
        return jax.nn.gelu(h[..., 0, :], approximate=True)
    if act == "relu2":
        r = jax.nn.relu(h[..., 0, :])
        return r * r
    raise ValueError(f"unknown act {act!r}")


def mlp_apply(p: dict, x: jnp.ndarray, act: str) -> jnp.ndarray:
    h = jnp.einsum("...d,dgf->...gf", x, cast(p["w_in"]))
    h = activation(h, act)
    return jnp.einsum("...f,fd->...d", h, cast(p["w_out"]))


# ---------------------------------------------------------------------------
# Embeddings and the (chunked) LM loss.
# ---------------------------------------------------------------------------

def embed_defs(vocab: int, d: int) -> dict:
    return {"table": ParamDef((vocab, d), ("vocab", "embed"), init="normal")}


def embed_apply(p: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    return cast(p["table"])[tokens]


def unembed_defs(d: int, vocab: int) -> dict:
    return {"w": ParamDef((d, vocab), ("embed", "vocab"), init="scaled")}


def logits_apply(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("...d,dv->...v", x, cast(p["w"])).astype(jnp.float32)


def chunked_ce_loss(unembed: dict, h: jnp.ndarray, labels: jnp.ndarray,
                    mask: jnp.ndarray | None = None,
                    chunk: int = 1024) -> jnp.ndarray:
    """Cross-entropy without materializing [B, S, V] — scan over seq chunks.

    ``h`` [B, S, D] final hidden states; ``labels`` [B, S] int32 (next-token
    ids; -1 = ignore). Returns mean loss over unmasked positions.
    """
    B, S, D = h.shape
    if mask is None:
        mask = labels >= 0
    labels = jnp.maximum(labels, 0)
    n_chunks = max(S // chunk, 1)
    chunk = S // n_chunks
    hc = h.reshape(B, n_chunks, chunk, D).swapaxes(0, 1)
    lc = labels.reshape(B, n_chunks, chunk).swapaxes(0, 1)
    mc = mask.reshape(B, n_chunks, chunk).swapaxes(0, 1)

    def body(carry, xs):
        tot, cnt = carry
        hx, lx, mx = xs
        logits = logits_apply(unembed, hx)              # [B, c, V] fp32
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lx[..., None], -1)[..., 0]
        nll = jnp.where(mx, lse - gold, 0.0)
        return (tot + nll.sum(), cnt + mx.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.int32(0)),
                                 (hc, lc, mc))
    return tot / jnp.maximum(cnt, 1).astype(jnp.float32)
