"""Public model API: ``build_model(cfg)`` -> defs + train/prefill/decode fns.

All forwards are pure functions of (params, batch) suitable for
``jax.jit`` / ``jax.grad``; the ParallelCfg (jit-static) selects sharding
and perf levers.  Batch dict keys follow ``repro.models.common.input_specs``
exactly, so the same functions serve the smoke tests (real arrays, 1
device) and the multi-pod dry-run (ShapeDtypeStructs, 512 devices).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import families
from repro.models.common import ArchConfig
from repro.models.layers import (cast, chunked_ce_loss, embed_apply,
                                 embed_defs, logits_apply, norm_apply,
                                 norm_defs, sinusoidal_pos, unembed_defs)
from repro.models.parallel import ParallelCfg, batch_spec, constrain


def model_defs(cfg: ArchConfig) -> dict:
    defs: dict = {"embed": embed_defs(cfg.padded_vocab, cfg.d_model)}
    defs["blocks"] = families.stack_defs(families.block_defs(cfg),
                                         cfg.n_layers)
    defs["final_norm"] = norm_defs(cfg.d_model, cfg.norm)
    if not cfg.tie_embeddings:
        defs["unembed"] = unembed_defs(cfg.d_model, cfg.padded_vocab)
    if cfg.n_encoder_layers:
        defs["encoder"] = families.stack_defs(
            families.block_defs(cfg, encoder=True), cfg.n_encoder_layers)
        defs["enc_norm"] = norm_defs(cfg.d_model, cfg.norm)
    return defs


def _logits(params: dict, cfg: ArchConfig, h: jnp.ndarray) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return jnp.einsum("...d,vd->...v", h,
                          cast(params["embed"]["table"])).astype(jnp.float32)
    return logits_apply(params["unembed"], h)


def _embed_in(params, cfg: ArchConfig, par: ParallelCfg, batch: dict,
              decode: bool = False):
    """Token (+ stub-frontend) embedding. Returns (x [B,S,D], q_offset)."""
    if decode:
        return embed_apply(params["embed"], batch["token"]), 0
    x = embed_apply(params["embed"], batch["tokens"])
    q_offset = 0
    if cfg.frontend == "vision_stub":
        x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], 1)
    if cfg.pos == "sinusoidal":
        x = x + sinusoidal_pos(x.shape[1], cfg.d_model)
    return x, q_offset


def _run_encoder(params, cfg: ArchConfig, par: ParallelCfg, frames):
    x = frames.astype(jnp.bfloat16)
    if cfg.pos == "sinusoidal":
        x = x + sinusoidal_pos(x.shape[1], cfg.d_model)
    x = constrain(x, par, batch_spec(par, None, None))
    x, _, _ = families.stack_apply(
        params["encoder"], x, cfg, par, mode="prefill",
        n_layers=cfg.n_encoder_layers, causal=False)
    return norm_apply(params["enc_norm"], x, cfg.norm, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Train forward (loss).
# ---------------------------------------------------------------------------

def loss_fn(params: dict, batch: dict, cfg: ArchConfig, par: ParallelCfg
            ) -> jnp.ndarray:
    x, q_offset = _embed_in(params, cfg, par, batch)
    x = constrain(x, par, batch_spec(par, "model" if par.seq_shard else None,
                                     None))
    enc = None
    if cfg.n_encoder_layers:
        enc = _run_encoder(params, cfg, par, batch["frame_embeds"])
    x, _, aux = families.stack_apply(
        params["blocks"], x, cfg, par, mode="train", n_layers=cfg.n_layers,
        q_offset=q_offset, enc=enc)
    x = norm_apply(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    if cfg.frontend == "vision_stub":          # loss only on text positions
        x = x[:, batch["patch_embeds"].shape[1]:]
    unemb = ({"w": params["embed"]["table"].T} if cfg.tie_embeddings
             else params["unembed"])
    loss = chunked_ce_loss(unemb, x, batch["labels"], chunk=par.loss_chunk)
    if cfg.family == "moe":
        loss = loss + cfg.router_aux_weight * aux / cfg.n_layers
    return loss


# ---------------------------------------------------------------------------
# Serve forwards.
# ---------------------------------------------------------------------------

def _caches_out(new_caches: dict) -> dict:
    out = {}
    if "k" in new_caches:
        out["k_cache"], out["v_cache"] = new_caches["k"], new_caches["v"]
    if "h" in new_caches:
        out["ssm_state"], out["conv_state"] = (new_caches["h"],
                                               new_caches["conv"])
    if "ck" in new_caches:
        out["enc_out"], out["enc_out_v"] = new_caches["ck"], new_caches["cv"]
    return out


def prefill_fn(params: dict, batch: dict, cfg: ArchConfig, par: ParallelCfg):
    """Full-sequence forward -> (last-position logits [B, V], caches).

    The caches (stacked [L, ...]) feed ``decode_fn`` directly — this is the
    serve-engine prefill step, and what the ``prefill_32k`` cells lower.
    """
    x, q_offset = _embed_in(params, cfg, par, batch)
    enc = None
    if cfg.n_encoder_layers:
        enc = _run_encoder(params, cfg, par, batch["frame_embeds"])
    x, new_caches, _ = families.stack_apply(
        params["blocks"], x, cfg, par, mode="prefill",
        n_layers=cfg.n_layers, q_offset=q_offset, enc=enc)
    x = norm_apply(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    return _logits(params, cfg, x[:, -1]), _caches_out(new_caches)


def decode_fn(params: dict, batch: dict, cfg: ArchConfig, par: ParallelCfg):
    """One decode step. batch: token [B,1], pos scalar, + caches [L,...].

    Returns (logits [B, V], new_caches dict).
    """
    x, _ = _embed_in(params, cfg, par, batch, decode=True)
    if cfg.pos == "sinusoidal":
        posv = jnp.broadcast_to(batch["pos"], (x.shape[0],))
        d = cfg.d_model
        div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32)
                      * (-jnp.log(10000.0) / d))
        ang = posv[:, None].astype(jnp.float32) * div
        pe = jnp.zeros((x.shape[0], d), jnp.float32)
        pe = pe.at[:, 0::2].set(jnp.sin(ang)).at[:, 1::2].set(jnp.cos(ang))
        x = x + pe[:, None].astype(x.dtype)
    caches: dict = {}
    if "k_cache" in batch:
        caches["k"], caches["v"] = batch["k_cache"], batch["v_cache"]
    if "ssm_state" in batch:
        caches["h"], caches["conv"] = batch["ssm_state"], batch["conv_state"]
    if "enc_out" in batch:
        caches["ck"], caches["cv"] = batch["enc_out"], batch["enc_out_v"]
    x, new_caches, _ = families.stack_apply(
        params["blocks"], x, cfg, par, mode="decode",
        n_layers=cfg.n_layers, pos=batch["pos"], caches=caches)
    x = norm_apply(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    logits = _logits(params, cfg, x[:, 0])
    return logits, _caches_out(new_caches)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    defs: dict
    loss: Callable[..., Any]
    prefill: Callable[..., Any]
    decode: Callable[..., Any]


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg=cfg, defs=model_defs(cfg), loss=loss_fn,
                 prefill=prefill_fn, decode=decode_fn)
