"""Seeded scenario -> :class:`~repro.core.instance.Instance` sampling.

A :class:`ScenarioConfig` names one *cell* of the structure space: a DAG
family with its ``(width, depth)`` shape knobs, a job count, a fleet (name +
machine count) and the duration/arrival distributions of the paper's
Section 3.1 (exp-distributed base durations, ceil to >= 1 epoch; arrivals
uniform over the next 24 h).  :func:`sample_instance` draws one instance
from a cell given an ``np.random.Generator``; determinism is entirely the
caller's rng seed, so equal seeds reproduce instances bit-for-bit across
processes (property-tested).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.instance import Instance, Job
from repro.scenarios.families import FAMILY_NAMES, build_dag
from repro.scenarios.fleets import FLEET_NAMES, build_fleet


@dataclasses.dataclass(frozen=True)
class ScenarioConfig:
    """One cell of the scenario space (hashable, usable as a dict key)."""

    family: str = "layered"        # DAG family (see scenarios.families)
    n_jobs: int = 6                # jobs per instance
    width: int = 3                 # family width knob (parallelism)
    depth: int = 3                 # family depth knob (critical path)
    n_machines: int = 5            # fleet size
    fleet: str = "homog"           # fleet generator (see scenarios.fleets)
    mean_dur: float = 7.0          # exp mean of base durations (epochs)
    arrival_horizon: int = 96      # arrivals uniform in [0, horizon)

    def validate(self) -> "ScenarioConfig":
        if self.family not in FAMILY_NAMES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.fleet not in FLEET_NAMES:
            raise ValueError(f"unknown fleet {self.fleet!r}")
        if min(self.n_jobs, self.width, self.depth, self.n_machines) < 1:
            raise ValueError(f"non-positive scenario dimension in {self}")
        return self

    def label(self) -> str:
        return (f"{self.family}-w{self.width}d{self.depth}"
                f"-j{self.n_jobs}-m{self.n_machines}-{self.fleet}")


def sample_job(rng: np.random.Generator, cfg: ScenarioConfig) -> Job:
    """One job: a family DAG plus exp(mean_dur) durations and a uniform
    arrival epoch."""
    k, edges = build_dag(cfg.family, rng, cfg.width, cfg.depth)
    durs = np.maximum(1, np.ceil(rng.exponential(cfg.mean_dur, size=k)))
    arrival = int(rng.integers(0, cfg.arrival_horizon))
    return Job(arrival=arrival,
               base_durations=tuple(int(d) for d in durs),
               edges=edges)


def sample_instance(rng: np.random.Generator, cfg: ScenarioConfig) -> Instance:
    """Draw one instance from a scenario cell."""
    cfg.validate()
    jobs = tuple(sample_job(rng, cfg) for _ in range(cfg.n_jobs))
    powers, speeds = build_fleet(cfg.fleet, rng, cfg.n_machines)
    return Instance(jobs=jobs, powers_kw=powers, speeds=speeds)


def sample_batch(rng: np.random.Generator, cfg: ScenarioConfig,
                 n: int) -> list[Instance]:
    """Draw ``n`` independent instances from one cell."""
    return [sample_instance(rng, cfg) for _ in range(n)]
