"""Parametric, seeded scenario generation for the DAG-scheduling repro.

The paper evaluates a handful of hand-rolled Fig. 3 shapes; this package
spans the structure space its sensitivity analysis names as decisive (job
structure x server count) with first-class, seeded generators:

    families   — parametric DAG families (chain, fanout, diamond/series-
                 parallel, random layered, TPC-H-like query plans)
    fleets     — machine-fleet generators (homogeneous, paper's 5-class
                 tiers, randomly mixed tiers)
    generator  — ScenarioConfig (one cell) -> seeded Instance sampling
    batching   — pad mixed-shape instances to one stacked batch (inert
                 padding on the task AND machine axes — see the padding
                 contract on PackedInstance)
    sweep      — the vectorized structure sweep (one XLA program over all
                 cells x instances x gate policies + the offline SA bound)

How to add a family or fleet: see the ``families`` / ``fleets`` module
docstrings.  The padding contract and its property tests: ``batching`` and
``tests/test_scenarios.py``.
"""
from repro.scenarios.batching import aligned_shape, pack_aligned
from repro.scenarios.families import FAMILIES, FAMILY_NAMES, build_dag
from repro.scenarios.fleets import FLEETS, FLEET_NAMES, build_fleet
from repro.scenarios.generator import (ScenarioConfig, sample_batch,
                                       sample_instance, sample_job)
from repro.scenarios.sweep import (SweepSpec, build_batch, learned_summary,
                                   structure_cells, sweep_structure,
                                   trend_summary)

__all__ = [
    "FAMILIES", "FAMILY_NAMES", "build_dag",
    "FLEETS", "FLEET_NAMES", "build_fleet",
    "ScenarioConfig", "sample_batch", "sample_instance", "sample_job",
    "aligned_shape", "pack_aligned",
    "SweepSpec", "build_batch", "learned_summary", "structure_cells",
    "sweep_structure", "trend_summary",
]
