"""Parametric DAG families for the scenario generator.

The paper's sensitivity analysis (and its Fig. 3) shows that *job structure*
drives how much of the carbon reduction is achievable: chains leave no
parallel slack to shift into clean windows, fan-outs leave a lot.  This
module widens the repo's three hand-rolled Fig. 3 shapes into parametric
families spanning that structural axis:

========== =====================================================
family     structure (one job)
========== =====================================================
chain      path of ``depth`` tasks — zero parallelism
fanout     source -> ``width`` branches of ``depth`` tasks -> sink
diamond    ``depth`` series-composed diamond blocks, each a split
           -> ``width`` parallel tasks -> join (series-parallel)
layered    random layered DAG: ``depth`` layers of 1..``width``
           tasks, every task wired to >= 1 parent one layer up
tpch       TPC-H-like multi-stage query plan a la gym-sparksched:
           ``width`` scan leaves, a binary join tree over them,
           then a ``depth``-stage aggregation tail
========== =====================================================

Every builder returns ``(k, edges)`` with local task indices ``0..k-1`` in
topological order (``u < v`` on every edge), the invariant
:func:`repro.core.instance.pack` requires — so acyclicity holds by
construction and is re-checked by :func:`assert_topological` and the
property tests in ``tests/test_scenarios.py``.

Adding a family: write ``def myfam(rng, width, depth) -> (k, edges)``
keeping the topological invariant, and register it in :data:`FAMILIES`.
Builders take an ``np.random.Generator`` even when deterministic so every
family has the same signature (only ``layered`` and ``tpch`` draw from it).
"""
from __future__ import annotations

import numpy as np

Edges = tuple[tuple[int, int], ...]


def chain(rng: np.random.Generator, width: int, depth: int
          ) -> tuple[int, Edges]:
    """Path of ``depth`` tasks (``width`` ignored): the zero-parallelism pole."""
    k = max(1, depth)
    return k, tuple((i, i + 1) for i in range(k - 1))


def fanout(rng: np.random.Generator, width: int, depth: int
           ) -> tuple[int, Edges]:
    """Source -> ``width`` parallel branches of ``depth`` tasks each -> sink."""
    width, depth = max(1, width), max(1, depth)
    k = 2 + width * depth
    edges: list[tuple[int, int]] = []
    sink = k - 1
    for b in range(width):
        head = 1 + b * depth
        edges.append((0, head))
        for i in range(depth - 1):
            edges.append((head + i, head + i + 1))
        edges.append((head + depth - 1, sink))
    return k, tuple(sorted(edges))


def diamond(rng: np.random.Generator, width: int, depth: int
            ) -> tuple[int, Edges]:
    """``depth`` diamond blocks in series (split -> width middles -> join);
    each join doubles as the next block's split predecessor."""
    width, depth = max(1, width), max(1, depth)
    edges: list[tuple[int, int]] = []
    node = 0
    prev_join: int | None = None
    for _ in range(depth):
        split = node
        mids = list(range(split + 1, split + 1 + width))
        join = split + 1 + width
        if prev_join is not None:
            edges.append((prev_join, split))
        for m in mids:
            edges.append((split, m))
            edges.append((m, join))
        prev_join = join
        node = join + 1
    return node, tuple(sorted(edges))


def layered(rng: np.random.Generator, width: int, depth: int
            ) -> tuple[int, Edges]:
    """Random layered DAG: ``depth`` layers of 1..``width`` tasks; every
    non-root task draws >= 1 parent from the previous layer (p = 0.5 per
    candidate plus a guaranteed pick), so the DAG is layer-connected."""
    width, depth = max(1, width), max(1, depth)
    widths = [int(rng.integers(1, width + 1)) for _ in range(depth)]
    edges: list[tuple[int, int]] = []
    node = 0
    prev_layer: list[int] = []
    for w in widths:
        layer = list(range(node, node + w))
        for v in layer:
            if prev_layer:
                parents = [u for u in prev_layer if rng.random() < 0.5]
                if not parents:
                    parents = [prev_layer[int(rng.integers(len(prev_layer)))]]
                edges.extend((u, v) for u in parents)
        prev_layer = layer
        node += w
    return node, tuple(sorted(edges))


def tpch(rng: np.random.Generator, width: int, depth: int
         ) -> tuple[int, Edges]:
    """TPC-H-like multi-stage query plan (cf. gym-sparksched's TPC-H DAGs):
    ``width`` scan leaves, a (randomly paired) binary join tree reducing
    them to one root, then a ``depth``-stage aggregation tail."""
    width, depth = max(2, width), max(1, depth)
    edges: list[tuple[int, int]] = []
    frontier = list(range(width))   # scan stages, no parents
    node = width
    while len(frontier) > 1:        # join tree: pair off until one root
        rng.shuffle(frontier)
        nxt = []
        for i in range(0, len(frontier) - 1, 2):
            edges.append((frontier[i], node))
            edges.append((frontier[i + 1], node))
            nxt.append(node)
            node += 1
        if len(frontier) % 2:       # odd stage joins into the next level
            nxt.append(frontier[-1])
        frontier = nxt
    for _ in range(depth):          # aggregation / output tail
        edges.append((frontier[0], node))
        frontier = [node]
        node += 1
    return node, tuple(sorted(edges))


FAMILIES = {
    "chain": chain,
    "fanout": fanout,
    "diamond": diamond,
    "layered": layered,
    "tpch": tpch,
}

FAMILY_NAMES = tuple(FAMILIES)


def build_dag(family: str, rng: np.random.Generator, width: int,
              depth: int) -> tuple[int, Edges]:
    """Build one job DAG from a named family; returns ``(k, edges)``."""
    try:
        fn = FAMILIES[family]
    except KeyError:
        raise ValueError(
            f"unknown DAG family {family!r}; have {FAMILY_NAMES}") from None
    k, edges = fn(rng, width, depth)
    assert_topological(k, edges, ctx=family)
    return k, edges


def assert_topological(k: int, edges: Edges, ctx: str = "") -> None:
    """Every edge must satisfy ``0 <= u < v < k`` — which makes the graph a
    DAG outright (any cycle needs at least one non-increasing edge)."""
    for (u, v) in edges:
        if not (0 <= u < v < k):
            raise AssertionError(
                f"non-topological edge ({u}, {v}) with k={k}"
                f"{f' in family {ctx}' if ctx else ''}")
