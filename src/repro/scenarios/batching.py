"""Shape-static batching of mixed-shape instances.

Instances from different scenario cells differ in task count (families,
widths, depths, job counts) *and* machine count (fleet sizes).  The JAX
dispatchers and solvers vmap over a stacked
:class:`~repro.core.instance.PackedInstance`, which requires one static
``(T, M)`` — so this module pads every instance to the batch maximum on
both axes and stacks:

* task padding appends masked tasks (``task_mask == False``) that schedule
  instantly and never touch the objectives;
* machine padding appends never-``allowed`` zero-power machines that no
  decoder can select;
* batch padding (:func:`pad_stacked` / ``pack_aligned(pad_batch=...)``)
  appends whole *inert rows* — instances made entirely of padding tasks —
  so the batch axis can be padded to a device multiple for
  :mod:`repro.shard`'s instance-axis sharding.

All three paddings are **inert**: dispatching the padded batch is bit-exact
with the unpadded one on the real tasks and real rows (the padding contract
on :class:`~repro.core.instance.PackedInstance`; every program that vmaps
or shard_maps over the batch axis is row-wise independent, so a padded row
cannot influence a real one — property-tested across all families in
``tests/test_scenarios.py``).
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

import jax.numpy as jnp

from repro.core.instance import Instance, PackedInstance, pack, stack_packed


def aligned_shape(instances: Sequence[Instance]) -> tuple[int, int]:
    """The smallest common ``(pad_tasks, pad_machines)`` for a mixed batch."""
    if not instances:
        raise ValueError("aligned_shape: empty instance sequence")
    return (max(i.n_tasks for i in instances),
            max(i.n_machines for i in instances))


def pack_aligned(instances: Sequence[Instance],
                 pad_tasks: int | None = None,
                 pad_machines: int | None = None,
                 pad_batch: int | None = None) -> PackedInstance:
    """Pack mixed-shape instances to one stacked ``[B, ...]`` batch.

    ``pad_tasks`` / ``pad_machines`` override the computed maxima (e.g. to
    align several independently built batches to one XLA program shape);
    they must cover every instance.  ``pad_batch`` pads the *batch* axis to
    the given row count with inert all-padding rows (see
    :func:`pad_stacked`) — how :mod:`repro.shard` aligns the instance axis
    to a device multiple.
    """
    T, M = aligned_shape(instances)
    T = max(T, pad_tasks or 0)
    M = max(M, pad_machines or 0)
    batch = stack_packed([pack(i, pad_tasks=T, pad_machines=M)
                          for i in instances])
    if pad_batch is not None:
        batch = pad_stacked(batch, pad_batch)
    return batch


def padding_rows(rows: int, T: int, M: int) -> PackedInstance:
    """``rows`` stacked all-padding instances of shape ``(T, M)``.

    Each row follows :func:`repro.core.instance.pack`'s padded-task
    convention exactly — every task masked out, zero duration, runnable
    only on machine 0, no dependencies, zero power — so a padding row
    dispatches instantly and contributes nothing to any objective.
    """
    allowed = np.zeros((rows, T, M), dtype=bool)
    allowed[:, :, 0] = True
    return PackedInstance(
        dur=jnp.zeros((rows, T, M), jnp.int32),
        allowed=jnp.asarray(allowed),
        pred=jnp.zeros((rows, T, T), bool),
        arrival=jnp.zeros((rows, T), jnp.int32),
        job=jnp.zeros((rows, T), jnp.int32),
        task_mask=jnp.zeros((rows, T), bool),
        power=jnp.zeros((rows, M), jnp.float32),
    )


def pad_stacked(batch: PackedInstance, rows: int) -> PackedInstance:
    """Pad a stacked ``[B, ...]`` batch's leading axis to ``rows`` with
    inert all-padding rows (:func:`padding_rows`).

    The batch-axis padding contract: every consumer of a stacked batch
    (``vmap`` or ``shard_map`` over the leading axis) treats rows
    independently, so padded rows can never influence real rows — results
    on ``[:B]`` are bit-exact with the unpadded batch, and callers simply
    slice them off (property-tested in ``tests/test_scenarios.py``).
    """
    B = batch.dur.shape[0]
    if rows < B:
        raise ValueError(f"pad_stacked: rows={rows} < batch size {B}")
    if rows == B:
        return batch
    pad = padding_rows(rows - B, batch.T, batch.M)
    return PackedInstance(*(jnp.concatenate([getattr(batch, f),
                                             getattr(pad, f)])
                            for f in PackedInstance._fields))
