"""Shape-static batching of mixed-shape instances.

Instances from different scenario cells differ in task count (families,
widths, depths, job counts) *and* machine count (fleet sizes).  The JAX
dispatchers and solvers vmap over a stacked
:class:`~repro.core.instance.PackedInstance`, which requires one static
``(T, M)`` — so this module pads every instance to the batch maximum on
both axes and stacks:

* task padding appends masked tasks (``task_mask == False``) that schedule
  instantly and never touch the objectives;
* machine padding appends never-``allowed`` zero-power machines that no
  decoder can select.

Both paddings are **inert**: dispatching the padded instance is bit-exact
with the unpadded one on the real tasks (the padding contract on
:class:`~repro.core.instance.PackedInstance`, property-tested across all
families in ``tests/test_scenarios.py``).
"""
from __future__ import annotations

from typing import Sequence

from repro.core.instance import Instance, PackedInstance, pack, stack_packed


def aligned_shape(instances: Sequence[Instance]) -> tuple[int, int]:
    """The smallest common ``(pad_tasks, pad_machines)`` for a mixed batch."""
    if not instances:
        raise ValueError("aligned_shape: empty instance sequence")
    return (max(i.n_tasks for i in instances),
            max(i.n_machines for i in instances))


def pack_aligned(instances: Sequence[Instance],
                 pad_tasks: int | None = None,
                 pad_machines: int | None = None) -> PackedInstance:
    """Pack mixed-shape instances to one stacked ``[B, ...]`` batch.

    ``pad_tasks`` / ``pad_machines`` override the computed maxima (e.g. to
    align several independently built batches to one XLA program shape);
    they must cover every instance.
    """
    T, M = aligned_shape(instances)
    T = max(T, pad_tasks or 0)
    M = max(M, pad_machines or 0)
    return stack_packed([pack(i, pad_tasks=T, pad_machines=M)
                         for i in instances])
