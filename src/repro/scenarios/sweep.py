"""Vectorized structure sweep: family x shape x fleet grid as one program.

The paper's headline sensitivity claim is that *job structure and server
count* set the achievable fraction of the ~25% carbon reduction.  This
module sweeps that space at XLA scale: every (family, width/depth,
server-count, fleet) cell contributes ``instances_per_cell`` seeded
instances, all cells are padded to one static ``(T, M)`` by
:func:`repro.scenarios.batching.pack_aligned` (padding is inert — see the
padding contract) and the whole sweep runs as

* **one** :func:`~repro.core.solvers.online_jax.sweep_policies` call for the
  carbon-gated online dispatcher (all cells x instances x gate policies),
* **one** :func:`~repro.core.solvers.bilevel.solve_bilevel_batch` call for
  the offline SA bound (the paper's S-stretch bi-level protocol),

instead of the per-instance numpy event loop, which could never cover the
grid.  Every schedule in the sweep is checked by the shared validator
(:func:`repro.core.validate.total_violations_batch`).

:func:`sweep_structure` returns one row of aggregates per cell; the
``benchmarks/structure_sweep.py`` CLI turns them into
``BENCH_structure.json`` and ``tests/test_structure_golden.py`` locks the
tiny grid's values.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import synthesize, validate
from repro.core.instance import PackedInstance
from repro.core.objectives import evaluate, utilization
from repro.core.solvers import solve_bilevel_batch
from repro.core.solvers.annealing import SAConfig
from repro.core.solvers.online_jax import policy_grid, sweep_policies
from repro.scenarios.batching import pack_aligned
from repro.scenarios.generator import ScenarioConfig, sample_batch


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """The whole structure sweep: grid cells + shared knobs."""

    cells: tuple[ScenarioConfig, ...]
    instances_per_cell: int = 4
    seed: int = 2024
    region: str = "AU-SA"
    horizon: int = 768             # forecast/simulation epochs per instance
    thetas: tuple[float, ...] = (0.3, 0.5)
    windows: tuple[int, ...] = (48,)
    stretches: tuple[float, ...] = (1.5, 2.0)
    offline_stretch: float = 1.5   # S of the offline bi-level bound
    sa: SAConfig = SAConfig(pop=32, iters=60, sweeps=2)


def structure_cells(families: Sequence[str],
                    sizes,
                    machine_counts: Sequence[int],
                    fleets: Sequence[str],
                    n_jobs: int = 6) -> tuple[ScenarioConfig, ...]:
    """The full outer product family x (width, depth) x M x fleet.

    ``sizes`` is either one ``[(width, depth), ...]`` list shared by every
    family, or a ``{family: [(width, depth), ...]}`` mapping.  The mapping
    form is how a sweep holds *tasks per job* fixed across families (each
    family's task count is a different function of width/depth), so the
    family axis compares structure at matched load — the paper's Fig. 3
    comparison — rather than structure confounded with job size.
    """
    by_family = (sizes if isinstance(sizes, dict)
                 else {f: sizes for f in families})
    missing = set(families) - set(by_family)
    if missing:
        raise ValueError(f"sizes mapping missing families {sorted(missing)}")
    return tuple(
        ScenarioConfig(family=f, n_jobs=n_jobs, width=w, depth=d,
                       n_machines=m, fleet=fl).validate()
        for f in families for (w, d) in by_family[f]
        for m in machine_counts for fl in fleets)


class SweepBatch(NamedTuple):
    """All cells' instances stacked to one shape (cell_of maps rows back)."""

    batch: "PackedInstance"     # stacked [B, ...]
    intensity: jnp.ndarray      # float32 [B, E]
    cum: jnp.ndarray            # float32 [B, E+1]
    cell_of: np.ndarray         # int [B] — index into spec.cells


def build_batch(spec: SweepSpec) -> SweepBatch:
    """Generate + pad + stack every cell's instances, with per-instance
    carbon windows drawn from one synthesized year (seeded)."""
    rng = np.random.default_rng(spec.seed)
    year = synthesize(spec.region, days=366, seed=spec.seed)
    instances, cell_of = [], []
    for ci, cell in enumerate(spec.cells):
        instances.extend(sample_batch(rng, cell, spec.instances_per_cell))
        cell_of.extend([ci] * spec.instances_per_cell)
    batch = pack_aligned(instances)
    intens, cums = [], []
    for _ in instances:
        w = year.window(int(rng.integers(0, year.n_epochs - spec.horizon)),
                        spec.horizon)
        intens.append(w.intensity)
        cums.append(w.cumulative())
    return SweepBatch(batch, jnp.asarray(np.stack(intens)),
                      jnp.asarray(np.stack(cums)),
                      np.asarray(cell_of))


def _batch_eval(batch, start, assign, cum):
    return jax.vmap(evaluate)(batch, start, assign, cum)


def sweep_structure(spec: SweepSpec, offline: bool = True, learn=None,
                    devices: int | None = None,
                    processes: int | None = None) -> tuple[list[dict], dict]:
    """Run the sweep; returns (one aggregate row per cell, meta).

    Row fields: the cell parameters; greedy-dispatch carbon/makespan/
    utilization means; per-policy mean online savings; the best policy and
    its savings; and (when ``offline``) the SA bi-level bound's savings.
    ``offline=False`` skips the SA bound — the dispatch-only path is fully
    deterministic (no jax.random), which is what the golden regression test
    locks.

    ``learn`` (a :class:`repro.learn.LearnConfig`) adds *learned-theta*
    cells alongside the fixed grid: per (cell, stretch) one gradient-trained
    gate theta, initialized from the best fixed policy at that stretch and
    kept only if its hard-dispatch savings beat the init (so a learned cell
    is ``>=`` its fixed-grid counterpart at equal stretch budget by
    construction; ``improved`` records whether training moved past the
    grid).  Rows gain a ``"learned"`` mapping keyed by stretch; the default
    ``learn=None`` leaves the output bit-identical to before (golden-locked
    path).  The learned path is deterministic too — no PRNG anywhere in the
    relaxation or the Adam loop.

    ``devices`` (int, default None == single device) shards the instance
    axis of every program in the sweep — the gated dispatch, the offline SA
    bound and the learner — over that many local devices via
    :mod:`repro.shard`.  ``processes`` (int, default None == this process
    only) spans those shards across a ``jax.distributed`` fleet —
    ``devices`` then counts devices *per process* (``None`` == all of
    each process's local devices), and every process must be running this
    same call (``tests/harness.py`` / ``python -m tests.harness`` spawn
    that).  Sharded results are **bit-exact** with the single-device sweep
    (the parity contracts ``tests/test_shard.py`` /
    ``tests/test_distributed.py`` and the sharded golden re-runs lock), so
    ``devices``/``processes`` only change wall-clock, never a number.
    """
    sharded = devices is not None or processes is not None
    if sharded:
        from repro.shard import (bilevel_sharded, dispatch_sharded,
                                 eval_theta_sharded, train_sharded)
    sb = build_batch(spec)
    B = int(sb.cell_of.shape[0])

    if not sharded:
        res = sweep_policies(sb.batch, sb.intensity, spec.thetas,
                             spec.windows, spec.stretches)
    else:
        res = dispatch_sharded(sb.batch, sb.intensity, spec.thetas,
                               spec.windows, spec.stretches, devices=devices,
                               processes=processes)
    mask = np.asarray(sb.batch.task_mask)
    if not (np.asarray(res.greedy.scheduled) | ~mask).all():
        raise AssertionError("greedy dispatch incomplete: raise spec.horizon")
    if not (np.asarray(res.gated.scheduled) | ~mask[:, None, :]).all():
        raise AssertionError("gated dispatch incomplete: raise spec.horizon")
    v = validate.total_violations_batch(sb.batch, res.greedy.start,
                                        res.greedy.assign)
    assert int(np.asarray(v).sum()) == 0, "greedy schedule infeasible"
    v = validate.total_violations_batch(sb.batch, res.gated.start,
                                        res.gated.assign)
    assert int(np.asarray(v).sum()) == 0, "gated schedule infeasible"

    th, wi, sx = (np.asarray(a) for a in
                  policy_grid(spec.thetas, spec.windows, spec.stretches))
    P = th.shape[0]
    base = _batch_eval(sb.batch, res.greedy.start, res.greedy.assign, sb.cum)
    base_carbon = np.asarray(base.carbon)                        # [B]
    base_ms = np.asarray(base.makespan).astype(float)            # [B]
    util = np.asarray(jax.vmap(utilization)(
        sb.batch, res.greedy.start, res.greedy.assign))          # [B]
    sav = np.zeros((B, P))
    ms_ratio = np.zeros((B, P))
    for j in range(P):
        gated = _batch_eval(sb.batch, res.gated.start[:, j],
                            res.gated.assign[:, j], sb.cum)
        sav[:, j] = 1.0 - np.asarray(gated.carbon) / base_carbon
        ms_ratio[:, j] = np.asarray(gated.makespan) / np.maximum(base_ms, 1.0)

    if offline:
        keys = jax.random.split(jax.random.key(spec.seed), B)
        if not sharded:
            bires = solve_bilevel_batch(sb.batch, sb.cum, keys,
                                        objective="carbon",
                                        stretch=spec.offline_stretch,
                                        cfg1=spec.sa, cfg2=spec.sa)
        else:
            bires = bilevel_sharded(sb.batch, sb.cum, keys, devices=devices,
                                    processes=processes,
                                    objective="carbon",
                                    stretch=spec.offline_stretch,
                                    cfg1=spec.sa, cfg2=spec.sa)
        off_sav = np.asarray(bires.carbon_savings)               # [B]

    learned_by_cell: dict[int, dict] = {}
    if learn is not None:
        from repro.learn import evaluate_theta, train_gate   # lazy: optional
        if learn.machine_rule != "earliest_finish":
            # The fixed grid above (sweep_policies) and its greedy baseline
            # are earliest_finish; comparing a differently-ruled learned
            # policy against them would silently misreport savings.
            raise ValueError(
                "sweep_structure(learn=...) compares against the "
                "earliest_finish fixed grid; train other machine rules "
                "directly via repro.learn.train_gate")
        n_cells = len(spec.cells)
        cell_idx = [np.where(sb.cell_of == ci)[0] for ci in range(n_cells)]
        # Greedy baseline already dispatched above — reuse it so the learner
        # doesn't re-run the whole-batch greedy pass per stretch.
        greedy_ref = (res.greedy_makespan, base.carbon)
        for sx_val in spec.stretches:
            # Best fixed policy at this stretch per cell -> the learner's
            # init (and the fallback if gradient training doesn't improve).
            pol = np.where(np.isclose(sx, float(sx_val)))[0]
            theta0 = np.zeros(n_cells, np.float32)
            window0 = np.zeros(n_cells, np.int32)
            fixed_best = np.zeros(n_cells)
            for ci in range(n_cells):
                psav = sav[np.ix_(cell_idx[ci], pol)].mean(axis=0)
                j = pol[int(psav.argmax())]
                theta0[ci], window0[ci] = th[j], wi[j]
                fixed_best[ci] = psav.max()
            wins = window0[sb.cell_of]
            if not sharded:
                tr = train_gate(sb.batch, sb.intensity, sb.cum, sb.cell_of,
                                wins, float(sx_val), theta0, cfg=learn,
                                baseline=greedy_ref)
            else:
                tr = train_sharded(sb.batch, sb.intensity, sb.cum,
                                   sb.cell_of, wins, float(sx_val), theta0,
                                   cfg=learn, baseline=greedy_ref,
                                   devices=devices, processes=processes)
            theta_l = np.asarray(tr.theta)
            eval_fn = (evaluate_theta if not sharded else
                       functools.partial(eval_theta_sharded,
                                         devices=devices,
                                         processes=processes))
            s_l, _, _, _ = eval_fn(
                sb.batch, sb.intensity, sb.cum,
                jnp.asarray(theta_l)[sb.cell_of], wins, float(sx_val),
                baseline=greedy_ref)
            s_l = np.asarray(s_l)
            for ci in range(n_cells):
                lsav = float(s_l[cell_idx[ci]].mean())
                improved = lsav > float(fixed_best[ci]) + 1e-12
                learned_by_cell.setdefault(ci, {})[str(float(sx_val))] = {
                    "theta": round(float(theta_l[ci] if improved
                                         else theta0[ci]), 4),
                    "init_theta": round(float(theta0[ci]), 4),
                    "window": int(window0[ci]),
                    "savings_pct": round(
                        100 * max(lsav, float(fixed_best[ci])), 3),
                    "trained_savings_pct": round(100 * lsav, 3),
                    "fixed_best_savings_pct": round(
                        100 * float(fixed_best[ci]), 3),
                    "improved": bool(improved),
                }

    rows = []
    for ci, cell in enumerate(spec.cells):
        sel = sb.cell_of == ci
        psav = sav[sel].mean(axis=0)                             # [P]
        best = int(psav.argmax())
        row = {
            "family": cell.family, "width": cell.width, "depth": cell.depth,
            "n_jobs": cell.n_jobs, "n_machines": cell.n_machines,
            "fleet": cell.fleet,
            "tasks_per_job": int(mask[sel].sum() // cell.n_jobs
                                 // int(sel.sum())),
            "greedy_carbon_g": round(float(base_carbon[sel].mean()), 3),
            "greedy_makespan": round(float(base_ms[sel].mean()), 3),
            "greedy_utilization_pct": round(100 * float(util[sel].mean()), 3),
            "online_savings_pct_by_policy": [
                round(100 * float(s), 3) for s in psav],
            "online_best_savings_pct": round(100 * float(psav[best]), 3),
            "online_best_policy": {"theta": round(float(th[best]), 4),
                                   "window": int(wi[best]),
                                   "stretch": round(float(sx[best]), 4)},
            "online_makespan_ratio": round(
                float(ms_ratio[sel, best].mean()), 3),
        }
        if offline:
            row["offline_bound_savings_pct"] = round(
                100 * float(off_sav[sel].mean()), 3)
        if learn is not None:
            row["learned"] = learned_by_cell[ci]
        rows.append(row)

    meta = {
        "instances": B,
        "instances_per_cell": spec.instances_per_cell,
        "cells": len(spec.cells),
        "policies": int(P),
        "grid": {"thetas": list(spec.thetas),
                 "windows": [int(w) for w in spec.windows],
                 "stretches": list(spec.stretches)},
        "horizon": spec.horizon,
        "region": spec.region,
        "seed": spec.seed,
        "pad_tasks": int(sb.batch.T),
        "pad_machines": int(sb.batch.M),
        "offline": bool(offline),
        "offline_stretch": spec.offline_stretch,
        "devices": (int(devices) if devices is not None else
                    len(jax.local_devices()) if sharded else 1),
        "processes": int(processes) if processes is not None else 1,
    }
    if learn is not None:
        meta["learn"] = dict(learn._asdict())
    return rows, meta


def learned_summary(rows: list[dict]) -> tuple[dict, bool]:
    """Learned vs best-fixed savings per family x stretch.

    Returns ``(summary, acceptance)``: per family and stretch the mean
    learned and mean best-fixed-grid savings over cells (equal stretch
    budget by construction — both numbers come from the same budget), plus
    whether the learned policy is ``>=`` the fixed grid *everywhere* — the
    acceptance bar ``benchmarks/learned_gate.py`` reports.
    """
    fams: dict = {}
    for r in rows:
        for sx_key, cell in r.get("learned", {}).items():
            d = fams.setdefault(r["family"], {}).setdefault(
                sx_key, {"learned": [], "fixed": [], "improved": 0})
            d["learned"].append(cell["savings_pct"])
            d["fixed"].append(cell["fixed_best_savings_pct"])
            d["improved"] += int(cell["improved"])
    out: dict = {}
    ok = True
    for fam, by_sx in sorted(fams.items()):
        out[fam] = {}
        for sx_key, d in sorted(by_sx.items()):
            lm = float(np.mean(d["learned"]))
            fm = float(np.mean(d["fixed"]))
            ok = ok and lm >= fm - 1e-9
            out[fam][sx_key] = {
                "learned_savings_pct": round(lm, 3),
                "fixed_best_savings_pct": round(fm, 3),
                "improved_cells": int(d["improved"]),
                "cells": len(d["learned"]),
            }
    return out, bool(ok)


def trend_summary(rows: list[dict]) -> dict:
    """Savings vs structure / server count, averaged over the other axes —
    the qualitative shape the paper reports (savings grow with
    parallelism-friendly structure and with server count)."""
    def mean_by(key, field):
        out: dict = {}
        for r in rows:
            if field in r:
                out.setdefault(r[key], []).append(r[field])
        return {k: round(float(np.mean(v)), 3) for k, v in sorted(out.items())}

    summary = {
        "online_best_savings_pct_by_family":
            mean_by("family", "online_best_savings_pct"),
        "online_best_savings_pct_by_machines":
            mean_by("n_machines", "online_best_savings_pct"),
        "online_best_savings_pct_by_fleet":
            mean_by("fleet", "online_best_savings_pct"),
    }
    if any("offline_bound_savings_pct" in r for r in rows):
        summary.update({
            "offline_bound_savings_pct_by_family":
                mean_by("family", "offline_bound_savings_pct"),
            "offline_bound_savings_pct_by_machines":
                mean_by("n_machines", "offline_bound_savings_pct"),
        })
    return summary
