"""Heterogeneous machine-fleet generators (speed/power tiers).

The paper's Section 3.1 uses five server classes with power draw growing
faster than speed (so fast servers are energy-inefficient — the source of
the carbon/energy tension in its heterogeneous results).  This module turns
that single hand-rolled menu into named fleet generators over any machine
count:

=========== ==========================================================
fleet       composition
=========== ==========================================================
homog       all baseline: 1 kW, speed 1 (the paper's homogeneous setup)
tiered      the paper's 5-class menu cycled deterministically over the
            machines (machine ``i`` gets class ``i mod 5``)
mixed       each machine draws a class uniformly at random, with one
            machine forced to the baseline class so every fleet has a
            speed-1 reference server
=========== ==========================================================

Every generator returns ``(powers_kw, speeds)`` tuples ready for
:class:`repro.core.instance.Instance`.  Adding a fleet: write
``def myfleet(rng, n_machines) -> (powers, speeds)`` and register it in
:data:`FLEETS`.
"""
from __future__ import annotations

import numpy as np

from repro.core.instance import HETERO_POWERS_KW, HETERO_SPEEDS

Fleet = tuple[tuple[float, ...], tuple[float, ...]]

_N_CLASSES = len(HETERO_POWERS_KW)
_BASELINE_CLASS = HETERO_SPEEDS.index(1.0)


def homog(rng: np.random.Generator, n_machines: int) -> Fleet:
    """All machines identical: 1 kW at speed 1."""
    return (1.0,) * n_machines, (1.0,) * n_machines


def tiered(rng: np.random.Generator, n_machines: int) -> Fleet:
    """The paper's 5-class menu, cycled deterministically over the fleet."""
    powers = tuple(HETERO_POWERS_KW[i % _N_CLASSES] for i in range(n_machines))
    speeds = tuple(HETERO_SPEEDS[i % _N_CLASSES] for i in range(n_machines))
    return powers, speeds


def mixed(rng: np.random.Generator, n_machines: int) -> Fleet:
    """Uniform random class per machine; machine 0 pinned to the baseline
    class so every fleet has a speed-1 reference server."""
    cls = rng.integers(0, _N_CLASSES, size=n_machines)
    cls[0] = _BASELINE_CLASS
    return (tuple(HETERO_POWERS_KW[c] for c in cls),
            tuple(HETERO_SPEEDS[c] for c in cls))


FLEETS = {
    "homog": homog,
    "tiered": tiered,
    "mixed": mixed,
}

FLEET_NAMES = tuple(FLEETS)


def build_fleet(fleet: str, rng: np.random.Generator,
                n_machines: int) -> Fleet:
    """Build a named fleet; returns ``(powers_kw, speeds)``."""
    if n_machines < 1:
        raise ValueError(f"n_machines must be >= 1, got {n_machines}")
    try:
        fn = FLEETS[fleet]
    except KeyError:
        raise ValueError(
            f"unknown fleet {fleet!r}; have {FLEET_NAMES}") from None
    return fn(rng, n_machines)
