"""Counters, gauges and histograms with a snapshot API.

The host-side metrics registry the engines and benchmarks expose:
:meth:`repro.stream.engine.StreamEngine.summary` is a view over one of
these, and ``benchmarks/stream_serve.py`` reads distributions from it
instead of keeping ad-hoc counters.  Everything here is plain Python on
the host — nothing is ever traced by JAX, so metrics can never move a
dispatch decision (the same bit-exactness contract as
:mod:`repro.obs.trace`).

Instruments:

* :class:`Counter` — monotone ``inc``;
* :class:`Gauge` — last-write-wins ``set``;
* :class:`Histogram` — ``observe`` samples, snapshot reports
  count/mean/p50/p90/max (the queue-delay and wall-clock distributions).
"""
from __future__ import annotations

import numpy as np


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int | float = 1) -> None:
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v


class Histogram:
    __slots__ = ("samples",)

    def __init__(self):
        self.samples: list[float] = []

    def observe(self, x: float) -> None:
        self.samples.append(float(x))

    def percentile(self, q: float) -> float:
        if not self.samples:
            return 0.0
        return float(np.percentile(np.asarray(self.samples, np.float64), q))

    def snapshot(self) -> dict:
        if not self.samples:
            return {"count": 0, "mean": 0.0, "p50": 0.0, "p90": 0.0,
                    "max": 0.0}
        a = np.asarray(self.samples, np.float64)
        return {"count": int(a.size), "mean": float(a.mean()),
                "p50": float(np.percentile(a, 50)),
                "p90": float(np.percentile(a, 90)), "max": float(a.max())}


class MetricsRegistry:
    """Get-or-create instruments by name; one ``snapshot()`` dict out.

    Names are free-form; the type is fixed by whichever of
    ``counter``/``gauge``/``histogram`` first claims the name (claiming it
    again with a different type raises — a silent type swap would corrupt
    the snapshot).
    """

    def __init__(self):
        self._instruments: dict[str, Counter | Gauge | Histogram] = {}

    def _get(self, name: str, cls):
        inst = self._instruments.get(name)
        if inst is None:
            inst = self._instruments[name] = cls()
        elif not isinstance(inst, cls):
            raise TypeError(f"metric {name!r} is {type(inst).__name__}, "
                            f"not {cls.__name__}")
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def reset(self) -> None:
        self._instruments.clear()

    def snapshot(self) -> dict:
        """Flat name -> value dict: counters/gauges as scalars, histograms
        as their distribution dicts.  Safe to ``json.dump``."""
        out: dict = {}
        for name, inst in sorted(self._instruments.items()):
            if isinstance(inst, Histogram):
                out[name] = inst.snapshot()
            else:
                v = inst.value
                out[name] = float(v) if isinstance(v, float) else v
        return out
