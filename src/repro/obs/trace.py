"""Structured event tracing with a Chrome-trace/Perfetto exporter.

Design constraints, in order:

1. **Bit-exactness.**  Tracing must never change what the engines compute.
   Every record call happens on the host, *around* jitted steps, reading
   values that were (or would be) computed anyway.  Nothing in this module
   is ever traced by JAX.
2. **Zero overhead when off.**  The module-level default is
   :data:`NULL_TRACER`, whose record methods are empty one-liners; engines
   hold a tracer reference and call through unconditionally.  Per-tick
   *loops* of record calls should additionally guard on
   ``tracer.enabled`` so the off path does no per-tick work at all.
3. **Two clocks.**  Engine events are timestamped in *epochs* (the
   simulation clock — deterministic, golden-safe); host-side wall-clock
   spans (jit compile vs warm step) use an injectable ``clock`` so tests
   can fake it.  The exporter maps epochs to milliseconds (1 epoch = 1 ms)
   on the simulation track and keeps wall spans on their own track.

Event vocabulary (the schema ``docs/observability.md`` documents):

====================  ====  =====================================================
name                  ph    meaning
====================  ====  =====================================================
``job:<rid>``         X     lane-occupancy span, admission -> completion
``admit``             i     job admitted (lane, rid, queue_delay, budget,
                            carbon intensity at dispatch time)
``reject``            i     job rejected (too late to finish greedily)
``evict``             i     job evicted from its lane (carbon, savings)
``gate``              C     carbon gate state per tick (dirty 0/1)
``carbon_gpkwh``      C     carbon intensity at the tick
``lanes_active``      C     occupied lanes per tick
``queue_len``         C     jobs waiting for a lane per tick
``forecast_resolve``  i     MPC/forecast re-quantile boundary
``xla:<name>``        X     wall-clock span of one jitted call
                            (args.first_call marks the compile)
====================  ====  =====================================================

Enable globally with ``REPRO_TRACE=1`` (checked on every
:func:`get_tracer` call, so tests can monkeypatch the environment), or
pass an explicit :class:`Tracer` to an engine.  Export with
:meth:`Tracer.export` and open the JSON at https://ui.perfetto.dev.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable

# Exported simulation timebase: 1 epoch = 1 ms = 1000 Chrome-trace us.
US_PER_EPOCH = 1000

# pids separate the two clocks into two Perfetto process groups.
PID_SIM = 1        # simulation events, epoch timebase
PID_WALL = 2       # host wall-clock spans (jit compile / warm steps)

# tids on the simulation track: lanes occupy 0..n_lanes-1, these sit below.
TID_COUNTERS = 1000
TID_EVENTS = 1001


class Tracer:
    """In-memory structured event log (host-side only; see module doc)."""

    enabled = True

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self._clock = clock
        self.events: list[dict] = []
        self._first_calls: set[str] = set()

    # -- simulation-clock records (timestamps are epochs) -------------------

    def instant(self, name: str, t: int, **args: Any) -> None:
        """Point event at epoch ``t`` (admission, rejection, eviction...)."""
        self.events.append({"name": name, "ph": "i", "t": int(t),
                            "args": args})

    def span(self, name: str, t0: int, t1: int, lane: int | None = None,
             **args: Any) -> None:
        """Duration event over epochs ``[t0, t1)`` — a lane-occupancy bar."""
        self.events.append({"name": name, "ph": "X", "t": int(t0),
                            "dur": max(int(t1) - int(t0), 0),
                            "lane": lane, "args": args})

    def counter(self, name: str, t: int, value: float) -> None:
        """Counter track sample at epoch ``t`` (gate state, occupancy...)."""
        self.events.append({"name": name, "ph": "C", "t": int(t),
                            "value": float(value)})

    # -- wall-clock records --------------------------------------------------

    def wall_span(self, name: str, seconds: float, **args: Any) -> None:
        """Host wall-clock span that just ended (duration known)."""
        self.events.append({"name": name, "ph": "X", "wall_end": self._clock(),
                            "wall_dur": float(seconds), "args": args})

    def timed(self, name: str, fn: Callable, *args: Any, **kwargs: Any):
        """Call ``fn`` and record its wall-clock span, blocking on the result
        so the span covers device execution (values are unchanged —
        ``block_until_ready`` is an identity on the data).

        The first call per ``name`` is flagged ``first_call=True`` — with
        jitted callees that is the compile+execute span; later calls are
        warm steps.  This is the ONLY place tracing touches a jitted
        function, and it stays strictly on the host side of the boundary.
        """
        import jax
        first = name not in self._first_calls
        self._first_calls.add(name)
        t0 = self._clock()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        self.wall_span(f"xla:{name}", self._clock() - t0, first_call=first)
        return out

    # -- export ----------------------------------------------------------------

    def to_chrome_trace(self, lane_names: dict[int, str] | None = None
                        ) -> dict:
        """Render the log as a Chrome-trace/Perfetto ``traceEvents`` dict.

        Simulation events land on pid 1 (lanes as threads, counters on a
        counter track); wall-clock spans on pid 2.  Load the JSON in
        https://ui.perfetto.dev (or chrome://tracing) to see the lane x time
        timeline next to the carbon/gate counter tracks.
        """
        out: list[dict] = [
            {"ph": "M", "pid": PID_SIM, "name": "process_name",
             "args": {"name": "simulation (1 epoch = 1 ms)"}},
            {"ph": "M", "pid": PID_WALL, "name": "process_name",
             "args": {"name": "host wall clock"}},
            {"ph": "M", "pid": PID_SIM, "tid": TID_EVENTS,
             "name": "thread_name", "args": {"name": "events"}},
        ]
        for lane, label in (lane_names or {}).items():
            out.append({"ph": "M", "pid": PID_SIM, "tid": int(lane),
                        "name": "thread_name", "args": {"name": label}})
        wall0 = min((e["wall_end"] - e["wall_dur"] for e in self.events
                     if "wall_end" in e), default=0.0)
        for e in self.events:
            if "wall_end" in e:                       # host wall-clock span
                start_us = (e["wall_end"] - e["wall_dur"] - wall0) * 1e6
                out.append({"name": e["name"], "ph": "X", "pid": PID_WALL,
                            "tid": 0, "ts": start_us,
                            "dur": e["wall_dur"] * 1e6,
                            "args": e.get("args", {})})
                continue
            ts = e["t"] * US_PER_EPOCH
            if e["ph"] == "C":
                out.append({"name": e["name"], "ph": "C", "pid": PID_SIM,
                            "tid": TID_COUNTERS, "ts": ts,
                            "args": {"value": e["value"]}})
            elif e["ph"] == "X":
                tid = e["lane"] if e.get("lane") is not None else TID_EVENTS
                out.append({"name": e["name"], "ph": "X", "pid": PID_SIM,
                            "tid": int(tid), "ts": ts,
                            "dur": e["dur"] * US_PER_EPOCH,
                            "args": e.get("args", {})})
            else:
                out.append({"name": e["name"], "ph": "i", "pid": PID_SIM,
                            "tid": TID_EVENTS, "ts": ts, "s": "t",
                            "args": e.get("args", {})})
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def export(self, path: str, lane_names: dict[int, str] | None = None
               ) -> str:
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(lane_names), f)
            f.write("\n")
        return path


class _NullTracer(Tracer):
    """The off switch: every record method is a no-op (and ``enabled`` is
    False so per-tick record loops can skip building their arguments)."""

    enabled = False

    def __init__(self):
        super().__init__()

    def instant(self, *a: Any, **k: Any) -> None:
        pass

    def span(self, *a: Any, **k: Any) -> None:
        pass

    def counter(self, *a: Any, **k: Any) -> None:
        pass

    def wall_span(self, *a: Any, **k: Any) -> None:
        pass

    def timed(self, name: str, fn: Callable, *args: Any, **kwargs: Any):
        return fn(*args, **kwargs)


NULL_TRACER = _NullTracer()

_GLOBAL: Tracer | None = None


def set_tracer(tracer: Tracer | None) -> None:
    """Install (or clear) the process-global tracer."""
    global _GLOBAL
    _GLOBAL = tracer


def trace_enabled() -> bool:
    """True when a global tracer is installed or ``REPRO_TRACE`` is set to a
    truthy value.  Reads the environment on every call so tests can
    monkeypatch it."""
    if _GLOBAL is not None:
        return True
    return os.environ.get("REPRO_TRACE", "") not in ("", "0")


def get_tracer() -> Tracer:
    """The ambient tracer: the installed global, a fresh env-enabled one, or
    :data:`NULL_TRACER`.  ``REPRO_TRACE=1`` lazily installs a global tracer
    on first use so one process-wide log accumulates across engines."""
    global _GLOBAL
    if _GLOBAL is not None:
        return _GLOBAL
    if os.environ.get("REPRO_TRACE", "") not in ("", "0"):
        _GLOBAL = Tracer()
        return _GLOBAL
    return NULL_TRACER


def traced_xla_call(name: str, fn: Callable, *args: Any, **kwargs: Any):
    """Host-side boundary wrapper for jitted entry points.

    With tracing off this is exactly ``fn(*args, **kwargs)`` — no clock
    reads, no blocking, nothing.  With tracing on it records the call's
    wall-clock span (compile vs warm flagged per name).  Values are
    identical either way; the bit-exact telemetry contract rests on this.
    """
    tracer = get_tracer()
    if not tracer.enabled:
        return fn(*args, **kwargs)
    return tracer.timed(name, fn, *args, **kwargs)
