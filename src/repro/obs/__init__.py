"""Observability layer: event tracing + metrics, zero-overhead when off.

Two host-side primitives threaded through the engines and benchmarks:

* :mod:`repro.obs.trace` — structured event tracing with a
  Chrome-trace/Perfetto JSON exporter.  A run of the streaming dispatch
  engine renders as a lane x time timeline next to the carbon-intensity
  counter track.  Enabled explicitly (pass a :class:`Tracer`) or globally
  via ``REPRO_TRACE=1``; the default is a no-op null tracer.
* :mod:`repro.obs.metrics` — counters/gauges/histograms with a
  ``snapshot()`` API; :meth:`repro.stream.engine.StreamEngine.summary`
  and the benchmark harness are built on it.

The hard contract (property- and golden-tested in ``tests/test_obs.py``):
telemetry-on is **bit-exact** to telemetry-off.  All collection happens on
the host *around* jitted steps — never inside traced code — so enabling
tracing can never move a dispatch decision, a gate threshold, or a golden.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                               MetricsRegistry)
from repro.obs.trace import (NULL_TRACER, Tracer, get_tracer,  # noqa: F401
                             set_tracer, trace_enabled, traced_xla_call)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "NULL_TRACER", "Tracer", "get_tracer", "set_tracer", "trace_enabled",
    "traced_xla_call",
]
