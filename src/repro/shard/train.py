"""Sharded gate-policy training: the Adam loop split over instances.

:func:`train_sharded` is the multi-device twin of
:func:`repro.learn.train.train_gate`: the same single-program ``lax.scan``
over Adam steps, with the per-instance relaxation work — the expensive
epoch-scan forward *and* its backward — sharded over the instance axis.

**Bit-exact by canonical reduction.**  Cross-row reductions are where
naive data parallelism loses exactness: per-device partial sums combined
by ``psum`` reassociate float additions differently for every device
count.  This module never psums.  Instead each device computes *per-row*
loss terms and gradients — each row's gradient seeded with exactly the
``1/B`` cotangent that ``jnp.mean``'s backward emits, so per-row float
work matches the single-device fused backward op for op — then
``all_gather`` reassembles them into original row order on every device,
padded rows are sliced off, and the final reduction (``sum`` over the row
axis of a ``[B, G, 2]`` array) runs replicated, in one fixed association,
identical for 1, 2, 4 or 8 devices.  The gathered arrays are tiny (per-row
scalars and ``[G, 2]`` grads); the sharded term is the dispatch-sized
forward/backward, so compute still scales with the mesh.

Parameters, optimizer state and the scan carry are replicated; every
device runs the identical (deterministic) Adam update, so replication is
preserved without a collective.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

import functools

from repro.core.instance import PackedInstance
from repro.core.objectives import carbon, makespan
from repro.core.solvers.online_jax import online_greedy_jax, sorted_windows
from repro.learn.train import (LearnConfig, TrainResult, _hard_eval,
                               build_train_step, logit, run_train_scan,
                               train_opt_cfg)
from repro.shard.batch import (AXIS, _make_global, _pad_rows, instance_mesh,
                               round_up, run_rows_sharded)
from repro.shard.compat import shard_map_compat


@functools.lru_cache(maxsize=128)
def _per_shard_greedy(n_epochs: int, machine_rule: str):
    def per_shard(b, cm):
        def one(inst, c):
            g = online_greedy_jax(inst, n_epochs, machine_rule=machine_rule)
            return (makespan(inst, g.start, g.assign),
                    carbon(inst, g.start, g.assign, c))
        return jax.vmap(one)(b, cm)

    return per_shard


@functools.lru_cache(maxsize=128)
def _per_shard_hard_eval(max_window: int, n_epochs: int, machine_rule: str):
    def per_shard(b, it, cm, th, wi, bud):
        return _hard_eval(b, it, cm, th, wi, bud, max_window, n_epochs,
                          machine_rule)

    return per_shard


def greedy_sharded(batch: PackedInstance, cum, n_epochs: int,
                   machine_rule: str = "earliest_finish",
                   devices: int | None = None,
                   processes: int | None = None):
    """Sharded :func:`repro.learn.train.greedy_reference`:
    per-instance greedy baseline ``(makespan [B], carbon [B])``."""
    return run_rows_sharded(_per_shard_greedy(n_epochs, machine_rule),
                            (batch, jnp.asarray(cum)), devices=devices,
                            processes=processes)


def _train_sharded(batch, intensity, cum, group_of, window, budget,
                   base_carbon, ms0, feats, raw0, cfg: LearnConfig,
                   max_window: int, n_epochs: int,
                   devices: int | None,
                   processes: int | None = None) -> TrainResult:
    mesh = instance_mesh(devices, processes=processes)
    B = int(intensity.shape[0])
    rows = round_up(B, int(mesh.size))
    pads = tuple(_pad_rows(a, rows) for a in
                 (batch, intensity, cum, group_of, window, budget,
                  base_carbon, ms0, feats))
    # The exact cotangent jnp.mean's backward seeds every row with.
    inv_b = jnp.float32(1.0) / jnp.float32(B)
    opt_cfg = train_opt_cfg(cfg)

    # Full-batch (unpadded, replicated) normalizers for the value path.
    base_c_full = jnp.maximum(base_carbon, 1e-6)
    ms_norm_full = jnp.maximum(ms0.astype(jnp.float32), 1.0)

    def gather_rows(x):
        # Gather per-row pieces into original row order and drop padded
        # rows — the canonical reduce then runs replicated, with one
        # association for every device count.
        return jax.lax.all_gather(x, AXIS, axis=0, tiled=True)[:B]

    def body(b_sh, inten_sh, cum_sh, gid_sh, win_sh, bud_sh, basec_sh,
             ms0_sh, feats_sh, raw0_rep, basec_rep, msn_rep):
        sv_sh, n_sh = jax.vmap(lambda i, w: sorted_windows(i, w, max_window))(
            inten_sh, win_sh)
        base_c = jnp.maximum(basec_sh, 1e-6)
        ms_norm = jnp.maximum(ms0_sh.astype(jnp.float32), 1.0)

        # The single shared copy of the update math (learn.train): same
        # per-row loss, same ordered reductions — only the rows this
        # device computes differ, and gather_rows puts them back.
        step = build_train_step(
            cfg, opt_cfg, n_epochs, inv_b,
            row_args=(b_sh, cum_sh, inten_sh, sv_sh, n_sh, gid_sh,
                      feats_sh, bud_sh, base_c, ms_norm),
            reduce_rows=gather_rows, value_norms=(basec_rep, msn_rep))
        raw, (losses, ratios, thetas) = run_train_scan(step, raw0_rep,
                                                       opt_cfg, cfg.steps)
        return raw, losses, ratios, thetas

    fn = shard_map_compat(
        body, mesh=mesh,
        in_specs=(P(AXIS),) * len(pads) + (P(),) * 3,
        # Everything returned is replicated: every device holds the full
        # gathered rows and runs the identical deterministic reduction and
        # Adam update.
        out_specs=P())
    if processes is None:
        raw, losses, ratios, thetas = jax.jit(fn)(*pads, raw0, base_c_full,
                                                  ms_norm_full)
    else:
        # Multi-process: same program, inputs lifted to global arrays —
        # row shards by mesh position, replicated leaves everywhere.  The
        # replicated outputs come back to host so callers see plain local
        # arrays, identical to the single-process result.
        g = tuple(_make_global(p, mesh) for p in pads) + tuple(
            _make_global(x, mesh, rows=False)
            for x in (raw0, base_c_full, ms_norm_full))
        raw, losses, ratios, thetas = jax.tree.map(
            lambda x: jnp.asarray(np.asarray(x)), jax.jit(fn)(*g))
    return TrainResult(raw=raw, theta=jax.nn.sigmoid(raw[:, 0]),
                       loss_curve=losses, carbon_curve=ratios,
                       theta_curve=thetas)


def train_sharded(batch: PackedInstance, intensity, cum, group_of, window,
                  stretch: float, theta0, cfg: LearnConfig = LearnConfig(),
                  feats=None, baseline=None,
                  devices: int | None = None,
                  processes: int | None = None) -> TrainResult:
    """:func:`repro.learn.train.train_gate` with instances sharded over
    ``devices`` (default: all local devices).

    Same signature plus ``devices``/``processes`` (``processes=P`` spans
    the ``jax.distributed`` fleet, ``devices`` per process), same
    :class:`~repro.learn.train.TrainResult`, bit-exact with the
    single-device learner — the parity and device-count-invariance
    contracts ``tests/test_shard.py`` / ``tests/test_distributed.py`` lock.
    """
    intensity = jnp.asarray(intensity, jnp.float32)
    n_epochs = int(intensity.shape[-1])
    window = np.asarray(window, np.int32)
    max_window = int(window.max())
    ms0, base_c = (baseline if baseline is not None else
                   greedy_sharded(batch, cum, n_epochs, cfg.machine_rule,
                                  devices=devices, processes=processes))
    ms0 = jnp.asarray(ms0, jnp.int32)
    base_c = jnp.asarray(base_c, jnp.float32)
    budget = (jnp.float32(stretch) * ms0.astype(jnp.float32)).astype(
        jnp.int32)
    theta0 = jnp.asarray(theta0, jnp.float32)
    raw0 = jnp.stack([logit(theta0), jnp.zeros_like(theta0)], axis=1)
    if feats is None:
        feats = jnp.zeros(intensity.shape, jnp.float32)
    return _train_sharded(batch, intensity, jnp.asarray(cum),
                          jnp.asarray(group_of), jnp.asarray(window), budget,
                          base_c, ms0, jnp.asarray(feats, jnp.float32), raw0,
                          cfg, max_window, n_epochs, devices,
                          processes=processes)


def eval_theta_sharded(batch: PackedInstance, intensity, cum, theta, window,
                       stretch: float,
                       machine_rule: str = "earliest_finish", baseline=None,
                       devices: int | None = None,
                       processes: int | None = None):
    """Sharded :func:`repro.learn.train.evaluate_theta`: hard-dispatch
    evaluation of learned thetas, instances split over ``devices``
    (per process when ``processes=P`` spans the fleet).
    Returns the same ``(savings, gated_carbon, base_carbon, ms_ratio)``
    per-instance arrays, bit-exact with the single-device evaluation."""
    intensity = jnp.asarray(intensity, jnp.float32)
    n_epochs = int(intensity.shape[-1])
    window = np.asarray(window, np.int32)
    max_window = int(window.max())
    ms0, base_c = (baseline if baseline is not None else
                   greedy_sharded(batch, cum, n_epochs, machine_rule,
                                  devices=devices, processes=processes))
    ms0 = jnp.asarray(ms0, jnp.int32)
    base_c = jnp.asarray(base_c, jnp.float32)
    budget = (jnp.float32(stretch) * ms0.astype(jnp.float32)).astype(
        jnp.int32)

    gated_c, gated_ms, done = run_rows_sharded(
        _per_shard_hard_eval(max_window, n_epochs, machine_rule),
        (batch, intensity, jnp.asarray(cum), jnp.asarray(theta, jnp.float32),
         jnp.asarray(window), budget), devices=devices, processes=processes)
    if not bool(jnp.all(done)):
        raise AssertionError(
            "gated dispatch incomplete at evaluation — raise the horizon")
    savings = 1.0 - gated_c / jnp.maximum(base_c, 1e-6)
    ms_ratio = (gated_ms.astype(jnp.float32)
                / jnp.maximum(ms0.astype(jnp.float32), 1.0))
    return savings, gated_c, base_c, ms_ratio
