"""Instance-axis device mesh + the generic row-sharded runner.

The sweeps this repo runs — batched gated dispatch, the offline bi-level
bound, gate-policy training — are embarrassingly parallel over the
*instance* (row) axis of a stacked
:class:`~repro.core.instance.PackedInstance` batch.  This module owns the
two pieces every sharded entry point shares:

* :func:`instance_mesh` — a 1-D device mesh over the ``"inst"`` axis;
* :func:`run_rows_sharded` — run a per-shard program under ``shard_map``
  with every argument and result sharded on its leading row axis.  The
  batch is padded to a device multiple with *inert rows*
  (:func:`repro.scenarios.batching.pad_stacked` — the batch-axis padding
  contract) and results are sliced back to the real rows.

**Bit-exactness.**  The per-shard program is the same row-wise-independent
vmapped program the single-device path runs; no collective touches the
data, each row's floating-point work is identical whatever shard it lands
on, and padded rows are sliced off before anything consumes them.  Sharded
output therefore equals single-device output *exactly*, for any device
count — the parity contract ``tests/test_shard.py`` locks across all
scenario families x fleets.

**Multi-process.**  With ``processes=P`` the mesh spans ``jax.devices()``
across a ``jax.distributed`` fleet in the canonical process-major order of
:func:`repro.shard.distributed.mesh_devices`; ``devices`` then counts
devices *per process*.  The only collective the multi-process runner adds
is a trailing ``all_gather`` that moves every device's finished row shard
back into canonical row order (``out_specs=P()``) — rows move, nothing is
reduced, so the bit-exact contract is unchanged at any (process count,
device count) with the same total (``tests/test_distributed.py``).
"""
from __future__ import annotations

import functools
from typing import Callable, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.instance import PackedInstance
from repro.scenarios.batching import pad_stacked
from repro.shard import distributed
from repro.shard.compat import shard_map_compat

AXIS = "inst"   # the one mesh axis: stacked-instance (batch) rows


def device_count() -> int:
    """Local device count (8 under the CI job's forced-host-device flag)."""
    return len(jax.devices())


def instance_mesh(devices: int | None = None,
                  processes: int | None = None,
                  process_order: tuple[int, ...] | None = None) -> Mesh:
    """1-D mesh over the ``"inst"`` axis — local or process-spanning.

    ``processes=None`` (the default) is the single-process mesh over the
    first ``devices`` local devices (default: all), unchanged from PR 5.
    ``processes=P`` builds the process-spanning mesh: ``devices`` then
    counts devices *per process* and the mesh runs over
    :func:`repro.shard.distributed.mesh_devices` — process-major, so row
    blocks land on processes in canonical id order regardless of spawn
    order.  Raises with the ``XLA_FLAGS`` recipe when more devices are
    requested than the platform exposes — on CPU, fake devices must be
    forced before the first jax call:
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
    """
    if processes is not None:
        procs = int(processes)
        if procs != jax.process_count():
            raise ValueError(
                f"instance_mesh: processes={procs} but this runtime has "
                f"{jax.process_count()} jax process(es) — launch one worker "
                "per process under jax.distributed (see tests/harness.py / "
                "python -m tests.harness) with "
                "repro.shard.distributed.initialize_from_env()")
        devs = distributed.mesh_devices(devices_per_process=devices,
                                        process_order=process_order)
        return Mesh(np.asarray(devs), (AXIS,))
    avail = jax.devices()
    n = len(avail) if devices is None else int(devices)
    if n < 1:
        raise ValueError(f"instance_mesh: need >= 1 device, got {n}")
    if n > len(avail):
        raise ValueError(
            f"instance_mesh: {n} devices requested but only {len(avail)} "
            "available — on CPU, force fake devices before jax initializes: "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n}")
    return Mesh(np.asarray(avail[:n]), (AXIS,))


def round_up(rows: int, multiple: int) -> int:
    """Smallest multiple of ``multiple`` >= ``rows``."""
    return -(-rows // multiple) * multiple


def _leading_rows(args: Sequence) -> int:
    if not args:
        raise ValueError("run_rows_sharded: no arguments")
    a = args[0]
    if isinstance(a, PackedInstance):
        return int(a.dur.shape[0])
    return int(jnp.asarray(a).shape[0])


def _pad_rows(a, rows: int):
    """Pad one argument's leading axis to ``rows``: inert rows for a
    PackedInstance, zero rows for plain arrays (padded-row *values* are
    never consumed — results are sliced to the real rows)."""
    if isinstance(a, PackedInstance):
        return pad_stacked(a, rows)
    a = jnp.asarray(a)
    if a.shape[0] == rows:
        return a
    pad = jnp.zeros((rows - a.shape[0],) + a.shape[1:], a.dtype)
    return jnp.concatenate([a, pad])


@functools.lru_cache(maxsize=512)
def _sharded_callable(fn: Callable, n_dev: int, n_args: int) -> Callable:
    """Memoized jitted shard_map of ``fn`` — callers that reuse a per-shard
    function hit jit's trace cache instead of retracing every call."""
    mesh = instance_mesh(n_dev)
    return jax.jit(shard_map_compat(fn, mesh=mesh,
                                    in_specs=(P(AXIS),) * n_args,
                                    out_specs=P(AXIS)))


@functools.lru_cache(maxsize=512)
def _sharded_callable_mp(fn: Callable, processes: int, devices: int | None,
                         process_order: tuple[int, ...] | None,
                         n_args: int):
    """Memoized (mesh, jitted shard_map) for the process-spanning path.

    The per-shard body is ``fn`` unchanged, followed by a tiled
    ``all_gather`` over the mesh axis so every process holds every row in
    canonical order (``out_specs=P()`` — fully replicated).  The gather
    only *moves* rows; per-row floating point is untouched."""
    mesh = instance_mesh(devices=devices, processes=processes,
                         process_order=process_order)

    def gathered(*a):
        out = fn(*a)
        return jax.tree.map(
            lambda x: jax.lax.all_gather(x, AXIS, axis=0, tiled=True), out)

    return mesh, jax.jit(shard_map_compat(gathered, mesh=mesh,
                                          in_specs=(P(AXIS),) * n_args,
                                          out_specs=P()))


def _make_global(a, mesh: Mesh, rows: bool = True):
    """Lift one (host-replicated, already padded) argument into a global
    array across the process-spanning mesh — sharded on its leading row
    axis (``rows=True``) or fully replicated (``rows=False``).  Every
    process holds the same full host value, so each just hands XLA the
    blocks its local devices own."""
    def leaf(x):
        x = np.asarray(x)
        spec = P(AXIS) if (rows and x.ndim) else P()
        sh = jax.sharding.NamedSharding(mesh, spec)
        return jax.make_array_from_callback(x.shape, sh,
                                            lambda idx, x=x: x[idx])
    return jax.tree.map(leaf, a)


def run_rows_sharded(fn: Callable, args: Sequence,
                     devices: int | None = None,
                     processes: int | None = None,
                     process_order: tuple[int, ...] | None = None):
    """Run ``fn(*args)`` sharded over the leading row axis of every arg.

    ``fn`` must be a row-wise-independent batched program (a ``vmap`` over
    the leading axis); every argument — PackedInstance or array — and every
    output leaf must carry the row axis first.  Rows are padded to a device
    multiple (inert rows / zero rows), each device runs ``fn`` on its
    contiguous row shard, and outputs come back sliced to the real rows.

    With ``processes=P`` the shards span the ``jax.distributed`` fleet
    (``devices`` per process); inputs are lifted to global arrays from the
    host-replicated batch and outputs are all-gathered back to canonical
    row order on every process, returned as host arrays.
    """
    B = _leading_rows(args)
    if processes is None:
        n_dev = int(instance_mesh(devices).size)
        padded = tuple(_pad_rows(a, round_up(B, n_dev)) for a in args)
        out = _sharded_callable(fn, n_dev, len(padded))(*padded)
        return jax.tree.map(lambda x: x[:B], out)
    mesh, call = _sharded_callable_mp(fn, int(processes), devices,
                                      process_order, len(args))
    padded = tuple(_pad_rows(a, round_up(B, int(mesh.size))) for a in args)
    out = call(*tuple(_make_global(a, mesh) for a in padded))
    return jax.tree.map(lambda x: np.asarray(x)[:B], out)
