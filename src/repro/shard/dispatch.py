"""Sharded online dispatch: the policy sweep split over the instance axis.

:func:`dispatch_sharded` is the multi-device twin of
:func:`repro.core.solvers.online_jax.sweep_policies` (and, with
single-element policy axes, of a batched
:func:`~repro.core.solvers.online_jax.online_carbon_gated_jax`): the same
one-XLA-program gate-policy sweep, with the stacked instance batch sharded
over a 1-D device mesh.  Policies are replicated — the policy grid is the
cheap axis (window sorts are shared across thetas/stretches inside each
row) while instances carry the epoch-scan simulator, so the instance axis
is the one worth splitting.

Bit-exact with ``sweep_policies`` by construction: each device runs the
identical per-row program on its row shard, with no collectives (see
:mod:`repro.shard.batch`).
"""
from __future__ import annotations

import functools

import numpy as np

import jax.numpy as jnp

from repro.core.instance import PackedInstance
from repro.core.solvers.online_jax import SweepResult, _sweep
from repro.shard.batch import run_rows_sharded


@functools.lru_cache(maxsize=128)
def _per_shard_sweep(thetas: tuple, windows: tuple, stretches: tuple,
                     n_epochs: int, max_window: int, machine_rule: str):
    """Memoized per-shard sweep closure (stable identity -> jit cache hits
    in :func:`repro.shard.batch.run_rows_sharded` across repeat calls)."""
    th = jnp.asarray(thetas, jnp.float32)
    wi = jnp.asarray(np.asarray(windows, np.int32))
    sx = jnp.asarray(stretches, jnp.float32)

    def per_shard(b, inten):
        return _sweep(b, inten, th, wi, sx, n_epochs=n_epochs,
                      max_window=max_window, machine_rule=machine_rule)

    return per_shard


def dispatch_sharded(batch: PackedInstance, intensity, thetas, windows,
                     stretches, machine_rule: str = "earliest_finish",
                     devices: int | None = None,
                     processes: int | None = None) -> SweepResult:
    """``sweep_policies`` with the instance axis sharded over ``devices``.

    Same signature and same (bit-exact) :class:`~repro.core.solvers.
    online_jax.SweepResult` as the single-device sweep; ``devices=None``
    uses every local device.  ``processes=P`` spans the mesh across a
    ``jax.distributed`` fleet (``devices`` then counts per process) — see
    :func:`repro.shard.batch.run_rows_sharded`.  A single-policy call —
    one theta, one window, one stretch — is the sharded batched equivalent
    of ``online_carbon_gated_jax`` (``.gated`` squeezed on the policy axis,
    ``.greedy`` the baseline, ``.budget`` the stretch cap).
    """
    intensity = jnp.asarray(intensity)
    windows_np = np.asarray(windows, np.int32)
    per_shard = _per_shard_sweep(
        tuple(float(t) for t in np.asarray(thetas, np.float32)),
        tuple(int(w) for w in windows_np),
        tuple(float(s) for s in np.asarray(stretches, np.float32)),
        int(intensity.shape[-1]), int(windows_np.max()), machine_rule)
    return run_rows_sharded(per_shard, (batch, intensity), devices=devices,
                            processes=processes)
