"""The single ``shard_map`` entry point across the JAX API move.

Every ``shard_map`` call in the repo — the MoE expert-parallel path in
:mod:`repro.models.moe` and the instance-axis sharding layer in
:mod:`repro.shard` — routes through :func:`shard_map_compat`, so the
``jax.shard_map`` / ``jax.experimental.shard_map`` API bridge lives in
exactly one place (hoisted here from ``models/moe.py``, where it was born
as the fix for the seed-era ``test_moe_train_step_on_8_devices`` failure).
"""
from __future__ import annotations

import jax


def shard_map_compat(body, *, mesh, in_specs, out_specs):
    """``shard_map`` across the JAX API move, replication checks off.

    Newer JAX exposes ``jax.shard_map`` (replication checking via
    ``check_vma``); older releases only have
    ``jax.experimental.shard_map.shard_map`` with ``check_rep``.  The
    callers' output collectives (MoE's psum, the instance layer's
    all_gather) make outputs fully replicated where the specs say so, but
    the checker can't prove it through scatters, so it is disabled under
    whichever spelling the running JAX accepts.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(body, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)
