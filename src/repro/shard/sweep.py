"""Sharded structure sweep: both of its XLA programs split over instances.

:func:`~repro.scenarios.sweep.sweep_structure` runs the whole family x
size x server-count x fleet grid as two XLA programs — the gated online
dispatch sweep and the offline SA bi-level bound.  This module shards both
over the instance axis:

* :func:`bilevel_sharded` — :func:`repro.core.solvers.bilevel.
  solve_bilevel_batch` with rows (instances, traces, PRNG keys) sharded;
* :func:`sweep_sharded` — the full structure sweep on ``devices`` devices,
  a thin veneer over ``sweep_structure(devices=...)`` (which routes its
  dispatch / bound / learn programs through this package), so benchmarks
  and tests have one sharded front door.

Bit-exact with the single-device sweep: per-row SA chains are driven by
per-row keys and rows never interact.  Unlike the dispatch/train paths,
the bound does **not** go through ``shard_map``: XLA's manual-partitioning
pipeline fuses transcendentals (the ``erf_inv`` behind
``jax.random.normal``) a vector-ulp differently than the plain jit path,
and a one-ulp fitness difference can flip a stochastic-search
accept/reject and diverge the whole SA trajectory.  Instead each device
runs the *same compiled batched program* on its committed row shard —
per-device program dispatch, which is asynchronous in JAX, so shards still
execute concurrently — and the program is batch-size independent
(``tests/test_shard.py`` locks that parity too).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.instance import PackedInstance
from repro.core.solvers.bilevel import BilevelResult, solve_bilevel_batch
from repro.shard.batch import _pad_rows, instance_mesh, round_up


def bilevel_sharded(insts: PackedInstance, cums, keys,
                    devices: int | None = None,
                    processes: int | None = None, **kw) -> BilevelResult:
    """``solve_bilevel_batch`` with the instance axis sharded.

    ``keys`` is the same ``[B]`` typed-key array the batched solver takes;
    rows are padded to a device multiple (inert instances, zero keys),
    each device solves its committed shard of rows with the identical
    compiled program (see module docstring for why this path dispatches
    per device instead of shard_mapping), and results come back
    concatenated in row order, sliced to the real rows.

    With ``processes=P`` (``devices`` per process) each process dispatches
    only the contiguous row block its canonical process id owns — the same
    per-device pattern, one level up — then
    ``multihost_utils.process_allgather`` concatenates the blocks in
    process-id order, which *is* canonical row order.  Each device still
    runs the identical compiled program on identically-shaped shards, so
    the SA trajectories — and therefore the bound — are bit-exact at any
    (process count, device count) with the same total.
    """
    mesh = instance_mesh(devices, processes=processes)
    B = int(jnp.asarray(cums).shape[0])
    rows = round_up(B, int(mesh.size))
    pad = rows - B
    if pad:
        kd = jax.random.key_data(keys)
        keys = jax.random.wrap_key_data(jnp.concatenate(
            [kd, jnp.zeros((pad,) + kd.shape[1:], kd.dtype)]))
    insts_p = _pad_rows(insts, rows)
    cums_p = _pad_rows(cums, rows)
    if processes is None:
        devs = list(mesh.devices.ravel())
        base = 0
    else:
        # Canonical id order, independent of process_order / spawn order:
        # process p owns rows [p*rows/P, (p+1)*rows/P) on its mesh-local
        # devices.
        pid = jax.process_index()
        devs = [d for d in mesh.devices.ravel() if d.process_index == pid]
        base = pid * (rows // jax.process_count())
    per = rows // int(mesh.size)
    shards = []
    for i, dev in enumerate(devs):
        sl = slice(base + i * per, base + (i + 1) * per)
        args = jax.tree.map(lambda x: jax.device_put(x[sl], dev),
                            (insts_p, cums_p, keys))
        shards.append(solve_bilevel_batch(*args, **kw))   # async, on dev i
    out = jax.tree.map(lambda *xs: np.concatenate(
        [np.asarray(x) for x in xs]), *shards)
    if processes is not None:
        from jax.experimental import multihost_utils
        out = multihost_utils.process_allgather(out, tiled=True)
    out = jax.tree.map(lambda x: x[:B], out)
    return jax.tree.map(jnp.asarray, out)


def sweep_sharded(spec, offline: bool = True, learn=None,
                  devices: int | None = None,
                  processes: int | None = None):
    """The full structure sweep, sharded: ``(rows, meta)`` as
    :func:`~repro.scenarios.sweep.sweep_structure` returns them, bit-exact
    with the single-device sweep.  ``devices=None`` uses every local
    device (every device per process when ``processes=P``)."""
    from repro.scenarios.sweep import sweep_structure   # lazy: avoids cycle
    from repro.shard.batch import device_count
    if processes is not None:
        return sweep_structure(spec, offline=offline, learn=learn,
                               devices=devices, processes=processes)
    return sweep_structure(spec, offline=offline, learn=learn,
                           devices=devices or device_count())
