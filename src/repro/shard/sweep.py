"""Sharded structure sweep: both of its XLA programs split over instances.

:func:`~repro.scenarios.sweep.sweep_structure` runs the whole family x
size x server-count x fleet grid as two XLA programs — the gated online
dispatch sweep and the offline SA bi-level bound.  This module shards both
over the instance axis:

* :func:`bilevel_sharded` — :func:`repro.core.solvers.bilevel.
  solve_bilevel_batch` with rows (instances, traces, PRNG keys) sharded;
* :func:`sweep_sharded` — the full structure sweep on ``devices`` devices,
  a thin veneer over ``sweep_structure(devices=...)`` (which routes its
  dispatch / bound / learn programs through this package), so benchmarks
  and tests have one sharded front door.

Bit-exact with the single-device sweep: per-row SA chains are driven by
per-row keys and rows never interact.  Unlike the dispatch/train paths,
the bound does **not** go through ``shard_map``: XLA's manual-partitioning
pipeline fuses transcendentals (the ``erf_inv`` behind
``jax.random.normal``) a vector-ulp differently than the plain jit path,
and a one-ulp fitness difference can flip a stochastic-search
accept/reject and diverge the whole SA trajectory.  Instead each device
runs the *same compiled batched program* on its committed row shard —
per-device program dispatch, which is asynchronous in JAX, so shards still
execute concurrently — and the program is batch-size independent
(``tests/test_shard.py`` locks that parity too).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.instance import PackedInstance
from repro.core.solvers.bilevel import BilevelResult, solve_bilevel_batch
from repro.shard.batch import _pad_rows, instance_mesh, round_up


def bilevel_sharded(insts: PackedInstance, cums, keys,
                    devices: int | None = None, **kw) -> BilevelResult:
    """``solve_bilevel_batch`` with the instance axis sharded.

    ``keys`` is the same ``[B]`` typed-key array the batched solver takes;
    rows are padded to a device multiple (inert instances, zero keys),
    each device solves its committed shard of rows with the identical
    compiled program (see module docstring for why this path dispatches
    per device instead of shard_mapping), and results come back
    concatenated in row order, sliced to the real rows.
    """
    mesh = instance_mesh(devices)
    devs = list(mesh.devices.ravel())
    n_dev = len(devs)
    B = int(jnp.asarray(cums).shape[0])
    rows = round_up(B, n_dev)
    pad = rows - B
    if pad:
        kd = jax.random.key_data(keys)
        keys = jax.random.wrap_key_data(jnp.concatenate(
            [kd, jnp.zeros((pad,) + kd.shape[1:], kd.dtype)]))
    insts_p = _pad_rows(insts, rows)
    cums_p = _pad_rows(cums, rows)
    per = rows // n_dev
    shards = []
    for i, dev in enumerate(devs):
        sl = slice(i * per, (i + 1) * per)
        args = jax.tree.map(lambda x: jax.device_put(x[sl], dev),
                            (insts_p, cums_p, keys))
        shards.append(solve_bilevel_batch(*args, **kw))   # async, on dev i
    out = jax.tree.map(lambda *xs: np.concatenate(
        [np.asarray(x) for x in xs])[:B], *shards)
    return jax.tree.map(jnp.asarray, out)


def sweep_sharded(spec, offline: bool = True, learn=None,
                  devices: int | None = None):
    """The full structure sweep, sharded: ``(rows, meta)`` as
    :func:`~repro.scenarios.sweep.sweep_structure` returns them, bit-exact
    with the single-device sweep.  ``devices=None`` uses every local
    device."""
    from repro.scenarios.sweep import sweep_structure   # lazy: avoids cycle
    from repro.shard.batch import device_count
    return sweep_structure(spec, offline=offline, learn=learn,
                           devices=devices or device_count())
