"""Multi-process runtime: ``jax.distributed`` wiring for the shard layer.

PR 5's sharding is single-process over N local devices; this module is the
step it was designed for — the same instance-axis programs spanning a
**process-spanning** device mesh, so the structure sweep and the learner
run across real worker processes (and, on a cluster, real hosts).  It owns
exactly three things:

* :func:`initialize` — a thin, idempotent wrapper over
  ``jax.distributed.initialize`` taking the coordinator address / process
  id / process count from arguments or from the ``REPRO_COORDINATOR`` /
  ``REPRO_NUM_PROCESSES`` / ``REPRO_PROCESS_ID`` environment (the contract
  ``tests/harness.py`` spawns workers with).  On the CPU backend it
  selects the ``gloo`` cross-process collectives implementation first —
  XLA's default CPU collectives cannot run multi-process computations at
  all, and the flag must be set before the backend initializes.
* :func:`initialize_from_env` — the no-op-when-unset variant benchmarks
  call unconditionally: a plain single-process run sees no env and pays
  nothing.
* :func:`mesh_devices` — the canonical device order for a process-spanning
  mesh: ``devices_per_process`` devices from every process, **process-major**
  (process 0's devices first), so the ``"inst"`` mesh axis maps rows to
  contiguous blocks in process-id order — the canonical row order every
  cross-process ``all_gather`` in :mod:`repro.shard` reassembles.

The bit-exactness story does not change here: collectives only *move*
rows (``all_gather`` into canonical order), never reduce them — reductions
stay the explicitly-sequenced ``seq_sum`` of :mod:`repro.learn.train` —
so sharded == single-device bit-for-bit at any (process count, device
count), goldens unchanged (``tests/test_distributed.py``).
"""
from __future__ import annotations

import os

import jax

ENV_COORDINATOR = "REPRO_COORDINATOR"
ENV_NUM_PROCESSES = "REPRO_NUM_PROCESSES"
ENV_PROCESS_ID = "REPRO_PROCESS_ID"

_INITIALIZED = False


def is_initialized() -> bool:
    """True once :func:`initialize` has run in this process."""
    return _INITIALIZED


def _enable_cpu_collectives() -> None:
    """Select gloo for cross-process CPU collectives (the XLA default CPU
    collectives raise ``Multiprocess computations aren't implemented on
    the CPU backend``).  Must run before the backend is created; harmless
    on jax versions or backends where the option is absent."""
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:   # option renamed/absent — non-CPU backends don't care
        pass


def initialize(coordinator: str | None = None,
               num_processes: int | None = None,
               process_id: int | None = None,
               initialization_timeout: int | None = None) -> None:
    """``jax.distributed.initialize`` from args or the ``REPRO_*`` env.

    Arguments win over the environment; either source must provide all
    three of (coordinator address, process count, process id).  Idempotent
    — a second call in the same process is a no-op, so library code and
    entry points can both call it.  ``initialization_timeout`` (seconds)
    bounds the coordination barrier — a dead worker then fails loudly
    instead of hanging the fleet for the default 300 s.
    """
    global _INITIALIZED
    if _INITIALIZED:
        return
    coordinator = coordinator or os.environ.get(ENV_COORDINATOR)
    if num_processes is None and os.environ.get(ENV_NUM_PROCESSES):
        num_processes = int(os.environ[ENV_NUM_PROCESSES])
    if process_id is None and os.environ.get(ENV_PROCESS_ID):
        process_id = int(os.environ[ENV_PROCESS_ID])
    if coordinator is None or num_processes is None or process_id is None:
        raise ValueError(
            "distributed.initialize needs coordinator address, process "
            "count and process id — pass them or set "
            f"{ENV_COORDINATOR}/{ENV_NUM_PROCESSES}/{ENV_PROCESS_ID} "
            f"(got coordinator={coordinator!r}, "
            f"num_processes={num_processes!r}, process_id={process_id!r})")
    _enable_cpu_collectives()
    kw = {}
    if initialization_timeout is not None:
        kw["initialization_timeout"] = int(initialization_timeout)
    jax.distributed.initialize(coordinator_address=coordinator,
                               num_processes=int(num_processes),
                               process_id=int(process_id), **kw)
    _INITIALIZED = True


def initialize_from_env(initialization_timeout: int | None = None) -> bool:
    """Initialize iff the ``REPRO_*`` env is set; returns whether it is.

    The benchmark entry points call this unconditionally: a plain
    single-process invocation (no env) is untouched, while the same
    command line spawned by ``tests/harness.py`` (or
    ``python -m tests.harness``) joins the process fleet.
    """
    if not os.environ.get(ENV_COORDINATOR):
        return False
    initialize(initialization_timeout=initialization_timeout)
    return True


def process_count() -> int:
    return jax.process_count()


def process_index() -> int:
    return jax.process_index()


def mesh_devices(devices_per_process: int | None = None,
                 process_order: tuple[int, ...] | None = None) -> list:
    """Process-major device list for a process-spanning ``"inst"`` mesh.

    Takes the first ``devices_per_process`` local devices of every process
    (default: every process's full complement, which must agree across
    processes) in ``process_order`` (default ``0..P-1``).  Process-major
    order is the canonical layout: mesh position — and therefore the row
    block a device owns — is a pure function of (process id, local device
    ordinal), independent of which OS process got spawned first
    (the process-permutation invariance ``tests/test_distributed.py``
    locks is exactly that ``process_order`` never changes a number).
    """
    procs = jax.process_count()
    order = tuple(range(procs)) if process_order is None else \
        tuple(int(p) for p in process_order)
    if sorted(order) != list(range(procs)):
        raise ValueError(f"process_order {order} is not a permutation of "
                         f"0..{procs - 1}")
    by_proc: dict[int, list] = {p: [] for p in range(procs)}
    for d in jax.devices():
        by_proc[d.process_index].append(d)
    per = (min(len(v) for v in by_proc.values())
           if devices_per_process is None else int(devices_per_process))
    if per < 1:
        raise ValueError(f"mesh_devices: need >= 1 device per process, "
                         f"got {per}")
    for p, devs in by_proc.items():
        if len(devs) < per:
            raise ValueError(
                f"mesh_devices: process {p} exposes {len(devs)} device(s), "
                f"{per} per process requested — on CPU, force fake devices "
                "in every worker: XLA_FLAGS="
                f"--xla_force_host_platform_device_count={per}")
    return [d for p in order for d in by_proc[p][:per]]
