"""Multi-device sharding of the instance axis.

The paper-scale sweeps — gated online dispatch x policy grids, the offline
SA bi-level bound, gate-policy training — are embarrassingly parallel over
*instances*, and every subsystem's ROADMAP next-step named "multi-host
sharding of the instance axis".  This package is that layer for the
single-process case: the existing vmapped XLA programs run under
``shard_map`` over a 1-D device mesh on the instance (or scenario-cell)
axis, with the batch padded to a device multiple by the inert batch-axis
padding contract (:mod:`repro.scenarios.batching`).

    compat    — the single ``jax.shard_map`` / ``jax.experimental.
                shard_map`` API bridge (hoisted from ``models/moe.py``)
    distributed — ``jax.distributed`` wiring: coordinator/process-id
                init (args or ``REPRO_*`` env) + the canonical
                process-major device order for process-spanning meshes
    batch     — the ``"inst"`` device mesh + the generic row-sharded runner
    dispatch  — ``dispatch_sharded``: the gate-policy sweep
                (``sweep_policies`` / batched ``online_carbon_gated_jax``)
    sweep     — ``bilevel_sharded`` (offline SA bound) + ``sweep_sharded``
                (the whole structure sweep, both programs)
    train     — ``train_sharded`` / ``eval_theta_sharded``: the learner's
                scanned Adam loop with canonically-reduced per-row grads

The headline contract, property-tested in ``tests/test_shard.py`` across
all scenario families x fleets and extended across process fleets by
``tests/test_distributed.py``: **sharded output is bit-exact with the
single-device output, for any (process count, device count)** — 1, 2, 4
and 8 devices, single- or multi-process, all produce identical results,
and the tiny golden grids reproduce their golden JSONs unchanged when run
sharded.

Exports resolve lazily (PEP 562) so that importing the leaf
``repro.shard.compat`` bridge (as ``models/moe.py`` does) never drags the
scheduling stack into model imports.
"""
from __future__ import annotations

_EXPORTS = {
    "shard_map_compat": "repro.shard.compat",
    "initialize": "repro.shard.distributed",
    "initialize_from_env": "repro.shard.distributed",
    "mesh_devices": "repro.shard.distributed",
    "AXIS": "repro.shard.batch",
    "device_count": "repro.shard.batch",
    "instance_mesh": "repro.shard.batch",
    "round_up": "repro.shard.batch",
    "run_rows_sharded": "repro.shard.batch",
    "dispatch_sharded": "repro.shard.dispatch",
    "bilevel_sharded": "repro.shard.sweep",
    "sweep_sharded": "repro.shard.sweep",
    "greedy_sharded": "repro.shard.train",
    "train_sharded": "repro.shard.train",
    "eval_theta_sharded": "repro.shard.train",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    if name in _EXPORTS:
        import importlib
        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module 'repro.shard' has no attribute {name!r}")


def __dir__():
    return __all__
