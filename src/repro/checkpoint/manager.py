"""Atomic, keep-k, async checkpointing for arbitrary pytrees.

Fault-tolerance contract (the piece the 1000-node posture relies on):

* **Atomicity** — a checkpoint is written to ``step_N.tmp`` and renamed to
  ``step_N`` only when complete, so a preemption mid-save can never corrupt
  the restore point.  ``latest()`` only ever sees complete directories.
* **Async** — ``save()`` snapshots the tree to host memory synchronously
  (cheap) and writes in a background thread, overlapping I/O with the next
  training steps; ``wait()`` joins before exit or the next save.
* **Keep-k** — older checkpoints are garbage-collected after a successful
  save (never before), so a crash during save leaves the previous good
  checkpoint intact.
* **Multi-host** — each process saves only addressable shards under
  ``proc_<i>``; restore re-assembles per-process.  In this container there
  is one process; the layout is the multi-host one regardless.

Format: one ``.npz`` per pytree ('/'-joined key paths) + a small JSON
manifest with the step and tree structure.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import numpy as np

import jax


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 process_index: int = 0):
        self.dir = directory
        self.keep = keep
        self.process_index = process_index
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- write ---------------------------------------------------------------
    def save(self, step: int, tree, blocking: bool = False) -> None:
        self.wait()
        host = _flatten(tree)          # device->host copy happens here
        if blocking:
            self._write(step, host)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._thread.start()

    def _write(self, step: int, flat: dict[str, np.ndarray]) -> None:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, f"proc_{self.process_index}.npz"), **flat)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "keys": sorted(flat)}, f)
        if os.path.exists(final):
            shutil.rmtree(tmp, ignore_errors=True)
        else:
            os.replace(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- read ----------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for n in os.listdir(self.dir):
            if n.startswith("step_") and not n.endswith(".tmp"):
                out.append(int(n.split("_")[1]))
        return sorted(out)

    def latest(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like, step: int | None = None):
        """Restore into the structure of ``tree_like`` (values replaced)."""
        self.wait()
        step = self.latest() if step is None else step
        if step is None:
            return None
        path = os.path.join(self.dir, f"step_{step:08d}",
                            f"proc_{self.process_index}.npz")
        data = np.load(path)
        flat = _flatten(tree_like)
        missing = [k for k in flat if k not in data.files]
        if missing:
            raise KeyError(f"checkpoint {step} missing keys: {missing[:5]}")
        treedef = jax.tree_util.tree_structure(tree_like)
        # Rebuild in tree order, mapping leaves via their key paths.
        path_leaves = jax.tree_util.tree_flatten_with_path(tree_like)[0]
        new = []
        for (p, leaf) in path_leaves:
            key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                           for q in p)
            arr = data[key]
            new.append(jax.numpy.asarray(arr, dtype=leaf.dtype)
                       if hasattr(leaf, "dtype") else arr)
        return jax.tree_util.tree_unflatten(treedef, new)
