"""Compiled-HLO analysis: collective wire bytes + scan-aware cost model.

XLA's ``cost_analysis()`` counts a ``while`` (lax.scan) body ONCE, so a
scanned-layer program under-reports FLOPs by ~L (verified in a spike;
see EXPERIMENTS.md §Roofline methodology).  The dry-run therefore compiles
two extra *probe* programs per cell — identical sharding/shapes but 1 and 2
UNROLLED layers — and extrapolates:

    total(L) = probe1 + (L - 1) * (probe2 - probe1)

which attributes embed/unembed/optimizer-scalars exactly once and each
layer exactly L times.  The same extrapolation applies to the collective
wire bytes parsed from the probes' HLO text.

Wire-byte model per op (G = replica-group size, B = result bytes,
ring-algorithm per-chip traffic):
    all-reduce          2 * B * (G-1)/G
    all-gather              B * (G-1)/G      (B = gathered output)
    reduce-scatter          B * (G-1)        (B = scattered output)
    all-to-all              B * (G-1)/G
    collective-permute      B

**bf16-dot correction** (on by default): the CPU backend upcasts bf16
dot_generals to f32 and the SPMD partitioner places partial-sum
all-reduces before the downcast, so matmul ARs appear at 2x their TPU
wire bytes (native MXU bf16 keeps them bf16).  f32 collectives whose HLO
metadata points at a dot_general (or at the bf16 embedding gather) are
charged at bf16 width.  Both corrected and raw totals are recorded.
"""
from __future__ import annotations

import re

_BF16_ARTIFACT_RE = re.compile(
    r'op_name="[^"]*(dot_general|gather)[^"]*"')

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2,
                "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\)|[a-z0-9]+\[[^\]]*\])[^=]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_ITOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")


def _result_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str, bf16_dot_correction: bool = True
                      ) -> list[dict]:
    """Per-collective (op, result_bytes, group_size, wire_bytes)."""
    out = []
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m is None:
            continue
        type_str, op = m.group(1), m.group(2)
        b = _result_bytes(type_str)
        corrected = False
        if bf16_dot_correction and "f32[" in type_str and \
                _BF16_ARTIFACT_RE.search(line):
            b *= 0.5
            corrected = True
        g = 1
        mi = _GROUPS_ITOTA_RE.search(line)
        if mi is not None:
            g = int(mi.group(2))
        else:
            ml = _GROUPS_LIST_RE.search(line)
            if ml is not None:
                g = len([x for x in ml.group(1).split(",") if x.strip()])
        if op == "collective-permute":
            wire = b                      # pairs, not replica groups
        elif g <= 1:
            wire = 0.0
        elif op == "all-reduce":
            wire = 2 * b * (g - 1) / g
        elif op in ("all-gather", "all-to-all"):
            wire = b * (g - 1) / g
        else:  # reduce-scatter
            wire = b * (g - 1)
        out.append({"op": op, "bytes": b, "group": g, "wire": wire,
                    "bf16_corrected": corrected})
    return out


def wire_bytes(hlo_text: str, bf16_dot_correction: bool = True) -> float:
    """Total per-chip collective wire bytes of one program execution
    (scan bodies counted once — use probe extrapolation for totals)."""
    return sum(c["wire"]
               for c in parse_collectives(hlo_text, bf16_dot_correction))


def collective_mix(hlo_text: str) -> dict[str, float]:
    mix: dict[str, float] = {}
    for c in parse_collectives(hlo_text):
        mix[c["op"]] = mix.get(c["op"], 0.0) + c["wire"]
    return mix


def extrapolate(probe1: float, probe2: float, n_layers: int) -> float:
    """total(L) = probe1 + (L-1) * (probe2 - probe1); clamped at >= 0."""
    per_layer = max(probe2 - probe1, 0.0)
    return probe1 + (n_layers - 1) * per_layer


def cost_dict(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    ca = ca or {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0))}


def memory_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
        return {"argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes)}
    except Exception as e:  # pragma: no cover - backend specific
        return {"error": str(e)}
