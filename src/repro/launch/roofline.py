"""Roofline analysis over the dry-run artifacts.

Per (arch x shape x mesh) cell (constants: v5e):

    compute_s    = FLOPs_per_chip / 197e12
    memory_s     = HLO_bytes_per_chip / 819e9
    collective_s = wire_bytes_per_chip / 50e9       (1 ICI link budget)

FLOPs/bytes come from the probe-extrapolated cost analysis (scan bodies
counted exactly L times — see hlo_analysis.py); wire bytes from the HLO
collective parse with ring-algorithm per-chip traffic factors.

``MODEL_FLOPS`` is the useful-work floor: 6·N_active·tokens for training,
2·N_active·tokens for inference; the ratio against compiled FLOPs x chips
flags remat/dispatch waste.  The dominant term is the bottleneck §Perf
iterates on.

Usage:  python -m repro.launch.roofline [--write-md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro import configs
from repro.models.common import SHAPES

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "experiments", "dryrun")


# ---------------------------------------------------------------------------
# Analytic post-fusion HBM model.
#
# XLA's ``bytes accessed`` sums every HLO op's operand+result bytes with no
# fusion model (on the CPU backend), so elementwise chains that a TPU would
# fuse into one VMEM-resident pass are each charged a full HBM round trip —
# a 5-20x overestimate.  The analytic model below charges only the traffic
# that MUST cross HBM on a TPU: parameter reads (per microbatch pass),
# gradient/optimizer state traffic, scan-carry activations (written fwd,
# read bwd under full remat), and KV-cache reads.  Both numbers are
# reported; the bottleneck decision uses the analytic one.
# ---------------------------------------------------------------------------

class _MeshLike:
    def __init__(self, multi_pod: bool):
        self.axis_names = (("pod", "data", "model") if multi_pod
                           else ("data", "model"))
        self.shape = ({"pod": 2, "data": 16, "model": 16} if multi_pod
                      else {"data": 16, "model": 16})


def analytic_hbm_bytes(rec: dict) -> float:
    import dataclasses

    import jax.numpy as jnp

    from repro.launch.sharding import auto_rules
    from repro.models.api import model_defs
    from repro.models.common import input_specs
    from repro.models.params import sharded_size_bytes, tree_map_defs

    cfg = configs.get(rec["arch"])
    sc = SHAPES[rec["shape"]]
    pol = rec["policy"]
    multi = rec["mesh"] == "pod2x16x16"
    mesh = _MeshLike(multi)
    rules = auto_rules(cfg, mesh, zero_stage=int(pol["zero_stage"]))
    pdt = jnp.dtype(pol["param_dtype"])
    defs = tree_map_defs(
        lambda d: dataclasses.replace(
            d, dtype=pdt if jnp.issubdtype(d.dtype, jnp.floating)
            else d.dtype), model_defs(cfg))
    p_chip = sharded_size_bytes(defs, rules, mesh.shape)

    data = mesh.shape["data"] * mesh.shape.get("pod", 1)
    b_loc = max(sc.batch // data, 1)
    micro = int(pol["microbatches"])
    layers = cfg.n_layers + cfg.n_encoder_layers

    # Per-chip batch/cache bytes (input specs sharded over batch axes and,
    # for caches, kv-heads over model when divisible).
    kv_seq = pol.get("kv_seq_shard") in (True, "True")
    cache_chip = 0.0
    for k, s in input_specs(cfg, rec["shape"]).items():
        n = 1
        for d in s.shape:
            n *= d
        bytes_ = n * jnp.dtype(s.dtype).itemsize
        if s.shape and s.shape[0] == sc.batch:
            bytes_ /= data
        elif len(s.shape) > 1 and s.shape[1] == sc.batch:   # [L, B, ...]
            bytes_ /= data
            if len(s.shape) > 3 and s.shape[3] == cfg.n_kv_heads and \
                    cfg.n_kv_heads % 16 == 0:
                bytes_ /= 16
            elif kv_seq and k in ("k_cache", "v_cache") and \
                    s.shape[2] % 16 == 0:   # window sharded over "model"
                bytes_ /= 16
        cache_chip += bytes_

    if sc.kind == "train":
        mdt = jnp.dtype(pol["moment_dtype"]).itemsize
        o_base = p_chip
        if int(pol["zero_stage"]) == 1:      # moments sharded over data
            o_base = sharded_size_bytes(
                defs, auto_rules(cfg, mesh, zero_stage=3), mesh.shape)
        o_chip = 2 * o_base / jnp.dtype(pdt).itemsize * mdt
        carry = layers * (b_loc / micro) * sc.seq * cfg.d_model * 2.0
        return (3.0 * micro * p_chip          # fwd+bwd+remat weight reads
                + 2.0 * micro * p_chip        # grad accum write+read (fp32)
                + 2.0 * (p_chip + o_chip)     # optimizer read+write
                + 2.0 * micro * carry         # scan carries (fwd w, bwd r)
                + cache_chip)
    if sc.kind == "prefill":
        act = layers * b_loc * sc.seq * cfg.d_model * 2.0
        return p_chip + act + cache_chip      # weights + stream + kv write
    # decode: weights once + cache read/write
    return p_chip + 2.0 * cache_chip


def model_flops(arch: str, shape: str) -> float:
    cfg = configs.get(arch)
    sc = SHAPES[shape]
    n = cfg.active_param_count()
    if sc.kind == "train":
        return 6.0 * n * sc.batch * sc.seq
    tokens = sc.batch * (sc.seq if sc.kind == "prefill" else 1)
    return 2.0 * n * tokens


def achieved_vs_roofline(cost: dict, warm_s: float) -> dict:
    """Achieved vs roofline for one measured jitted program.

    ``cost`` is :func:`repro.launch.hlo_analysis.cost_dict` of the compiled
    program; ``warm_s`` its measured warm wall-clock.  Returns the
    achieved-FLOP/s / achieved-bytes/s columns the benchmark provenance
    stamps into every BENCH_*.json, plus the roofline bound at the v5e
    reference constants (``PEAK_FLOPS`` / ``HBM_BW``).  ``roofline_frac``
    is bound-time / measured-time — on a TPU the fraction of the roofline
    achieved; on the CPU backend it reads as headroom to the reference
    accelerator (the perf gate tracks *warm_s* regressions either way,
    machine-local).
    """
    flops = float(cost.get("flops", 0.0))
    bytes_ = float(cost.get("bytes", 0.0))
    warm_s = max(float(warm_s), 1e-12)
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_ / HBM_BW
    bound_s = max(compute_s, memory_s)
    return {
        "hlo_flops": flops,
        "hlo_bytes": bytes_,
        "achieved_flops_per_s": flops / warm_s,
        "achieved_bytes_per_s": bytes_ / warm_s,
        "roofline_compute_s": compute_s,
        "roofline_memory_s": memory_s,
        "roofline_bound_s": bound_s,
        "roofline_frac": bound_s / warm_s,
        "dominant": "compute" if compute_s >= memory_s else "memory",
    }


def analyze(rec: dict) -> dict | None:
    if rec.get("status") != "ok" or "flops" not in rec:
        return None
    chips = 512 if rec["mesh"] == "pod2x16x16" else 256
    compute_s = rec["flops"] / PEAK_FLOPS
    memory_hlo_s = rec["bytes"] / HBM_BW
    memory_s = analytic_hbm_bytes(rec) / HBM_BW
    coll_s = rec["wire_bytes"] / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": coll_s}
    dominant = max(terms, key=terms.get)
    bound_s = max(terms.values())
    mf = model_flops(rec["arch"], rec["shape"])
    useful = mf / max(rec["flops"] * chips, 1.0)
    # Roofline fraction: useful-model-work time at peak vs. bound time.
    ideal_s = mf / chips / PEAK_FLOPS
    frac = ideal_s / max(bound_s, 1e-30)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "tag": rec.get("tag", "baseline"),
        "compute_s": compute_s, "memory_s": memory_s,
        "memory_hlo_s": memory_hlo_s,
        "collective_s": coll_s, "dominant": dominant,
        "step_s_bound": bound_s,
        "model_flops": mf, "hlo_flops_chip": rec["flops"],
        "useful_ratio": useful, "roofline_frac": frac,
        "mem_per_chip_gb": rec.get("memory", {}).get("argument_bytes", 0)
        / 1e9 + rec.get("memory", {}).get("temp_bytes", 0) / 1e9,
        "arg_gb": rec.get("memory", {}).get("argument_bytes", 0) / 1e9,
        "temp_gb": rec.get("memory", {}).get("temp_bytes", 0) / 1e9,
        "coll_mix": rec.get("coll_mix", {}),
        "compile_s": rec.get("compile_s", 0),
    }


def load_all(tag: str | None = None) -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if tag is not None and rec.get("tag", "baseline") != tag:
            continue
        a = analyze(rec)
        if a is not None:
            out.append(a)
    return out


def hint(row: dict) -> str:
    d = row["dominant"]
    if d == "compute":
        if row["useful_ratio"] < 0.5:
            return ("compute-bound with low useful ratio: cut remat "
                    "recompute or dead attention FLOPs")
        return "compute-bound near the useful floor: good place to be"
    if d == "memory":
        return ("HBM-bound: raise arithmetic intensity (bigger batch/"
                "fusion) or shrink weight traffic (quantize, cache-resident"
                " tiles)")
    return ("collective-bound: reshard to cut gather/reduce volume or "
            "overlap collectives with compute")


def to_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute_s | memory_s | hlo_mem_s | "
           "collective_s | bound | MODEL_FLOPS | useful | roofline | "
           "mem/chip GB | next lever |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['memory_hlo_s']:.3e} "
            f"| {r['collective_s']:.3e} | {r['dominant']} "
            f"| {r['model_flops']:.2e} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_frac']:.2f} "
            f"| {r['mem_per_chip_gb']:.1f} | {hint(r)} |")
    return hdr + "\n".join(lines) + "\n"


def pick_hillclimb_cells(rows: list[dict]) -> dict[str, dict]:
    """worst roofline fraction / most collective-bound / paper-representative
    (the biggest train cell — carbon pricing of training jobs is the
    paper-bridge workload)."""
    pod = [r for r in rows if r["mesh"] == "pod16x16"
           and r["shape"] != "long_500k"]
    worst = min(pod, key=lambda r: r["roofline_frac"])
    coll = max(pod, key=lambda r: r["collective_s"]
               / max(r["step_s_bound"], 1e-30))
    train = [r for r in pod if r["shape"] == "train_4k"]
    rep = max(train, key=lambda r: r["model_flops"])
    return {"worst_roofline": worst, "most_collective": coll,
            "paper_representative": rep}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--write-md", action="store_true")
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args()
    rows = load_all(args.tag)
    print(to_markdown(rows))
    picks = pick_hillclimb_cells(rows)
    for name, r in picks.items():
        print(f"{name}: {r['arch']} x {r['shape']} (dominant="
              f"{r['dominant']}, roofline={r['roofline_frac']:.2f}) — "
              f"{hint(r)}")
    if args.write_md:
        out = os.path.join(DRYRUN_DIR, "..", "roofline.md")
        with open(out, "w") as f:
            f.write(to_markdown(rows))
        print("wrote", os.path.abspath(out))


if __name__ == "__main__":
    main()
