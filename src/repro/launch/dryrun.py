import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: ``.lower().compile()`` every (arch x shape x mesh).

For each cell this script:
  1. builds the production program (train_step for ``train_*`` shapes,
     prefill/decode serve steps otherwise) with scanned layers, remat,
     per-arch auto sharding rules and ZeRO stage;
  2. lowers + compiles it on the production mesh (16x16 single-pod or
     2x16x16 multi-pod of host-platform placeholder devices);
  3. records ``memory_analysis()`` (per-chip bytes — proves the memory
     plan) and ``cost_analysis()`` (per-chip FLOPs/bytes);
  4. compiles two *probe* programs (1 and 2 unrolled layers, same
     sharding) so scan-body costs can be extrapolated exactly
     (see launch/hlo_analysis.py), and parses collective wire bytes;
  5. writes one JSON per cell under ``experiments/dryrun/``.

Usage:
  python -m repro.launch.dryrun --arch deepseek-67b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-done]
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import numpy as np  # noqa: E402

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch import hlo_analysis as ha
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import batch_shardings, make_parallel
from repro.models.api import build_model
from repro.models.common import (ArchConfig, SHAPES, input_specs,
                                 supports_shape)
from repro.models.params import (param_pspecs, sharded_size_bytes,
                                 tree_map_defs)
from repro.optim import AdamWConfig, adamw_update
from repro.optim.adamw import OptState

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

HBM_BYTES = 16e9               # v5e per chip
ACT_BUDGET = 6e9               # activation-carry budget driving microbatching


# ---------------------------------------------------------------------------
# Cell policy: dtypes, ZeRO stage, microbatches.
# ---------------------------------------------------------------------------

def cell_policy(cfg: ArchConfig, shape: str, mesh, overrides: dict
                ) -> dict:
    kind = SHAPES[shape].kind
    n_param = cfg.param_count()
    policy = {
        "kind": kind,
        "param_dtype": "float32" if kind == "train" else "bfloat16",
        "zero_stage": 3 if kind == "train" else 0,
        "moment_dtype": "bfloat16" if n_param > 2e11 else "float32",
        "remat": "full",
        "attn_block": 2048,
        "scan_layers": True,
        "microbatches": 1,
        "seq_shard": False,
        "moe_ep": True,
        "ar_barrier": False,
        "kv_seq_shard": False,
    }
    if kind != "train":
        # Serving: TP-only unless bf16 weights don't fit a chip.
        rules_tp = make_parallel(cfg, mesh, zero_stage=0).effective_rules()
        from repro.models.api import model_defs
        per_chip = sharded_size_bytes(
            tree_map_defs(lambda d: dataclasses.replace(d, dtype=jnp.bfloat16),
                          model_defs(cfg)),
            rules_tp, dict(mesh.shape))
        if per_chip > 0.85 * HBM_BYTES:
            policy["zero_stage"] = 3
    if kind == "train":
        sc = SHAPES[shape]
        data = 1
        for a in ("pod", "data"):
            data *= mesh.shape.get(a, 1)
        b_loc = max(sc.batch // data, 1)
        carry = cfg.n_layers * b_loc * sc.seq * cfg.d_model * 2.0
        micro = 1
        while carry / micro > ACT_BUDGET and micro < b_loc:
            micro *= 2
        policy["microbatches"] = micro
    policy.update(overrides)
    return policy


# ---------------------------------------------------------------------------
# Program builders.
# ---------------------------------------------------------------------------

def build_cell(cfg: ArchConfig, shape: str, mesh, policy: dict,
               probe_layers: int | None = None):
    """Returns (jitted_fn, arg_SDS_tuple)."""
    if probe_layers is not None:
        enc = probe_layers if cfg.n_encoder_layers else 0
        cfg = dataclasses.replace(cfg, n_layers=probe_layers,
                                  n_encoder_layers=enc)
    par = make_parallel(
        cfg, mesh, zero_stage=policy["zero_stage"],
        seq_shard=policy["seq_shard"], remat=policy["remat"],
        attn_block=policy["attn_block"],
        scan_layers=policy["scan_layers"] and probe_layers is None,
        moe_ep=policy["moe_ep"], ar_barrier=policy["ar_barrier"])
    model = build_model(cfg)
    pdt = jnp.dtype(policy["param_dtype"])
    p_sds = tree_map_defs(
        lambda d: jax.ShapeDtypeStruct(
            d.shape, pdt if jnp.issubdtype(d.dtype, jnp.floating) else d.dtype),
        model.defs)
    rules = par.effective_rules()
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                           param_pspecs(model.defs, rules))
    b_sds = input_specs(cfg, shape)
    b_shard = batch_shardings(cfg, shape, mesh, rules,
                              kv_seq_shard=policy["kv_seq_shard"])

    kind = policy["kind"]
    if kind == "train":
        mdt = jnp.dtype(policy["moment_dtype"])
        m_sds = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, mdt), p_sds)
        o_sds = OptState(m=m_sds, v=m_sds,
                         step=jax.ShapeDtypeStruct((), jnp.int32))
        # ZeRO-1/2: params (or dense params) replicated over data in
        # fwd/bwd, but moments always fully sharded over the data axes —
        # GSPMD reduce-scatters grads into the update and all-gathers the
        # new params, the classic ZeRO-1 schedule.
        m_rules = (make_parallel(cfg, mesh, zero_stage=3).effective_rules()
                   if policy["zero_stage"] in (1, 2) else rules)
        m_shard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                               param_pspecs(model.defs, m_rules))
        o_shard = OptState(m=m_shard, v=m_shard,
                           step=NamedSharding(mesh, P()))
        opt_cfg = AdamWConfig(moment_dtype=mdt)
        # Probes must see the whole batch in one pass: a microbatch scan is
        # another while-loop cost_analysis counts once (EXPERIMENTS §meth).
        micro = policy["microbatches"] if probe_layers is None else 1

        def train_step(params, opt_state, batch):
            def loss_fn(p, b):
                return model.loss(p, b, cfg, par)
            if micro > 1:
                def mstep(carry, mb):
                    l0, g0 = carry
                    l, g = jax.value_and_grad(loss_fn)(params, mb)
                    return (l0 + l, jax.tree.map(jnp.add, g0, g)), None
                split = jax.tree.map(
                    lambda x: x.reshape((micro, x.shape[0] // micro)
                                        + x.shape[1:])
                    if getattr(x, "ndim", 0) else x, batch)
                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (loss, grads), _ = jax.lax.scan(
                    mstep, (jnp.float32(0), zeros), split)
                loss, grads = loss / micro, jax.tree.map(
                    lambda g: g / micro, grads)
            else:
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            params, opt_state, _ = adamw_update(params, grads, opt_state,
                                                opt_cfg)
            return params, opt_state, loss

        fn = jax.jit(train_step, in_shardings=(p_shard, o_shard, b_shard),
                     donate_argnums=(0, 1))
        return fn, (p_sds, o_sds, b_sds)

    if kind == "prefill":
        def prefill(params, batch):
            return model.prefill(params, batch, cfg, par)
        fn = jax.jit(prefill, in_shardings=(p_shard, b_shard))
        return fn, (p_sds, b_sds)

    def decode(params, batch):
        return model.decode(params, batch, cfg, par)
    fn = jax.jit(decode, in_shardings=(p_shard, b_shard),
                 donate_argnums=(1,))
    return fn, (p_sds, b_sds)


# ---------------------------------------------------------------------------
# One cell end to end.
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape: str, multi_pod: bool, probes: bool = True,
             overrides: dict | None = None, tag: str = "") -> dict:
    cfg = configs.get(arch)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: dict = {"arch": arch, "shape": shape, "mesh": mesh_name,
                 "tag": tag or "baseline"}
    ok, reason = supports_shape(cfg, shape)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    policy = cell_policy(cfg, shape, mesh, overrides or {})
    rec["policy"] = {k: str(v) for k, v in policy.items()}
    t0 = time.time()
    try:
        with mesh:
            fn, args = build_cell(cfg, shape, mesh, policy)
            lowered = fn.lower(*args)
            compiled = lowered.compile()
            rec["memory"] = ha.memory_dict(compiled)      # proves it fits
            rec["cost_scanned"] = ha.cost_dict(compiled)
            rec["collectives_scanned"] = ha.collective_mix(
                compiled.as_text())
            rec["compile_s"] = round(time.time() - t0, 1)
            if probes:
                pc: dict = {}
                for L in (1, 2):
                    fnp, argsp = build_cell(cfg, shape, mesh, policy,
                                            probe_layers=L)
                    cp = fnp.lower(*argsp).compile()
                    hlo = cp.as_text()
                    pc[L] = {"cost": ha.cost_dict(cp),
                             "wire": ha.wire_bytes(hlo),
                             "wire_raw": ha.wire_bytes(
                                 hlo, bf16_dot_correction=False),
                             "mix": ha.collective_mix(hlo)}
                Lfull = cfg.n_layers
                rec["probe"] = {str(k): v for k, v in pc.items()}
                rec["flops"] = ha.extrapolate(
                    pc[1]["cost"]["flops"], pc[2]["cost"]["flops"], Lfull)
                rec["bytes"] = ha.extrapolate(
                    pc[1]["cost"]["bytes"], pc[2]["cost"]["bytes"], Lfull)
                rec["wire_bytes"] = ha.extrapolate(
                    pc[1]["wire"], pc[2]["wire"], Lfull)
                rec["wire_bytes_raw"] = ha.extrapolate(
                    pc[1]["wire_raw"], pc[2]["wire_raw"], Lfull)
                rec["coll_mix"] = {
                    op: ha.extrapolate(pc[1]["mix"].get(op, 0.0),
                                       pc[2]["mix"].get(op, 0.0), Lfull)
                    for op in set(pc[1]["mix"]) | set(pc[2]["mix"])}
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 - cell failures are data
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def cell_path(arch: str, shape: str, mesh_name: str, tag: str = "") -> str:
    suffix = f"__{tag}" if tag else ""
    return os.path.join(OUT_DIR, f"{arch}__{shape}__{mesh_name}{suffix}.json")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--override", action="append", default=[],
                    help="policy override key=value (e.g. attn_block=4096)")
    args = ap.parse_args()

    overrides: dict = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        overrides[k] = (int(v) if v.isdigit()
                        else v == "True" if v in ("True", "False") else v)

    os.makedirs(OUT_DIR, exist_ok=True)
    archs = [args.arch] if args.arch else list(configs.ALL_ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                path = cell_path(arch, shape, mesh_name, args.tag)
                if args.skip_done and os.path.exists(path):
                    continue
                rec = run_cell(arch, shape, mp, probes=not args.no_probes,
                               overrides=overrides, tag=args.tag)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec["status"]
                n_fail += status == "error"
                extra = (f" flops/chip={rec.get('flops', 0):.3e}"
                         if status == "ok" and "flops" in rec else
                         f" {rec.get('reason', rec.get('error', ''))[:90]}")
                print(f"[{status:7s}] {arch:22s} {shape:12s} {mesh_name:10s}"
                      f" {rec.get('total_s', 0):7.1f}s{extra}", flush=True)
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
