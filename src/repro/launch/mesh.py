"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state.  The dry-run forces 512
host devices before any jax import; the single-pod mesh then uses the first
256 (one v5e pod = 16x16 chips), the multi-pod mesh all 512 (2 pods).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have {len(devices)} "
            f"(set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            f"before importing jax for the dry-run)")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_smoke_mesh() -> Mesh:
    """1-device mesh with the production axis names (CPU tests)."""
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
