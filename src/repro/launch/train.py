"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it trains *reduced* configs end to end (the ~100M
example path); on a real slice drop ``--reduced`` and the same code
shards over the production mesh.  Checkpoint/resume: rerunning the same
command continues from the latest checkpoint in ``--ckpt-dir``.
"""
from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.launch.sharding import make_parallel
from repro.models.api import build_model
from repro.models.common import ShapeCfg
from repro.optim import AdamWConfig
from repro.train import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ALL_ARCHS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--production-mesh", action="store_true",
                    help="shard over the 16x16 mesh (needs 256 devices)")
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = None
    if args.production_mesh:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh()
    par = make_parallel(cfg, mesh, remat="none" if args.reduced else "full")
    model = build_model(cfg)
    tc = TrainConfig(
        steps=args.steps, microbatches=args.microbatches,
        ckpt_every=args.ckpt_every, log_every=max(args.steps // 20, 1),
        compress_grads=args.compress_grads,
        opt=AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                        total_steps=args.steps))
    shape = ShapeCfg("cli", "train", args.seq, args.batch)
    tr = Trainer(model, cfg, par, tc, shape=shape, ckpt_dir=args.ckpt_dir)
    start = tr.resume()
    print(f"arch={cfg.name} params={cfg.param_count():,} "
          f"devices={len(jax.devices())} resumed_at={start}")
    for m in tr.run():
        print(f"step {m['step']:5d}  loss {m['loss']:.4f}  "
              f"lr {m['lr']:.2e}  gnorm {m['grad_norm']:.2f}  "
          f"{m['sec']:.2f}s")


if __name__ == "__main__":
    main()
