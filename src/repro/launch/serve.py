"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Runs the continuous-batching engine over synthetic prompts on a reduced
config (CPU) or the production mesh (TPU).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from repro import configs
from repro.launch.sharding import make_parallel
from repro.models.api import build_model
from repro.models.params import init_params
from repro.serve import Request, ServeConfig, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ALL_ARCHS)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    par = make_parallel(cfg, None, remat="none")
    model = build_model(cfg)
    params = init_params(jax.random.key(0), model.defs)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        args.prompt_len).astype(np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    eng = ServeEngine(model, params, cfg, par,
                      ServeConfig(batch_slots=args.slots,
                                  max_len=args.prompt_len + args.max_new + 8,
                                  temperature=args.temperature))
    t0 = time.perf_counter()
    done = eng.run(reqs)
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.out_tokens) for r in done)
    print(f"arch={cfg.name} served {len(done)} requests, {n_tok} tokens "
          f"in {dt:.1f}s ({n_tok / dt:.1f} tok/s)")
    for r in done[:4]:
        print(f"  req {r.rid}: {r.out_tokens[:10]}")


if __name__ == "__main__":
    main()
