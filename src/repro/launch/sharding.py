"""Per-(arch x mesh) sharding policy: rules, batch specs, program builders.

``auto_rules`` adapts the logical->mesh table to an architecture: axes that
do not divide the tensor axis (e.g. 56 query heads or 25 kv-heads on a
16-way ``"model"`` axis) fall back to replication — GQA archs whose kv
heads < 16 keep kv replicated (the Megatron GQA rule) while q heads still
shard when divisible.  ``zero_stage=3`` additionally shards every weight's
``embed`` dim over the data axes (ZeRO-3 posture; required for the 67B+
training cells and the 1T serving cells).

``batch_shardings`` maps every ``input_specs`` key to a NamedSharding:
batch dims over ("pod","data") when divisible, KV caches' head dims over
``"model"`` when divisible, scalars replicated.
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ArchConfig, SHAPES, ShapeCfg, input_specs
from repro.models.params import DEFAULT_RULES, ShardingRules
from repro.models.parallel import ParallelCfg


def _div(n: int, size: int) -> bool:
    return n > 0 and n % size == 0


def auto_rules(cfg: ArchConfig, mesh: Mesh, zero_stage: int = 0,
               seq_shard: bool = False) -> ShardingRules:
    msize = mesh.shape.get("model", 1)
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    r = DEFAULT_RULES
    updates: dict = {}
    updates["heads"] = "model" if _div(cfg.n_heads, msize) else None
    updates["kv_heads"] = "model" if _div(cfg.n_kv_heads, msize) else None
    updates["mlp"] = "model" if _div(cfg.d_ff or 0, msize) or \
        _div(cfg.n_shared_experts * (cfg.d_ff or 0), msize) else None
    updates["expert"] = "model" if _div(cfg.n_experts, msize) else None
    updates["vocab"] = "model" if _div(cfg.padded_vocab, msize) else None
    updates["ssm_inner"] = "model" if _div(cfg.d_inner, msize) and \
        cfg.ssm_state else None
    updates["ssm_heads"] = "model" if _div(cfg.ssm_heads, msize) and \
        _div(cfg.d_inner, msize) else None
    updates["batch"] = data_axes
    updates["fsdp"] = data_axes
    if zero_stage >= 2:            # stage 2: shard only the expert bank
        updates["expert_embed"] = data_axes
    if zero_stage >= 3:            # stage 3: shard every weight's embed dim
        updates["embed"] = data_axes
    if seq_shard:
        updates["act_seq"] = "model"
    return r.replace(**updates)


def make_parallel(cfg: ArchConfig, mesh: Mesh | None, *, zero_stage: int = 0,
                  seq_shard: bool = False, remat: str = "full",
                  attn_block: int = 2048, scan_layers: bool = True,
                  moe_ep: bool = True, ar_barrier: bool = False
                  ) -> ParallelCfg:
    # ZeRO-1 shards only optimizer state (dryrun builds those shardings);
    # the model itself sees replicated-over-data params, i.e. stage 0.
    model_stage = 0 if zero_stage == 1 else zero_stage
    rules = (auto_rules(cfg, mesh, model_stage, seq_shard)
             if mesh is not None else DEFAULT_RULES)
    return ParallelCfg(mesh=mesh, rules=rules, remat=remat,
                       scan_layers=scan_layers, attn_block=attn_block,
                       seq_shard=seq_shard, moe_ep=moe_ep,
                       zero_stage=model_stage, ar_barrier=ar_barrier)


# ---------------------------------------------------------------------------
# Batch shardings per input_specs key.
# ---------------------------------------------------------------------------

def _batch_axes_for(B: int, mesh: Mesh) -> tuple[str, ...] | None:
    """Largest ("pod","data") prefix combination that divides B."""
    cands = []
    if "pod" in mesh.axis_names:
        cands.append(("pod", "data"))
        cands.append(("pod",))
    cands.append(("data",))
    for axes in cands:
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if _div(B, size):
            return axes
    return None


def batch_pspecs(cfg: ArchConfig, shape: str | ShapeCfg, mesh: Mesh,
                 rules: ShardingRules, kv_seq_shard: bool = False
                 ) -> dict[str, P]:
    """``kv_seq_shard``: shard the KV-cache *window* dim over "model" —
    the decode lever when kv-heads don't divide the tensor axis but the
    cache doesn't fit a chip (llava-34b x decode_32k: 32 GB/chip -> 2 GB).
    GSPMD turns the windowed softmax into partial max/sum + tiny ARs."""
    sc = SHAPES[shape] if isinstance(shape, str) else shape
    specs = input_specs(cfg, sc)
    msize = mesh.shape.get("model", 1)
    out: dict[str, P] = {}
    for k, s in specs.items():
        if not s.shape:                       # scalars (pos)
            out[k] = P()
            continue
        if k in ("k_cache", "v_cache"):       # [L, B, W, KVH, dh]
            bt = _batch_axes_for(s.shape[1], mesh)
            kv = "model" if _div(s.shape[3], msize) else None
            if kv_seq_shard and kv is None and _div(s.shape[2], msize):
                out[k] = P(None, bt, "model", None, None)
                continue
            out[k] = P(None, bt, None, kv, None)
        elif k in ("enc_out", "enc_out_v"):   # [L, B, S, KVH, dh]
            bt = _batch_axes_for(s.shape[1], mesh)
            kv = "model" if _div(s.shape[3], msize) else None
            out[k] = P(None, bt, None, kv, None)
        elif k == "ssm_state":                # [L, B, H, P, N]
            bt = _batch_axes_for(s.shape[1], mesh)
            hs = "model" if _div(s.shape[2], msize) else None
            out[k] = P(None, bt, hs, None, None)
        elif k == "conv_state":               # [L, B, K-1, C]
            bt = _batch_axes_for(s.shape[1], mesh)
            out[k] = P(None, bt, None, None)
        else:                                 # [B, ...] tokens/labels/embeds
            bt = _batch_axes_for(s.shape[0], mesh)
            out[k] = P(bt, *([None] * (len(s.shape) - 1)))
    return out


def batch_shardings(cfg: ArchConfig, shape, mesh: Mesh,
                    rules: ShardingRules, kv_seq_shard: bool = False
                    ) -> dict[str, NamedSharding]:
    return {k: NamedSharding(mesh, v)
            for k, v in batch_pspecs(cfg, shape, mesh, rules,
                                     kv_seq_shard).items()}
