"""Training loop: jit'd fused step + fault-tolerant driver.

``make_train_step`` builds one XLA program containing forward, backward,
(optional) microbatch gradient accumulation, (optional) int8 error-feedback
gradient compression, clipping and the AdamW update — the program the
multi-pod dry-run lowers for every (arch x shape) train cell.

``Trainer`` is the driver: data pipeline, checkpoint/restore (atomic,
async, keep-k), preemption recovery (``resume()`` picks up from the latest
complete checkpoint, including the data-pipeline cursor), and a fault hook
for tests to inject crashes at arbitrary steps.  Straggler mitigation and
node-failure rescheduling live one level up, in ``repro.cluster.executor``,
where whole jobs are FJSP tasks — inside one synchronous SPMD program the
collectives themselves are the straggler barrier.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticPipeline
from repro.models.api import Model
from repro.models.common import ArchConfig
from repro.models.parallel import ParallelCfg
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         compress_init, compressed_grads)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    microbatches: int = 1          # grad-accumulation chunks per step
    ckpt_every: int = 50
    log_every: int = 10
    compress_grads: bool = False   # int8 error-feedback (cross-pod reduce)
    opt: AdamWConfig = AdamWConfig()


def make_train_step(model: Model, cfg: ArchConfig, par: ParallelCfg,
                    tc: TrainConfig) -> Callable:
    """(params, opt_state, cstate, batch) -> (params, opt_state, cstate,
    metrics), one jit-able program."""

    def loss_fn(params, batch):
        return model.loss(params, batch, cfg, par)

    def grads_of(params, batch):
        if tc.microbatches <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def micro(carry, mb):
            loss_acc, g_acc = carry
            loss, g = jax.value_and_grad(loss_fn)(params, mb)
            return (loss_acc + loss,
                    jax.tree.map(jnp.add, g_acc, g)), None

        split = jax.tree.map(
            lambda x: x.reshape((tc.microbatches,
                                 x.shape[0] // tc.microbatches) + x.shape[1:])
            if x.ndim else jnp.broadcast_to(x, (tc.microbatches,)), batch)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (loss, g), _ = jax.lax.scan(micro, (jnp.float32(0.0), zeros), split)
        inv = 1.0 / tc.microbatches
        return loss * inv, jax.tree.map(lambda x: x * inv, g)

    def step(params, opt_state, cstate, batch):
        loss, grads = grads_of(params, batch)
        metrics = {"loss": loss}
        if tc.compress_grads:
            grads, cstate, cm = compressed_grads(grads, cstate)
            metrics.update(cm)
        params, opt_state, om = adamw_update(params, grads, opt_state, tc.opt)
        metrics.update(om)
        return params, opt_state, cstate, metrics

    return step


class Trainer:
    def __init__(self, model: Model, cfg: ArchConfig, par: ParallelCfg,
                 tc: TrainConfig, shape: str = "train_4k",
                 ckpt_dir: str | None = None, scale_batch: int = 1,
                 data_cfg: DataConfig = DataConfig(),
                 fault_hook: Callable[[int], None] | None = None):
        self.model, self.cfg, self.par, self.tc = model, cfg, par, tc
        self.pipeline = SyntheticPipeline(cfg, shape, data_cfg,
                                          scale_batch=scale_batch)
        self.ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self.fault_hook = fault_hook
        self.step_fn = jax.jit(make_train_step(model, cfg, par, tc),
                               donate_argnums=(0, 1, 2))
        self.state: dict[str, Any] = {}
        self.history: list[dict] = []

    # -- state ----------------------------------------------------------------
    def init(self, seed: int = 0) -> None:
        from repro.models.params import init_params
        params = init_params(jax.random.key(seed), self.model.defs)
        self.state = {"params": params,
                      "opt": adamw_init(params, self.tc.opt),
                      "cstate": compress_init(params),
                      "data": {"step": 0}}

    def resume(self) -> int:
        """Restore the latest checkpoint; returns the step resumed from
        (0 if none).  Called on every (re)start — this is the preemption
        recovery path."""
        if self.ckpt is None or self.ckpt.latest() is None:
            if not self.state:
                self.init()
            return 0
        if not self.state:
            self.init()
        self.state = self.ckpt.restore(self.state)
        self.pipeline.load_state_dict(
            {"step": int(self.state["data"]["step"])})
        return int(self.state["opt"].step)

    # -- run ------------------------------------------------------------------
    def run(self, steps: int | None = None) -> list[dict]:
        steps = steps if steps is not None else self.tc.steps
        start = int(self.state["opt"].step)
        for i in range(start, steps):
            if self.fault_hook is not None:
                self.fault_hook(i)      # may raise to simulate preemption
            batch = self.pipeline.next_batch()
            t0 = time.perf_counter()
            (self.state["params"], self.state["opt"], self.state["cstate"],
             metrics) = self.step_fn(self.state["params"], self.state["opt"],
                                     self.state["cstate"], batch)
            self.state["data"] = {"step": self.pipeline.step}
            if (i + 1) % self.tc.log_every == 0 or i == start:
                m = {k: float(v) for k, v in metrics.items()}
                m.update(step=i + 1, sec=time.perf_counter() - t0)
                self.history.append(m)
            if self.ckpt and (i + 1) % self.tc.ckpt_every == 0:
                self.ckpt.save(i + 1, self.state)
        if self.ckpt:
            self.ckpt.save(steps, self.state, blocking=True)
        return self.history
