"""Deterministic synthetic data pipeline.

Produces the exact batch dict that ``input_specs`` promises for any
(arch x shape) cell, generated on the host from a counter-based PRNG —
restartable from any step with no stored state beyond the step index
(the property the checkpoint/resume path relies on), and shardable: each
host generates only its slice when ``process_index/process_count`` are
set (multi-host posture; this container has one process).

The token stream is a Zipf-ish mixture with a Markov backbone so the
cross-entropy is learnable (loss decreases in the quickstart example) —
uniform random tokens would make optimizer bugs invisible.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax.numpy as jnp

from repro.models.common import ArchConfig, SHAPES, ShapeCfg, input_specs


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    zipf_a: float = 1.2
    markov_weight: float = 0.7     # P(next = f(cur)) vs fresh zipf draw


class SyntheticPipeline:
    """Iterator of batch dicts for (cfg, shape). State = step counter."""

    def __init__(self, cfg: ArchConfig, shape: str | ShapeCfg,
                 data_cfg: DataConfig = DataConfig(), scale_batch: int = 1,
                 process_index: int = 0, process_count: int = 1):
        self.cfg = cfg
        self.shape = SHAPES[shape] if isinstance(shape, str) else shape
        self.data_cfg = data_cfg
        self.scale_batch = scale_batch
        self.process_index = process_index
        self.process_count = process_count
        self.step = 0
        self._specs = input_specs(cfg, self.shape, scale_batch=scale_batch)

    # -- restart support ----------------------------------------------------
    def state_dict(self) -> dict:
        return {"step": self.step}

    def load_state_dict(self, state: dict) -> None:
        self.step = int(state["step"])

    # -- generation ----------------------------------------------------------
    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.data_cfg.seed, step, self.process_index))

    def _tokens(self, rng: np.random.Generator, shape: tuple[int, ...]
                ) -> np.ndarray:
        V = self.cfg.vocab_size
        fresh = np.minimum(rng.zipf(self.data_cfg.zipf_a, size=shape) - 1,
                           V - 1).astype(np.int32)
        out = np.empty(shape, np.int32)
        out[:, 0] = fresh[:, 0]
        keep = rng.random(shape) < self.data_cfg.markov_weight
        for t in range(1, shape[1]):                  # Markov: next = 7x+3
            out[:, t] = np.where(keep[:, t],
                                 (out[:, t - 1] * 7 + 3) % V, fresh[:, t])
        return out

    def next_batch(self) -> dict:
        rng = self._rng(self.step)
        self.step += 1
        batch = {}
        for k, spec in self._specs.items():
            # Per-process slice of the global batch (dim 0).
            shape = tuple(spec.shape)
            if shape and self.process_count > 1 and k != "pos":
                shape = (shape[0] // self.process_count,) + shape[1:]
            if k in ("tokens", "token"):
                batch[k] = jnp.asarray(self._tokens(rng, shape))
            elif k == "labels":
                pass                                   # filled below
            elif k == "pos":
                batch[k] = jnp.int32(self.shape.seq // 2)
            elif spec.dtype == jnp.int32:
                batch[k] = jnp.zeros(shape, jnp.int32)
            else:
                arr = rng.standard_normal(size=shape).astype(np.float32)
                batch[k] = jnp.asarray(0.02 * arr, dtype=spec.dtype)
        if "labels" in self._specs:
            toks = np.asarray(batch["tokens"])
            labels = np.concatenate(
                [toks[:, 1:], np.full((toks.shape[0], 1), -1, np.int32)], 1)
            batch["labels"] = jnp.asarray(labels)
        return batch

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        return self.next_batch()
