"""Table 1b: effect of tasks-per-job k in {3, 4, 5} (homogeneous, S=1).

Paper: k=3 -> ~30% savings at 36% utilization; k=5 -> ~20% at 57% —
more tasks raise utilization and shrink the shifting headroom.
"""
from __future__ import annotations

from benchmarks.common import BenchSetup, run_batch, summarize, write_csv


def run(instances: int = 24) -> list[dict]:
    rows = []
    for k in (3, 4, 5):
        r = run_batch(BenchSetup(k_tasks=k, stretch=1.0,
                                 instances=instances))
        row = {"bench": "table1b", "k_tasks": k}
        row.update(summarize(r))
        rows.append(row)
    write_csv("table1b_tasks", rows)
    return rows
