"""Streaming dispatch service under load: throughput, queue delay, savings.

The closed-batch sweeps measure what gating saves when every job is known
at t=0.  This benchmark drives the streaming engine (:mod:`repro.stream`)
with continuous arrivals and measures what the batch path cannot see: the
carbon/latency tension of a *finite lane pool*.  Delaying a job into a
cleaner window keeps its lane busy longer, so at high load the queue backs
up — savings are bought with queue delay.

For each (arrival family x load factor) cell the harness calibrates the
arrival rate against the pool's greedy service capacity (``load = arrival
rate / (n_lanes / mean greedy makespan)``), streams one seeded scenario
through :func:`repro.stream.simulate_stream`, and reports

* sustained dispatch throughput (jobs/sec of wall clock, post-warmup);
* the queue-delay distribution (epochs from arrival to lane admission);
* the carbon-savings distribution vs each job's greedy-at-admission
  baseline;
* unfinished/rejected job counts (the overload signal).

Outputs ``BENCH_stream.json`` (repo root by default) plus a per-cell CSV
under ``experiments/bench/``.  Expected shape: savings stay roughly flat
with load (the gate is per-job) while queue delay grows superlinearly as
load approaches 1 — and faster for the bursty family at equal load.

With ``--shared-fleet`` every cell also runs against ONE shared machine set
(``StreamConfig.shared_fleet=True``: lanes contend for machines inside the
epoch, the paper's common-fleet model) and the report gains per-cell
queue-delay/savings deltas vs the partitioned baseline.

    python -m benchmarks.stream_serve                   # full grid
    python -m benchmarks.stream_serve --tiny            # CI smoke grid
    python -m benchmarks.stream_serve --shared-fleet    # both fleet modes
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import numpy as np

from benchmarks.common import bench_timing, write_csv, write_json
from repro.core.instance import Instance, pack
from repro.core.objectives import makespan
from repro.core.solvers.online_jax import online_greedy_jax
from repro.obs import Tracer
from repro.scenarios.fleets import build_fleet
from repro.scenarios.generator import ScenarioConfig, sample_job
from repro.stream import StreamConfig, simulate_stream

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_stream.json")

# Full grid: 3 arrival families x 4 load factors, day-scale stream.
FULL = dict(horizon=1024, n_lanes=8, family="layered", width=3, depth=3,
            n_machines=3, fleet="tiered", mean_dur=6.0,
            loads=(0.3, 0.6, 0.9, 1.2),
            families=("poisson", "bursty", "diurnal"))

# Tiny grid (CI smoke): 2 families x 3 loads, quarter-day stream.
TINY = dict(horizon=256, n_lanes=4, family="layered", width=3, depth=2,
            n_machines=3, fleet="tiered", mean_dur=5.0,
            loads=(0.4, 0.8, 1.2),
            families=("poisson", "bursty"))


def probe_service_epochs(knobs: dict, seed: int, n_probe: int = 8) -> float:
    """Mean greedy makespan of the cell's job distribution — the per-lane
    service time the load factor is calibrated against."""
    rng = np.random.default_rng(seed)
    scen = ScenarioConfig(family=knobs["family"], n_jobs=1,
                          width=knobs["width"], depth=knobs["depth"],
                          n_machines=knobs["n_machines"],
                          fleet=knobs["fleet"],
                          mean_dur=knobs["mean_dur"]).validate()
    jobs = [dataclasses.replace(sample_job(rng, scen), arrival=0)
            for _ in range(n_probe)]
    powers, speeds = build_fleet(knobs["fleet"], rng, knobs["n_machines"])
    T = max(j.n_tasks for j in jobs)
    ms = []
    for j in jobs:
        inst = pack(Instance(jobs=(j,), powers_kw=powers, speeds=speeds),
                    pad_tasks=T)
        g = online_greedy_jax(inst, 512)
        ms.append(int(makespan(inst, g.start, g.assign)))
    return float(np.mean(ms))


def _dist(xs: list[float]) -> dict:
    if not xs:
        return {"mean": 0.0, "p50": 0.0, "p90": 0.0, "max": 0.0}
    a = np.asarray(xs, np.float64)
    return {"mean": round(float(a.mean()), 3),
            "p50": round(float(np.percentile(a, 50)), 3),
            "p90": round(float(np.percentile(a, 90)), 3),
            "max": round(float(a.max()), 3)}


def _round_dist(d: dict) -> dict:
    return {k: round(v, 3) if isinstance(v, float) else v
            for k, v in d.items()}


def _cell_config(knobs: dict, family: str, rate: float, seed: int,
                 shared_fleet: bool = False) -> StreamConfig:
    return StreamConfig(arrivals=family, rate=rate, horizon=knobs["horizon"],
                        n_lanes=knobs["n_lanes"], family=knobs["family"],
                        width=knobs["width"], depth=knobs["depth"],
                        n_machines=knobs["n_machines"], fleet=knobs["fleet"],
                        mean_dur=knobs["mean_dur"], seed=seed,
                        shared_fleet=shared_fleet)


def run_cell(knobs: dict, family: str, load: float, rate: float,
             seed: int, shared_fleet: bool = False) -> dict:
    cfg = _cell_config(knobs, family, rate, seed, shared_fleet=shared_fleet)
    t0 = time.time()
    res = simulate_stream(cfg)
    seconds = time.time() - t0
    # Counts and distributions come from the engine's own metrics registry
    # (res.summary) — the benchmark no longer re-derives them from job lists.
    s = res.summary
    n_finished = s["jobs_completed"]
    finished = [sj for sj in res.jobs if sj.finished]
    return {
        "arrivals": family,
        "load": load,
        "shared_fleet": shared_fleet,
        "rate_jobs_per_epoch": round(rate, 5),
        "n_jobs": len(res.jobs),
        "n_admitted": s["jobs_admitted"],
        "n_rejected": s["jobs_rejected"],
        "n_finished": n_finished,
        "n_truncated": s["jobs_truncated"],
        "n_unfinished": len(res.jobs) - n_finished,
        "final_lane_occupancy": s["final_lane_occupancy"],
        "seconds": round(seconds, 3),
        "jobs_per_sec": round(n_finished / max(seconds, 1e-9), 2),
        "queue_delay_epochs": _round_dist(s["queue_delay_epochs"]),
        "carbon_savings_pct": _round_dist(s["carbon_savings_pct"]),
        "realized_stretch": _dist(
            [(sj.completed - sj.admitted)
             / max(1, sj.greedy_makespan - sj.admitted)
             for sj in finished]),
    }


def export_trace(path: str, seed: int = 2024) -> str:
    """Stream one tiny traced cell and export its Chrome-trace JSON (the CI
    trace artifact; open at https://ui.perfetto.dev)."""
    knobs = dict(TINY)
    loads, families = knobs.pop("loads"), knobs.pop("families")
    service = probe_service_epochs(knobs, seed)
    rate = loads[0] * knobs["n_lanes"] / service
    tracer = Tracer()
    simulate_stream(_cell_config(knobs, families[0], rate, seed),
                    tracer=tracer)
    lanes = {i: f"lane {i}" for i in range(knobs["n_lanes"])}
    tracer.export(path, lane_names=lanes)
    print(f"# stream_serve: wrote engine trace {path} "
          f"({len(tracer.events)} events)", flush=True)
    return path


def _fleet_deltas(rows: list[dict]) -> list[dict]:
    """Per-(family, load) shared-minus-partitioned deltas: the contention
    cost (queue delay up) and gate-interaction cost (savings down) of one
    common machine set vs disjoint per-lane partitions."""
    part = {(r["arrivals"], r["load"]): r for r in rows
            if not r["shared_fleet"]}
    out = []
    for r in rows:
        if not r["shared_fleet"]:
            continue
        p = part.get((r["arrivals"], r["load"]))
        if p is None:
            continue
        out.append({
            "arrivals": r["arrivals"],
            "load": r["load"],
            "queue_delay_mean_delta": round(
                r["queue_delay_epochs"]["mean"]
                - p["queue_delay_epochs"]["mean"], 3),
            "queue_delay_p90_delta": round(
                r["queue_delay_epochs"]["p90"]
                - p["queue_delay_epochs"]["p90"], 3),
            "savings_mean_delta_pct": round(
                r["carbon_savings_pct"]["mean"]
                - p["carbon_savings_pct"]["mean"], 3),
            "finished_delta": r["n_finished"] - p["n_finished"],
        })
    return out


def run(tiny: bool = False, out: str | None = None,
        seed: int = 2024, shared_fleet: bool = False) -> list[dict]:
    """``shared_fleet=True`` runs each cell in BOTH fleet modes (partitioned
    baseline + one shared machine set) and reports per-cell deltas."""
    knobs = dict(TINY if tiny else FULL)
    loads = knobs.pop("loads")
    families = knobs.pop("families")
    service = probe_service_epochs(knobs, seed)
    capacity = knobs["n_lanes"] / service      # jobs/epoch the pool clears
    fleet_modes = (False, True) if shared_fleet else (False,)
    # Warmup cell outside the clock so per-cell seconds are post-compile.
    for sf in fleet_modes:
        run_cell(knobs, families[0], loads[0], loads[0] * capacity, seed,
                 shared_fleet=sf)

    t0 = time.time()
    rows = [run_cell(knobs, fam, load, load * capacity, seed,
                     shared_fleet=sf)
            for sf in fleet_modes for fam in families for load in loads]
    seconds = time.time() - t0

    record = {
        "bench": "stream_serve",
        "mode": "tiny" if tiny else "full",
        "shared_fleet_axis": shared_fleet,
        "seconds": round(seconds, 3),
        "timing": bench_timing(seconds),
        "seed": seed,
        "service_epochs": round(service, 3),
        "capacity_jobs_per_epoch": round(capacity, 5),
        **{k: v for k, v in knobs.items()},
        "cells": rows,
    }
    if shared_fleet:
        record["fleet_deltas"] = _fleet_deltas(rows)
    write_json(out or BENCH_JSON, record)
    write_csv("stream_serve" + ("_tiny" if tiny else ""),
              [{k: v for k, v in r.items() if not isinstance(v, dict)}
               for r in rows])

    print(f"# stream_serve[{record['mode']}]: {len(rows)} cells in "
          f"{seconds:.1f}s (service={service:.1f} epochs, "
          f"capacity={capacity:.4f} jobs/epoch)", flush=True)
    for r in rows:
        tag = " shared" if r["shared_fleet"] else ""
        print(f"#   {r['arrivals']:>7} load={r['load']:.1f}{tag}: "
              f"{r['n_finished']}/{r['n_jobs']} finished, "
              f"delay p90={r['queue_delay_epochs']['p90']}, "
              f"savings mean={r['carbon_savings_pct']['mean']}%, "
              f"{r['jobs_per_sec']} jobs/s", flush=True)
    for d in record.get("fleet_deltas", ()):
        print(f"#   delta {d['arrivals']:>7} load={d['load']:.1f}: "
              f"delay mean {d['queue_delay_mean_delta']:+.2f} epochs, "
              f"savings {d['savings_mean_delta_pct']:+.2f}pp", flush=True)
    return rows


def run_harness(instances: int = 16) -> list[dict]:
    """Adapter for ``benchmarks.run`` — small ``--instances`` requests map
    to the tiny grid (the stream length is the cost axis here)."""
    return run(tiny=instances <= 16)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke grid")
    ap.add_argument("--shared-fleet", action="store_true",
                    help="add the shared-fleet axis: run every cell in both "
                         "fleet modes and report contention deltas")
    ap.add_argument("--seed", type=int, default=2024)
    ap.add_argument("--out", type=str, default=None,
                    help=f"output JSON path (default {BENCH_JSON})")
    ap.add_argument("--trace-out", type=str, default=None, metavar="PATH",
                    help="skip the grid; stream one tiny traced cell and "
                         "export its Chrome-trace JSON to PATH")
    args = ap.parse_args()
    if args.trace_out:
        export_trace(args.trace_out, seed=args.seed)
        return
    run(tiny=args.tiny, out=args.out, seed=args.seed,
        shared_fleet=args.shared_fleet)


if __name__ == "__main__":
    main()
