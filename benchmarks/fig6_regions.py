"""Fig. 6: carbon savings at S=1 across grid regions.

Paper: AU-SA and CAL large savings (high variability / solar); TEX small
(high mean, low variance); CA-ON small (already ~90% clean).
"""
from __future__ import annotations

from benchmarks.common import BenchSetup, run_batch, summarize, write_csv

REGIONS = ("AU-SA", "CAL", "TEX", "CA-ON")


def run(instances: int = 24) -> list[dict]:
    rows = []
    for hetero in (False, True):
        for region in REGIONS:
            r = run_batch(BenchSetup(heterogeneous=hetero, region=region,
                                     stretch=1.0, instances=instances))
            row = {"bench": "fig6", "setup": "hetero" if hetero else "homo",
                   "region": region}
            row.update(summarize(r))
            rows.append(row)
    write_csv("fig6_regions", rows)
    return rows
