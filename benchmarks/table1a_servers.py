"""Table 1a: effect of server count M in {2, 5, 10} (homogeneous, S=1).

Paper: M=2 -> ~1% savings at 89% utilization; M=10 -> ~34% at 24% —
more servers = more slack to shift into clean windows.
"""
from __future__ import annotations

from benchmarks.common import BenchSetup, run_batch, summarize, write_csv


def run(instances: int = 24) -> list[dict]:
    rows = []
    for m in (2, 5, 10):
        r = run_batch(BenchSetup(n_machines=m, stretch=1.0,
                                 instances=instances))
        row = {"bench": "table1a", "n_machines": m}
        row.update(summarize(r))
        rows.append(row)
    write_csv("table1a_servers", rows)
    return rows
