"""Savings vs. structure and server count, at sweep scale.

The paper's sensitivity analysis reports that job structure and server
count set the achievable carbon reduction.  This benchmark reproduces that
trend with the scenario subsystem (:mod:`repro.scenarios`): a grid of
family x (width, depth) x server-count x fleet cells, every cell's
instances padded and stacked into ONE batch, dispatched by the carbon-gated
online scheduler across a gate-policy grid and bounded by the offline SA
bi-level solve — two XLA programs for the whole grid, a scale the
sequential numpy event loop could never reach.

Outputs ``BENCH_structure.json`` (repo root by default): one row per cell
plus the trend summary (savings by family / server count / fleet).  The
expected qualitative shape, matching the paper: savings grow with server
count and with slack-rich (parallelism-friendly, low-utilization)
structures, and the online gate captures a large fraction of the offline
bound.

    python -m benchmarks.structure_sweep             # full grid
    python -m benchmarks.structure_sweep --tiny      # CI smoke / golden grid
    python -m benchmarks.structure_sweep --no-offline  # dispatch only

``--tiny`` is the exact grid the golden regression test
(``tests/test_structure_golden.py``) locks; CI runs it every push and
uploads the JSON as an artifact.
"""
from __future__ import annotations

# jax.distributed must initialize before ANY jax computation, and some
# transitive imports below build module-level jnp constants — so join the
# fleet (a no-op in a plain single-process run, see docs/sharding.md)
# before importing anything that touches jax.
from repro.shard.distributed import initialize_from_env

initialize_from_env()

import argparse
import os
import time

from benchmarks.common import bench_timing, write_csv, write_json
from repro.core.solvers.annealing import SAConfig
from repro.scenarios import (SweepSpec, structure_cells, sweep_structure,
                             trend_summary)

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_structure.json")

FAMILIES = ("chain", "fanout", "diamond", "layered", "tpch")

# Sizes are per-family (width, depth) pairs chosen so *tasks per job* match
# across families at each size step — the family axis then compares
# structure at equal load (the paper's Fig. 3 comparison), not structure
# confounded with job size.  Task counts: chain = depth, fanout =
# 2 + width*depth, diamond = depth*(width+2), layered ~ depth*(width+1)/2,
# tpch = 2*width - 1 + depth.

# Full grid: 5 families x 2 sizes (6 and 10 tasks/job) x 3 server counts
# x 2 fleets = 60 cells.
FULL = dict(sizes={"chain": ((1, 6), (1, 10)),
                   "fanout": ((2, 2), (4, 2)),
                   "diamond": ((1, 2), (3, 2)),
                   "layered": ((3, 3), (4, 4)),
                   "tpch": ((3, 1), (4, 3))},
            machine_counts=(2, 5, 8),
            fleets=("homog", "tiered"), n_jobs=6,
            instances_per_cell=4, horizon=2048,
            sa=SAConfig(pop=24, iters=40, sweeps=1))

# Tiny grid (CI smoke + golden lock): 5 x 1 size (4 tasks/job) x 2 x 2 =
# 20 cells, 2 instances each.
TINY = dict(sizes={"chain": ((1, 4),),
                   "fanout": ((2, 1),),
                   "diamond": ((2, 1),),
                   "layered": ((3, 2),),
                   "tpch": ((2, 1),)},
            machine_counts=(2, 4),
            fleets=("homog", "tiered"), n_jobs=4,
            instances_per_cell=2, horizon=768,
            sa=SAConfig(pop=16, iters=24, sweeps=1))


def make_spec(tiny: bool = False, instances_per_cell: int | None = None,
              seed: int = 2024) -> SweepSpec:
    knobs = dict(TINY if tiny else FULL)
    sa = knobs.pop("sa")
    n_jobs = knobs.pop("n_jobs")
    ipc = instances_per_cell or knobs.pop("instances_per_cell")
    knobs.pop("instances_per_cell", None)
    horizon = knobs.pop("horizon")
    cells = structure_cells(families=FAMILIES, n_jobs=n_jobs, **knobs)
    return SweepSpec(cells=cells, instances_per_cell=ipc, seed=seed,
                     horizon=horizon, sa=sa)


def check_devices(devices: int | None) -> int | None:
    """Validate a ``--devices`` request against the visible platform."""
    if devices is None:
        return None
    import jax
    if devices > len(jax.devices()):
        raise SystemExit(
            f"--devices {devices}: only {len(jax.devices())} local "
            "device(s) visible — on CPU, force fake devices with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={devices}")
    return int(devices)


def check_topology(devices: int | None,
                   processes: int | None) -> tuple[int | None, int | None]:
    """Join the ``jax.distributed`` fleet (if the ``REPRO_*`` env names
    one) and validate ``--devices``/``--processes`` against it.

    Must run before anything touches jax devices — process topology locks
    at first backend init.  Single-process (``processes=None``) reduces to
    :func:`check_devices`; with ``--processes`` the command must be
    running once per rank (``python -m tests.harness --processes P
    --devices D -- <this command>`` spawns that), and ``devices`` counts
    fake devices *per process*.
    """
    from repro.shard.distributed import initialize_from_env
    initialize_from_env()
    if processes is None:
        return check_devices(devices), None
    import jax
    if jax.process_count() != processes:
        raise SystemExit(
            f"--processes {processes}: this run has {jax.process_count()} "
            "jax process(es) — launch one worker per rank, e.g. "
            f"python -m tests.harness --processes {processes} "
            f"--devices {devices or 1} -- <this command>")
    if devices is not None and devices > len(jax.local_devices()):
        raise SystemExit(
            f"--devices {devices}: only {len(jax.local_devices())} local "
            "device(s) per process — the harness forces "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={devices} "
            "in every worker")
    return devices, int(processes)


def run(tiny: bool = False, offline: bool = True,
        instances_per_cell: int | None = None, out: str | None = None,
        seed: int = 2024, devices: int | None = None,
        processes: int | None = None) -> list[dict]:
    devices, processes = check_topology(devices, processes)
    spec = make_spec(tiny=tiny, instances_per_cell=instances_per_cell,
                     seed=seed)
    t0 = time.time()
    rows, meta = sweep_structure(spec, offline=offline, devices=devices,
                                 processes=processes)
    seconds = time.time() - t0

    trends = trend_summary(rows)
    record = {
        "bench": "structure_sweep",
        "mode": "tiny" if tiny else "full",
        "seconds": round(seconds, 3),
        "timing": bench_timing(seconds),
        **meta,
        "trends": trends,
        "cells": rows,
    }
    write_json(out or BENCH_JSON, record)
    write_csv("structure_sweep" + ("_tiny" if tiny else ""),
              [{k: v for k, v in r.items()
                if not isinstance(v, (list, dict))} for r in rows])

    print(f"# structure_sweep[{record['mode']}]: {len(rows)} cells x "
          f"{spec.instances_per_cell} instances in {seconds:.1f}s "
          f"on {meta['processes']} process(es) x {meta['devices']} "
          f"device(s) (pad T={meta['pad_tasks']}, M={meta['pad_machines']})",
          flush=True)
    for key, series in trends.items():
        print(f"#   {key}: {series}", flush=True)
    return rows


def run_harness(instances: int = 16) -> list[dict]:
    """Adapter for ``benchmarks.run`` (its ``--instances`` is the per-setup
    batch size; here it maps to instances per grid cell, clamped)."""
    return run(instances_per_cell=min(8, max(1, instances // 4)))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke grid (the golden-locked cells)")
    ap.add_argument("--no-offline", action="store_true",
                    help="skip the offline SA bound (dispatch only)")
    ap.add_argument("--instances", type=int, default=None,
                    help="instances per cell (default: grid preset)")
    ap.add_argument("--seed", type=int, default=2024)
    ap.add_argument("--devices", type=int, default=None,
                    help="shard the instance axis over N local devices "
                         "(bit-exact with the single-device sweep; the "
                         "'seconds'/'devices' columns record the sharded "
                         "wall clock); with --processes, devices per "
                         "process")
    ap.add_argument("--processes", type=int, default=None,
                    help="span the shards over a P-process jax.distributed "
                         "fleet (bit-exact; run one worker per rank via "
                         "python -m tests.harness --processes P --devices D "
                         "-- <this command>)")
    ap.add_argument("--out", type=str, default=None,
                    help=f"output JSON path (default {BENCH_JSON})")
    args = ap.parse_args()
    run(tiny=args.tiny, offline=not args.no_offline,
        instances_per_cell=args.instances, out=args.out, seed=args.seed,
        devices=args.devices, processes=args.processes)


if __name__ == "__main__":
    main()
