"""Beyond-paper: the price of online — carbon-gated dispatch vs the bound.

The paper's §4 poses online heuristics as future work.  This benchmark
quantifies the gap on the paper's own setup (AU-SA, n=10, k=4, M=5,
homogeneous): the offline bi-level bound vs two online dispatchers that
see jobs only at arrival (online_greedy is also the savings baseline):

    savings(online)  = 1 - carbon(gated) / carbon(greedy)
    savings(offline) = the §Paper S=1.5 bound on the same instances
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import (DEF_HORIZON, SA_FAST, BenchSetup, write_csv)
from repro.core import generate_instance, pack, synthesize
from repro.core.objectives import check_feasible_np, evaluate
from repro.core.solvers import solve_bilevel
from repro.core.solvers.online import online_carbon_gated, online_greedy


def run(instances: int = 16) -> list[dict]:
    setup = BenchSetup(stretch=1.5)
    rng = np.random.default_rng(setup.seed)
    year = synthesize(setup.region, days=366, seed=2024)
    keys = jax.random.split(jax.random.key(setup.seed), instances)
    sav_online, sav_offline, overshoot = [], [], []
    for i in range(instances):
        inst = generate_instance(rng, n_jobs=setup.n_jobs,
                                 k_tasks=setup.k_tasks,
                                 n_machines=setup.n_machines)
        p = pack(inst, pad_tasks=setup.n_jobs * setup.k_tasks)
        w = year.window(int(rng.integers(0, year.n_epochs - DEF_HORIZON)),
                        DEF_HORIZON)
        cum = jnp.asarray(w.cumulative())
        s0, a0 = online_greedy(p)
        sg, ag = online_carbon_gated(p, w.intensity, theta=0.4,
                                     stretch=setup.stretch)
        assert not check_feasible_np(p, sg, ag)
        base = evaluate(p, jnp.asarray(s0), jnp.asarray(a0), cum)
        gated = evaluate(p, jnp.asarray(sg), jnp.asarray(ag), cum)
        sav_online.append(1 - float(gated.carbon) / float(base.carbon))
        overshoot.append(float(gated.makespan) / float(base.makespan))
        res = solve_bilevel(p, cum, keys[i], objective="carbon",
                            stretch=setup.stretch, cfg1=SA_FAST,
                            cfg2=SA_FAST)
        sav_offline.append(float(res.carbon_savings))
    rows = [{
        "bench": "online_vs_offline",
        "stretch": setup.stretch,
        "online_gated_savings_pct": 100 * float(np.mean(sav_online)),
        "offline_bound_savings_pct": 100 * float(np.mean(sav_offline)),
        "online_fraction_of_bound": float(np.mean(sav_online))
        / max(float(np.mean(sav_offline)), 1e-9),
        "online_makespan_ratio": float(np.mean(overshoot)),
        "instances": instances,
    }]
    write_csv("online_vs_offline", rows)
    return rows
