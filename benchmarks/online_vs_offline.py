"""Beyond-paper: the price of online — batched gated dispatch vs the bound.

The paper's §4 poses online heuristics as future work.  This benchmark
quantifies the gap on the paper's own setup (AU-SA, n=10, k=4, M=5,
homogeneous), now at sweep scale: ``instances`` batched
:class:`PackedInstance`s x a ``theta x window x stretch`` gate-policy grid
run as ONE vmapped XLA program (:func:`sweep_policies` from
``core/solvers/online_jax``), instead of the old one-instance-at-a-time
numpy event loop.

The numpy loop stays as the *reference oracle*: every (instance, policy)
cell of the sweep is re-simulated sequentially, cross-checked for exact
``(start, assign)`` agreement, and timed — the wall-clock ratio is recorded
in ``BENCH_online.json`` at the repo root.  Every schedule (both paths) is
checked by the shared validator (``core/validate``, Eqs. 4-8).

    savings(online)  = 1 - carbon(gated) / carbon(greedy)      per policy
    savings(offline) = the paper's S=1.5 bi-level bound on the same instances
"""
from __future__ import annotations

import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import (BenchSetup, SA_FAST, bench_timing, write_csv,
                               write_json)
from repro.core import generate_instance, pack, stack_packed, synthesize, validate
from repro.core.objectives import evaluate
from repro.core.solvers import solve_bilevel_batch
from repro.core.solvers.online import online_carbon_gated, online_greedy
from repro.core.solvers.online_jax import policy_grid, sweep_policies

# Gate-policy grid: 3 x 2 x 2 = 12 combinations per instance.
THETAS = (0.3, 0.4, 0.5)
WINDOWS = (48, 96)
STRETCHES = (1.25, 1.5)

# Forecast/simulation horizon (epochs).  Generously above any greedy online
# makespan at this instance size, so every dispatch completes (asserted).
SIM_HORIZON = 768

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_online.json")


def _batch_eval(batch, start, assign, cum):
    return jax.vmap(evaluate)(batch, start, assign, cum)


def run(instances: int = 16) -> list[dict]:
    if instances < 8:
        print(f"# online_vs_offline: raising instances {instances} -> 8 "
              "(minimum sweep batch)", flush=True)
        instances = 8
    setup = BenchSetup(stretch=1.5, instances=instances)
    rng = np.random.default_rng(setup.seed)
    year = synthesize(setup.region, days=366, seed=2024)
    pad = setup.n_jobs * setup.k_tasks
    packs, intens, cums = [], [], []
    for _ in range(instances):
        inst = generate_instance(rng, n_jobs=setup.n_jobs,
                                 k_tasks=setup.k_tasks,
                                 n_machines=setup.n_machines)
        packs.append(pack(inst, pad_tasks=pad))
        w = year.window(int(rng.integers(0, year.n_epochs - SIM_HORIZON)),
                        SIM_HORIZON)
        intens.append(w.intensity)
        cums.append(jnp.asarray(w.cumulative()))
    batch = stack_packed(packs)
    inten = jnp.asarray(np.stack(intens))
    cum = jnp.stack(cums)

    # ---- batched JAX sweep: B instances x P policies, one XLA program. ----
    t0 = time.time()
    res = sweep_policies(batch, inten, THETAS, WINDOWS, STRETCHES)
    jax.block_until_ready(res)
    jax_cold = time.time() - t0
    t0 = time.time()
    res = sweep_policies(batch, inten, THETAS, WINDOWS, STRETCHES)
    jax.block_until_ready(res)
    jax_warm = time.time() - t0

    mask = np.asarray(batch.task_mask)
    assert (np.asarray(res.greedy.scheduled) | ~mask).all(), \
        "greedy dispatch did not complete within SIM_HORIZON"
    assert (np.asarray(res.gated.scheduled) | ~mask[:, None, :]).all(), \
        "gated dispatch did not complete within SIM_HORIZON"

    # Shared validator, batched jit path, over every schedule in the sweep.
    v_greedy = validate.total_violations_batch(batch, res.greedy.start,
                                               res.greedy.assign)
    v_gated = validate.total_violations_batch(batch, res.gated.start,
                                              res.gated.assign)
    assert int(np.asarray(v_greedy).sum()) == 0
    assert int(np.asarray(v_gated).sum()) == 0

    # ---- numpy reference oracle over the same sweep, timed + cross-checked.
    th, wi, sx = (np.asarray(a) for a in
                  policy_grid(THETAS, WINDOWS, STRETCHES))
    P = th.shape[0]
    g_start, g_assign = np.asarray(res.greedy.start), np.asarray(res.greedy.assign)
    c_start, c_assign = np.asarray(res.gated.start), np.asarray(res.gated.assign)
    matches, total = 0, 0
    t0 = time.time()
    for b in range(instances):
        p, w = packs[b], np.asarray(inten[b])
        s0, a0 = online_greedy(p)
        total += 1
        matches += int(np.array_equal(s0, g_start[b])
                       and np.array_equal(a0, g_assign[b]))
        # apples-to-apples with the sweep: the greedy baseline (and hence
        # the budget) is policy-invariant, so compute it once per instance
        # here too rather than letting each gated call redo it.
        dur = np.asarray(p.dur)
        ms0 = int(max(s0[t] + dur[t, a0[t]]
                      for t in range(p.T) if bool(p.task_mask[t])))
        for j in range(P):
            sg, ag = online_carbon_gated(p, w, theta=float(th[j]),
                                         window=int(wi[j]),
                                         budget=int(float(sx[j]) * ms0))
            total += 1
            matches += int(np.array_equal(sg, c_start[b, j])
                           and np.array_equal(ag, c_assign[b, j]))
    np_seconds = time.time() - t0
    assert matches == total, f"oracle mismatch: {matches}/{total}"

    # ---- objectives + the offline bi-level bound (batched, S = 1.5). ----
    base = _batch_eval(batch, res.greedy.start, res.greedy.assign, cum)
    base_carbon = np.asarray(base.carbon)                       # [B]
    base_ms = np.asarray(base.makespan).astype(float)
    keys = jax.random.split(jax.random.key(setup.seed), instances)
    bires = solve_bilevel_batch(batch, cum, keys, objective="carbon",
                                stretch=setup.stretch, cfg1=SA_FAST,
                                cfg2=SA_FAST)
    off_sav = float(np.asarray(bires.carbon_savings).mean())

    rows = []
    for j in range(P):
        gated = _batch_eval(batch, res.gated.start[:, j],
                            res.gated.assign[:, j], cum[:, :])
        sav = 1.0 - np.asarray(gated.carbon) / base_carbon
        rows.append({
            "bench": "online_vs_offline",
            "theta": round(float(th[j]), 4),
            "window": int(wi[j]),
            "stretch": float(sx[j]),
            "online_gated_savings_pct": 100 * float(sav.mean()),
            "offline_bound_savings_pct": 100 * off_sav,
            "online_fraction_of_bound": float(sav.mean()) / max(off_sav, 1e-9),
            "online_makespan_ratio": float(
                (np.asarray(gated.makespan) / base_ms).mean()),
            "instances": instances,
        })
    rows.sort(key=lambda r: -r["online_gated_savings_pct"])
    write_csv("online_vs_offline", rows)

    write_json(BENCH_JSON, {
        "bench": "online_vs_offline",
        "instances": instances,
        "policies": int(P),
        "grid": {"thetas": list(THETAS), "windows": list(WINDOWS),
                 "stretches": list(STRETCHES)},
        "sim_horizon": SIM_HORIZON,
        "tasks_per_instance": pad,
        "numpy_seconds": round(np_seconds, 3),
        "jax_seconds_warm": round(jax_warm, 3),
        "jax_seconds_with_compile": round(jax_cold, 3),
        "timing": bench_timing(jax_cold + jax_warm + np_seconds),
        "speedup_warm": round(np_seconds / jax_warm, 1),
        "speedup_with_compile": round(np_seconds / jax_cold, 1),
        "oracle_matches": matches,
        "oracle_cells": total,
        "best_policy": {k: rows[0][k] for k in ("theta", "window", "stretch",
                                                "online_gated_savings_pct")},
        "offline_bound_savings_pct": 100 * off_sav,
    })
    return rows
