"""Fig. 7: carbon-objective vs energy-objective solvers (heterogeneous).

Paper: at S=2 the carbon solver achieves ~50% carbon savings but only ~3%
energy savings; the energy solver ~30% carbon / ~10% energy — the
carbon-energy tension (energy optimum uses efficient-but-dirty hours).
"""
from __future__ import annotations

from benchmarks.common import BenchSetup, run_batch, summarize, write_csv

STRETCHES = (1.0, 1.5, 2.0)


def run(instances: int = 24) -> list[dict]:
    rows = []
    for objective in ("carbon", "energy"):
        for s in STRETCHES:
            r = run_batch(BenchSetup(heterogeneous=True, stretch=s,
                                     objective=objective,
                                     instances=instances))
            row = {"bench": "fig7", "objective": objective, "stretch": s}
            row.update(summarize(r))
            rows.append(row)
    write_csv("fig7_carbon_vs_energy", rows)
    return rows
