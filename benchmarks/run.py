"""Benchmark harness: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--instances N] [--only fig5]``

Prints a CSV row per result line and writes per-benchmark CSVs under
``experiments/bench/``.  Defaults are sized for this 1-core container;
``--instances 1000`` reproduces the paper's batch size.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (fig4_makespan, fig5_stretch, fig6_regions,
                        fig7_carbon_vs_energy, learned_gate,
                        online_vs_offline, stream_serve, structure_sweep,
                        table1a_servers, table1b_tasks)

BENCHES = {
    "fig4": fig4_makespan.run,
    "fig5": fig5_stretch.run,
    "fig6": fig6_regions.run,
    "fig7": fig7_carbon_vs_energy.run,
    "table1a": table1a_servers.run,
    "table1b": table1b_tasks.run,
    "online": online_vs_offline.run,   # beyond-paper: price of online
    "structure": structure_sweep.run_harness,  # savings vs DAG structure
    "learned": learned_gate.run_harness,   # learned vs fixed gate thetas
    "stream": stream_serve.run_harness,    # streaming dispatch under load
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--instances", type=int, default=16)
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. fig5,table1a")
    args = ap.parse_args()
    names = (args.only.split(",") if args.only else list(BENCHES))

    t0 = time.time()
    for name in names:
        rows = BENCHES[name](instances=args.instances)
        for row in rows:
            print(",".join(f"{k}={v}" for k, v in row.items()), flush=True)
    print(f"# total {time.time() - t0:.0f}s over {len(names)} benchmarks, "
          f"{args.instances} instances each", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
