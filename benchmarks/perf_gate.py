"""CI perf-regression gate over the pinned probe cells.

Raw speed was asserted exactly once (PR 1's ~14x); this gate makes it a
tracked, regression-locked quantity.  It re-times the pinned probe cells
(``benchmarks.common.perf_probe``: the dispatch-sweep and gate-learner
programs, AOT-compiled, warm medians over synced reps) and compares each
cell's warm wall-clock against the ``timing.probe`` blocks stored in
BENCH_*.json baselines.  A warm median more than ``--tolerance`` (default
30%) above a comparable baseline fails the gate (exit 1).

Wall clocks only compare on like hardware, so every probe carries a
machine fingerprint (backend, device kind/count, cpu count); baselines
with a different fingerprint are *skipped with a message*, never compared
(``--cross-machine`` overrides).  No comparable baseline at all is the
clear skip path: exit 0 with an explanation, so fresh checkouts and new
CI runners are never blocked.

    python -m benchmarks.perf_gate                       # gate vs BENCH_*.json
    python -m benchmarks.perf_gate --write-baseline      # refresh BENCH_perf.json
    python -m benchmarks.perf_gate --check-provenance 'bench-artifacts/*.json'
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import time

from benchmarks.common import (REPO_ROOT, bench_timing, machine_fingerprint,
                               perf_probe, write_json)

BENCH_JSON = os.path.join(REPO_ROOT, "BENCH_perf.json")
DEFAULT_TOLERANCE = 0.30

# Provenance fields every BENCH_*.json must carry post-harness (the CI
# artifact check); timing.probe is only required of records that ran the
# probe (a "timing" block present implies it).
REQUIRED_PROVENANCE = ("git_sha", "jax", "jaxlib", "backend", "device_kind",
                       "device_count")


def extract_probe(record: dict) -> dict | None:
    """The ``timing.probe`` block of a benchmark record (None if absent —
    pre-telemetry BENCH files are skipped, not errors)."""
    probe = record.get("timing", {}).get("probe")
    if probe and "cells" in probe:
        return probe
    return None


def load_baselines(patterns: list[str]) -> list[tuple[str, dict]]:
    """(path, probe) for every matched JSON that carries probe timing."""
    out = []
    for pattern in patterns:
        for path in sorted(glob.glob(pattern)):
            try:
                with open(path) as f:
                    record = json.load(f)
            except (OSError, json.JSONDecodeError):
                continue
            probe = extract_probe(record)
            if probe is not None:
                out.append((path, probe))
    return out


def _warm(cell: dict) -> float:
    """The gate quantity: best warm rep (noise floor); median for records
    written before ``warm_s_min`` existed."""
    return cell.get("warm_s_min", cell.get("warm_s_median"))


def gate_verdict(current: dict, baselines: list[tuple[str, dict]],
                 tolerance: float = DEFAULT_TOLERANCE,
                 cross_machine: bool = False) -> dict:
    """Pure comparison (unit-tested with fake probes — no timing runs).

    For each probe cell, the baseline warm median is the *minimum* across
    comparable stored baselines (the best this machine has ever recorded —
    a monotone target that ratchets as BENCH files regenerate).  Verdict:
    ``ok`` unless any cell regressed past tolerance; ``skipped`` carries
    the per-file reasons when nothing was comparable.
    """
    fp = current["fingerprint"]
    comparable, skipped = [], []
    for path, base in baselines:
        if not cross_machine and base.get("fingerprint") != fp:
            skipped.append((path, "machine fingerprint differs"))
            continue
        comparable.append((path, base))
    rows = []
    for cell, cur in sorted(current["cells"].items()):
        best, src = None, None
        for path, base in comparable:
            b = base["cells"].get(cell)
            if b is None:
                continue
            w = _warm(b)
            if best is None or w < best:
                best, src = w, path
        if best is None:
            continue
        ratio = _warm(cur) / max(best, 1e-12)
        rows.append({"cell": cell, "warm_s": _warm(cur),
                     "baseline_warm_s": best, "baseline_from": src,
                     "ratio": round(ratio, 3),
                     "ok": ratio <= 1.0 + tolerance})
    return {
        "ok": all(r["ok"] for r in rows),
        "compared": rows,
        "skipped": [{"path": p, "reason": r} for p, r in skipped],
        "tolerance": tolerance,
        "fingerprint": fp,
    }


def _resolve_entry(entry: str) -> str | None:
    """Import the longest module prefix of ``entry`` and getattr the rest.

    Returns None when the dotted path resolves to a live object, else the
    failure reason — a probe naming a kernel entry point that no longer
    exists means the stored timings measure dead code.
    """
    import importlib
    parts = entry.split(".")
    for split in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:split]))
        except ImportError:
            continue
        try:
            for attr in parts[split:]:
                obj = getattr(obj, attr)
        except AttributeError as e:
            return f"resolved module but {e}"
        return None
    return "no importable module prefix"


def _check_processes(path: str, record: dict, prov: dict) -> list[str]:
    """Validate the ``processes`` provenance column (multi-process runs).

    Records written before the column existed — single-process baselines —
    are accepted as-is (the skip-path).  When present, ``processes`` must
    be a positive int, must agree with the record-level ``processes``
    column the sweep meta stamps, and on a genuine fleet (> 1) the global
    ``device_count`` must split evenly across processes — the
    process-spanning mesh is process-uniform by construction.
    """
    procs = prov.get("processes")
    if procs is None:
        return []   # pre-multiprocess record: single-process skip-path
    problems = []
    if not isinstance(procs, int) or procs < 1:
        problems.append(
            f"{path}: provenance 'processes' {procs!r} is not a positive "
            "int")
        return problems
    meta_procs = record.get("processes")
    if meta_procs is not None and meta_procs != procs:
        problems.append(
            f"{path}: processes column mismatch — provenance stamped "
            f"{procs} but the record's sweep meta says {meta_procs}")
    dc = prov.get("device_count")
    if procs > 1 and isinstance(dc, int) and dc % procs:
        problems.append(
            f"{path}: device_count {dc} does not divide across "
            f"{procs} processes — a process-spanning mesh is "
            "process-uniform, so this record's topology is inconsistent")
    return problems


def check_provenance(patterns: list[str]) -> list[str]:
    """Missing-field report for the CI artifact check (empty == pass).

    Beyond the required provenance fields, every probe cell that names an
    ``entry`` (the dotted path of the function it times) must resolve
    against the *current* tree — stale probes pointing at removed or
    renamed kernel entry points fail here instead of silently gating on
    dead code.  The ``processes`` column, when stamped, is validated for
    topology consistency (:func:`_check_processes`); records from before
    the column existed pass unchanged.
    """
    problems = []
    paths = [p for pattern in patterns for p in sorted(glob.glob(pattern))]
    if not paths:
        problems.append(f"no files matched {patterns}")
        return problems
    for path in paths:
        try:
            with open(path) as f:
                record = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            problems.append(f"{path}: unreadable ({e})")
            continue
        prov = record.get("provenance")
        if not isinstance(prov, dict):
            problems.append(f"{path}: missing provenance block")
            continue
        for field in REQUIRED_PROVENANCE:
            if field not in prov:
                problems.append(f"{path}: provenance missing {field!r}")
        problems.extend(_check_processes(path, record, prov))
        timing = record.get("timing")
        probe = extract_probe(record)
        if timing is not None and probe is None:
            problems.append(f"{path}: timing block without probe cells")
        for cell, data in (probe or {}).get("cells", {}).items():
            entry = data.get("entry")
            if entry is None:
                continue   # pre-entry records stay valid
            reason = _resolve_entry(entry)
            if reason is not None:
                problems.append(f"{path}: probe cell {cell!r} entry "
                                f"{entry!r} does not resolve ({reason})")
    return problems


def write_baseline(out: str = BENCH_JSON) -> str:
    """Refresh the canonical stored baseline (BENCH_perf.json)."""
    t0 = time.time()
    probe = perf_probe(fresh=True)
    record = {
        "bench": "perf_gate",
        "timing": {**bench_timing(time.time() - t0, probe=False),
                   "probe": probe},
    }
    return write_json(out, record)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--bench-glob", action="append", default=None,
                    help="glob(s) of BENCH json baselines (default: "
                         "repo-root BENCH_*.json)")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE,
                    help="allowed warm-time regression fraction "
                         f"(default {DEFAULT_TOLERANCE})")
    ap.add_argument("--cross-machine", action="store_true",
                    help="compare even when machine fingerprints differ")
    ap.add_argument("--write-baseline", action="store_true",
                    help=f"re-measure and write {BENCH_JSON}, skipping the "
                         "gate")
    ap.add_argument("--out", type=str, default=BENCH_JSON,
                    help="baseline output path for --write-baseline")
    ap.add_argument("--check-provenance", action="append", default=None,
                    metavar="GLOB",
                    help="assert provenance fields on matched BENCH json "
                         "artifacts instead of running the gate")
    args = ap.parse_args(argv)

    if args.check_provenance:
        problems = check_provenance(args.check_provenance)
        if problems:
            for p in problems:
                print(f"# perf_gate provenance FAIL: {p}", flush=True)
            return 1
        print("# perf_gate: provenance fields present on all matched "
              "artifacts", flush=True)
        return 0

    if args.write_baseline:
        path = write_baseline(args.out)
        print(f"# perf_gate: wrote baseline {path}", flush=True)
        return 0

    patterns = args.bench_glob or [os.path.join(REPO_ROOT, "BENCH_*.json")]
    baselines = load_baselines(patterns)
    current = perf_probe()
    verdict = gate_verdict(current, baselines, tolerance=args.tolerance,
                           cross_machine=args.cross_machine)
    for s in verdict["skipped"]:
        print(f"# perf_gate skip: {s['path']} ({s['reason']})", flush=True)
    if not verdict["compared"]:
        print("# perf_gate: SKIP — no comparable stored baselines "
              f"(patterns {patterns}, fingerprint "
              f"{machine_fingerprint()}); run --write-baseline on this "
              "machine to arm the gate", flush=True)
        return 0
    for r in verdict["compared"]:
        state = "ok" if r["ok"] else "REGRESSION"
        print(f"# perf_gate {state}: {r['cell']} warm {r['warm_s']:.4f}s vs "
              f"baseline {r['baseline_warm_s']:.4f}s "
              f"(x{r['ratio']}, from {os.path.basename(r['baseline_from'])})",
              flush=True)
    if not verdict["ok"]:
        print(f"# perf_gate: FAIL — warm time regressed more than "
              f"{100 * args.tolerance:.0f}% on a pinned cell", flush=True)
        return 1
    print("# perf_gate: PASS", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
