"""Learned gate thetas vs the fixed policy grid, per scenario family.

The online gate's fixed ``(theta, window, stretch)`` grid (PR 1) leaves
savings on the table: the best theta depends on DAG structure, fleet and
stretch budget.  This benchmark trains per-(cell, stretch) thetas with the
differentiable relaxation (:mod:`repro.learn`) — initialized from the best
fixed-grid policy at the same stretch and kept only when the hard-dispatch
evaluation improves on it — and reports learned vs fixed savings per
family at **equal stretch budget**.

Outputs ``BENCH_learn.json``: the per-cell sweep rows with their
``"learned"`` cells, the family x stretch summary, and the acceptance flag
``learned_ge_fixed_everywhere`` (guaranteed by the init-fallback
construction; ``improved_cells`` counts where gradient training moved
strictly past the grid).

    python -m benchmarks.learned_gate             # full grid
    python -m benchmarks.learned_gate --tiny      # CI smoke / golden grid

Everything is deterministic (no PRNG in the relaxation, the loss or the
Adam loop), so equal seeds reproduce the JSON bit-for-bit.
"""
from __future__ import annotations

# Join any jax.distributed fleet before jax-touching imports — see the
# matching prelude in benchmarks/structure_sweep.py.
from repro.shard.distributed import initialize_from_env

initialize_from_env()

import argparse
import os
import time

from benchmarks.common import bench_timing, write_csv, write_json
from benchmarks.structure_sweep import check_topology, make_spec
from repro.learn import LearnConfig
from repro.scenarios import learned_summary, sweep_structure, trend_summary

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_learn.json")

TINY_LEARN = LearnConfig(steps=60)
FULL_LEARN = LearnConfig(steps=150)


def _csv_row(r: dict) -> dict:
    """Flatten a sweep row's per-stretch learned cells to scalar columns.

    ``learned_S<stretch>_{theta, savings_pct, fixed_best_savings_pct,
    improved}`` — the metrics this benchmark exists to measure, which a
    plain drop-the-dicts filter would lose.
    """
    flat = {k: v for k, v in r.items() if not isinstance(v, (list, dict))}
    for sx_key, cell in r.get("learned", {}).items():
        pfx = f"learned_S{sx_key}_"
        flat[pfx + "theta"] = cell["theta"]
        flat[pfx + "savings_pct"] = cell["savings_pct"]
        flat[pfx + "fixed_best_savings_pct"] = cell["fixed_best_savings_pct"]
        flat[pfx + "improved"] = int(cell["improved"])
    return flat


def run(tiny: bool = False, steps: int | None = None,
        instances_per_cell: int | None = None, out: str | None = None,
        seed: int = 2024, devices: int | None = None,
        processes: int | None = None) -> list[dict]:
    devices, processes = check_topology(devices, processes)
    spec = make_spec(tiny=tiny, instances_per_cell=instances_per_cell,
                     seed=seed)
    cfg = TINY_LEARN if tiny else FULL_LEARN
    if steps is not None:
        cfg = cfg._replace(steps=steps)

    t0 = time.time()
    rows, meta = sweep_structure(spec, offline=False, learn=cfg,
                                 devices=devices, processes=processes)
    seconds = time.time() - t0
    summary, ok = learned_summary(rows)

    record = {
        "bench": "learned_gate",
        "mode": "tiny" if tiny else "full",
        "seconds": round(seconds, 3),
        "timing": bench_timing(seconds),
        **meta,
        "summary_by_family": summary,
        "acceptance": {"learned_ge_fixed_everywhere": ok},
        "trends": trend_summary(rows),
        "cells": rows,
    }
    write_json(out or BENCH_JSON, record)
    write_csv("learned_gate" + ("_tiny" if tiny else ""),
              [_csv_row(r) for r in rows])

    print(f"# learned_gate[{record['mode']}]: {len(rows)} cells x "
          f"{spec.instances_per_cell} instances, {cfg.steps} steps "
          f"in {seconds:.1f}s on {meta['processes']} process(es) x "
          f"{meta['devices']} device(s) — "
          f"learned >= fixed everywhere: {ok}",
          flush=True)
    for fam, by_sx in summary.items():
        for sx, d in by_sx.items():
            print(f"#   {fam} S={sx}: learned "
                  f"{d['learned_savings_pct']:.2f}% vs fixed "
                  f"{d['fixed_best_savings_pct']:.2f}% "
                  f"({d['improved_cells']}/{d['cells']} cells improved)",
                  flush=True)
    if not ok:
        raise AssertionError(
            "learned thetas fell below the fixed grid somewhere — "
            "the init-fallback invariant is broken")
    return rows


def run_harness(instances: int = 16) -> list[dict]:
    """Adapter for ``benchmarks.run`` (instances per cell, clamped)."""
    return run(instances_per_cell=min(8, max(1, instances // 4)))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke grid (the golden-locked cells)")
    ap.add_argument("--steps", type=int, default=None,
                    help="gradient steps (default: mode preset)")
    ap.add_argument("--instances", type=int, default=None,
                    help="instances per cell (default: grid preset)")
    ap.add_argument("--seed", type=int, default=2024)
    ap.add_argument("--devices", type=int, default=None,
                    help="shard the instance axis over N local devices "
                         "(bit-exact; 'seconds'/'devices' record the "
                         "sharded wall clock); with --processes, devices "
                         "per process")
    ap.add_argument("--processes", type=int, default=None,
                    help="span the shards over a P-process jax.distributed "
                         "fleet (bit-exact; run one worker per rank via "
                         "python -m tests.harness --processes P --devices D "
                         "-- <this command>)")
    ap.add_argument("--out", type=str, default=None,
                    help=f"output JSON path (default {BENCH_JSON})")
    args = ap.parse_args()
    run(tiny=args.tiny, steps=args.steps,
        instances_per_cell=args.instances, out=args.out, seed=args.seed,
        devices=args.devices, processes=args.processes)


if __name__ == "__main__":
    main()
