"""Forecast robustness: how much of the offline bound survives bad forecasts?

The paper's ~25% figure is an offline upper bound against a *perfect*
day-ahead trace.  This benchmark sweeps the two deployment knobs the
forecast subsystem (:mod:`repro.forecast`) introduces — forecast-error scale
x replan frequency — and reports *realized* carbon (always evaluated on the
true trace) for four schedulers on the same instances:

* **day-ahead gate** — the online quantile gate with thresholds fixed from
  one forecast issued at epoch 0 (error at full day-ahead leads);
* **rolling gate**   — same gate, thresholds re-quantiled from a fresh
  forecast every ``every`` epochs (:func:`repro.forecast.rolling_dirty_mask`);
* **MPC replanner**  — full rolling-horizon re-optimization with the SA
  search, frozen executed prefix (:mod:`repro.core.solvers.rolling`);
* **offline bound**  — the paper's bi-level solve on the perfect trace.

Savings are reported against the carbon-agnostic greedy online dispatch.
At ``scale = 0`` the rolling and day-ahead gates coincide bit-exactly (the
regression tests lock this); at ``scale > 0`` rolling must do no worse —
the benchmark records ``rolling_ge_day_ahead`` per cell and aggregates it
into ``rolling_vs_day_ahead_ok``.

    PYTHONPATH=src python -m benchmarks.forecast_robustness [--tiny]

Writes ``BENCH_forecast.json`` at the repo root (``--out`` overrides; the
grid stays 3x3 even under ``--tiny``, which only shrinks instances / seeds /
search budgets for the CI smoke run).
"""
from __future__ import annotations

import argparse
import functools
import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import bench_timing, write_json
from repro.core import generate_instance, pack, stack_packed, synthesize, validate
from repro.core.objectives import evaluate, makespan
from repro.core.solvers import solve_bilevel_batch
from repro.core.solvers.annealing import SAConfig
from repro.core.solvers.online_jax import dirty_mask, simulate_online
from repro.core.solvers.rolling import MPCConfig, solve_mpc_batch
from repro.forecast import (day_ahead_dirty_mask, n_replans,
                            rolling_dirty_mask)

SCALES = (0.0, 0.5, 1.0)      # forecast error at day-ahead leads, trace-stds
EVERYS = (24, 48, 96)         # replan interval (epochs; 96 = daily)
# theta/window: the best cell of the committed online sweep (BENCH_online).
THETA, WINDOW, STRETCH = 0.3, 96, 1.5

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_forecast.json")


@functools.partial(jax.jit, static_argnames=("n_epochs",))
def _greedy(batch, n_epochs: int):
    """Greedy dispatch + stretch budgets, vmapped over instances."""
    def per_inst(inst):
        g = simulate_online(inst, jnp.zeros((n_epochs,), bool), jnp.int32(0),
                            n_epochs=n_epochs)
        ms0 = makespan(inst, g.start, g.assign)
        budget = (jnp.float32(STRETCH)
                  * ms0.astype(jnp.float32)).astype(jnp.int32)
        return g, budget
    return jax.vmap(per_inst)(batch)


@functools.partial(jax.jit, static_argnames=("n_epochs", "mode", "every"))
def _gate_cell(batch, truths, budgets, keys, scale, n_epochs: int,
               mode: str, every: int = 0):
    """Gated dispatch for one grid cell, vmapped over [B] x [S] seeds.

    ``mode``: "perfect" (true-trace thresholds, seed axis collapses),
    "day_ahead" (one noisy forecast at epoch 0) or "rolling" (re-issued
    every ``every`` epochs).
    """
    theta, window = jnp.float32(THETA), jnp.int32(WINDOW)

    def per_inst(inst, truth, budget):
        def per_seed(key):
            if mode == "perfect":
                dirty = dirty_mask(truth, theta, window, max_window=WINDOW)
            elif mode == "day_ahead":
                dirty = day_ahead_dirty_mask(truth, theta, window, key,
                                             scale, max_window=WINDOW)
            else:
                dirty = rolling_dirty_mask(truth, theta, window, key, scale,
                                           every=every, max_window=WINDOW)
            return simulate_online(inst, dirty, budget, n_epochs=n_epochs)
        return jax.vmap(per_seed)(keys)
    return jax.vmap(per_inst)(batch, truths, budgets)


def _carbon(batch, scheds, cums) -> np.ndarray:
    """Realized carbon on the true trace; collapses any seed axis by vmap."""
    def ev(inst, s, a, cum):
        return evaluate(inst, s, a, cum).carbon
    if scheds.start.ndim == 3:        # [B, S, T]
        f = jax.vmap(lambda i, s, a, c: jax.vmap(
            lambda s1, a1: ev(i, s1, a1, c))(s, a))
    else:                             # [B, T]
        f = jax.vmap(ev)
    return np.asarray(f(batch, scheds.start, scheds.assign, cums))


def _check_complete(scheds, mask):
    m = mask if scheds.scheduled.ndim == mask.ndim else mask[:, None, :]
    assert bool(np.asarray(scheds.scheduled | ~m).all()), \
        "dispatch did not complete within the horizon"


def run(instances: int = 8, seeds: int = 3, horizon: int = 512,
        n_jobs: int = 6, k_tasks: int = 3, mpc_seeds: int = 2,
        sa_pop: int = 24, sa_iters: int = 24, seed: int = 2024,
        out: str = BENCH_JSON) -> dict:
    rng = np.random.default_rng(seed)
    year = synthesize("AU-SA", days=366, seed=2024)
    pad = n_jobs * k_tasks
    packs, truths_l, cums_l = [], [], []
    for _ in range(instances):
        inst = generate_instance(rng, n_jobs=n_jobs, k_tasks=k_tasks,
                                 n_machines=5)
        packs.append(pack(inst, pad_tasks=pad))
        w = year.window(int(rng.integers(0, year.n_epochs - horizon)),
                        horizon)
        truths_l.append(w.intensity)
        cums_l.append(w.cumulative())
    batch = stack_packed(packs)
    truths = jnp.asarray(np.stack(truths_l))
    cums = jnp.asarray(np.stack(cums_l))
    mask = np.asarray(batch.task_mask)
    fc_keys = jax.random.split(jax.random.key(seed + 1), seeds)

    t_start = time.time()

    # ---- baselines: greedy, perfect-forecast gate, offline bound. --------
    greedy, budgets = _greedy(batch, horizon)
    _check_complete(greedy, mask)
    greedy_carbon = _carbon(batch, greedy, cums)                    # [B]

    perfect = _gate_cell(batch, truths, budgets, fc_keys[:1],
                         jnp.float32(0.0), horizon, mode="perfect")
    _check_complete(perfect, mask)
    perfect_carbon = _carbon(batch, perfect, cums)[:, 0]            # [B]

    keys = jax.random.split(jax.random.key(seed), instances)
    sa_off = SAConfig(pop=max(sa_pop, 48), iters=max(sa_iters, 60), sweeps=2)
    bires = solve_bilevel_batch(batch, cums, keys, objective="carbon",
                                stretch=STRETCH, cfg1=sa_off, cfg2=sa_off)
    offline_carbon = np.asarray(bires.optimized.carbon)             # [B]
    v_off = jax.vmap(lambda i, s, a, d: validate.total_violations(i, s, a, d))(
        batch, bires.optimized.start, bires.optimized.assign, bires.deadline)
    assert int(np.asarray(v_off).sum()) == 0

    def savings(carbon):        # vs the greedy online dispatch, in %
        return 100.0 * float(np.mean(1.0 - carbon / greedy_carbon))

    mpc_cfgs = {
        every: MPCConfig(every=every,
                         n_replans=n_replans(min(horizon, 240), every),
                         stretch=STRETCH,
                         sa=SAConfig(pop=sa_pop, iters=sa_iters, sweeps=1),
                         sa_phase1=SAConfig(pop=max(sa_pop, 32),
                                            iters=max(sa_iters, 40)))
        for every in EVERYS}
    mpc_keys = jax.random.split(jax.random.key(seed + 2), instances)
    mpc_fc = fc_keys[:max(1, mpc_seeds)]

    cells, all_ok = [], True
    for scale in SCALES:
        sc = jnp.float32(scale)
        da = _gate_cell(batch, truths, budgets, fc_keys, sc, horizon,
                        mode="day_ahead")
        _check_complete(da, mask)
        da_carbon = _carbon(batch, da, cums)                        # [B, S]
        for every in EVERYS:
            ro = _gate_cell(batch, truths, budgets, fc_keys, sc, horizon,
                            mode="rolling", every=every)
            _check_complete(ro, mask)
            ro_carbon = _carbon(batch, ro, cums)                    # [B, S]

            mpc = solve_mpc_batch(batch, truths, cums, mpc_keys, mpc_fc,
                                  sc, objective="carbon",
                                  cfg=mpc_cfgs[every])
            mpc_carbon = np.asarray(mpc.realized.carbon)            # [B, S']

            da_sav = savings(da_carbon.mean(1))
            ro_sav = savings(ro_carbon.mean(1))
            ok = ro_sav >= da_sav - 1e-6
            all_ok &= ok
            cells.append({
                "scale": scale,
                "every": every,
                "day_ahead": {"carbon_mean": float(da_carbon.mean()),
                              "savings_vs_greedy_pct": da_sav},
                "rolling": {"carbon_mean": float(ro_carbon.mean()),
                            "savings_vs_greedy_pct": ro_sav},
                "mpc": {"carbon_mean": float(mpc_carbon.mean()),
                        "savings_vs_greedy_pct": savings(mpc_carbon.mean(1))},
                "rolling_ge_day_ahead": ok,
            })
            print(f"scale={scale:4.1f} every={every:3d}  "
                  f"day-ahead {cells[-1]['day_ahead']['savings_vs_greedy_pct']:6.2f}%  "
                  f"rolling {cells[-1]['rolling']['savings_vs_greedy_pct']:6.2f}%  "
                  f"mpc {cells[-1]['mpc']['savings_vs_greedy_pct']:6.2f}%",
                  flush=True)

    record = {
        "bench": "forecast_robustness",
        "grid": {"scales": list(SCALES), "replan_every": list(EVERYS)},
        "theta": THETA, "window": WINDOW, "stretch": STRETCH,
        "instances": instances, "seeds": seeds, "mpc_seeds": len(mpc_fc),
        "horizon": horizon, "tasks_per_instance": pad,
        "greedy_carbon_mean": float(greedy_carbon.mean()),
        "perfect_day_ahead_gate": {
            "carbon_mean": float(perfect_carbon.mean()),
            "savings_vs_greedy_pct": savings(perfect_carbon)},
        "offline_bound": {
            "carbon_mean": float(offline_carbon.mean()),
            "savings_vs_greedy_pct": savings(offline_carbon)},
        "cells": cells,
        "rolling_vs_day_ahead_ok": bool(all_ok),
        "seconds": round(time.time() - t_start, 1),
        "timing": bench_timing(time.time() - t_start),
    }
    write_json(out, record)
    if not all_ok:
        print("WARNING: rolling gate fell below day-ahead in some cell "
              "(see rolling_ge_day_ahead flags)", flush=True)
    return record


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: same 3x3 grid, tiny instances/budgets")
    ap.add_argument("--instances", type=int, default=None)
    ap.add_argument("--seeds", type=int, default=None)
    ap.add_argument("--out", default=BENCH_JSON)
    args = ap.parse_args()
    kw: dict = {"out": args.out}
    if args.tiny:
        kw.update(instances=3, seeds=2, horizon=256, n_jobs=4, k_tasks=3,
                  mpc_seeds=1, sa_pop=12, sa_iters=10)
    if args.instances is not None:
        kw["instances"] = args.instances
    if args.seeds is not None:
        kw["seeds"] = args.seeds
    rec = run(**kw)
    print(f"# wrote {args.out} in {rec['seconds']}s; "
          f"rolling_vs_day_ahead_ok={rec['rolling_vs_day_ahead_ok']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
