"""Fig. 4: optimal-makespan distribution, homogeneous vs heterogeneous.

Paper: n=10 jobs, k=4 tasks, M=5 servers; homogeneous mean ~117 epochs,
heterogeneous shorter (faster classes absorb work).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import BenchSetup, run_batch, write_csv


def run(instances: int = 24) -> list[dict]:
    rows = []
    for hetero in (False, True):
        r = run_batch(BenchSetup(heterogeneous=hetero, stretch=1.0,
                                 instances=instances))
        ms = r["opt_makespan"]
        rows.append({
            "bench": "fig4",
            "setup": "hetero" if hetero else "homo",
            "mean_makespan": float(ms.mean()),
            "p10": float(np.percentile(ms, 10)),
            "median": float(np.median(ms)),
            "p90": float(np.percentile(ms, 90)),
            "seconds": round(r["seconds"], 1),
        })
    write_csv("fig4_makespan", rows)
    return rows
