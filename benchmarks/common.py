"""Shared harness for the paper-reproduction benchmarks.

Each benchmark solves a batch of FJSP instances with the bi-level protocol
(Section 3.1): phase 1 optimal makespan (carbon-agnostic baseline), phase 2
carbon/energy under ``makespan <= S x OPT``.  Instances follow the paper's
Section 3.1 setup: n jobs x k tasks, M servers (homogeneous 1 kW or the
5-class heterogeneous menu), exp(7)-epoch durations, arrivals uniform in
24 h, Fig. 3 DAG shapes, AU-SA 2024-style carbon trace, 15-min epochs.

The whole batch is one vmapped XLA program (`solve_bilevel_batch`).  The
paper averages 1000 instances; ``--instances`` trades runtime for CI width
on this 1-core container (defaults keep the full ``benchmarks.run`` under
~15 min; results match the paper's numbers within a few points either way
— see EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import platform
import subprocess
import time
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import generate_instance, pack, stack_packed, synthesize
from repro.core.carbon import CarbonTrace
from repro.core.instance import Instance
from repro.core.solvers import solve_bilevel_batch
from repro.core.solvers.annealing import SAConfig

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "bench")

# Solver budget per phase (paper: CP-SAT 1-5 min timeouts; our TPU-style
# population search uses fixed iteration budgets).
SA_FAST = SAConfig(pop=96, iters=150, sweeps=2)

DEF_HORIZON = 1500     # epochs of carbon trace per instance window


@dataclasses.dataclass(frozen=True)
class BenchSetup:
    n_jobs: int = 10
    k_tasks: int = 4
    n_machines: int = 5
    heterogeneous: bool = False
    region: str = "AU-SA"
    stretch: float = 1.0
    objective: str = "carbon"
    instances: int = 24
    seed: int = 2024


# ---------------------------------------------------------------------------
# Benchmark provenance: every write_json-emitted BENCH_*.json is stamped so
# a number can always be traced back to the code, toolchain and hardware
# that produced it (the ROADMAP's "tracked, regression-locked quantity").
# ---------------------------------------------------------------------------

def _git(*args: str) -> str:
    try:
        return subprocess.run(
            ["git", "-C", REPO_ROOT, *args], capture_output=True, text=True,
            timeout=10, check=True).stdout.strip()
    except Exception:
        return ""


def machine_fingerprint() -> dict:
    """The fields that must match for wall-clock comparisons to mean
    anything — the perf gate refuses to compare across fingerprints."""
    dev = jax.devices()[0]
    return {
        "backend": jax.default_backend(),
        "device_kind": str(dev.device_kind),
        "device_count": jax.device_count(),
        "cpu_count": os.cpu_count(),
        "machine": platform.machine(),
    }


def provenance() -> dict:
    """Git SHA, jax/jaxlib versions, device kind/count, process count,
    timestamp.  ``processes`` > 1 marks a record produced by a
    ``jax.distributed`` fleet (``device_count`` is then the global count
    across every process) — ``perf_gate --check-provenance`` validates the
    column's consistency."""
    import jaxlib
    return {
        "git_sha": _git("rev-parse", "HEAD") or "unknown",
        "git_dirty": bool(_git("status", "--porcelain")),
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "python": platform.python_version(),
        **machine_fingerprint(),
        "processes": jax.process_count(),
        "timestamp_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
    }


# ---------------------------------------------------------------------------
# Timing hygiene: every timed region syncs explicitly (block_until_ready),
# and cold (compile) is separated from warm medians.  The clock is
# injectable so the harness itself is unit-testable with a fake clock.
# ---------------------------------------------------------------------------

class BenchTimer:
    """Synced timing with an injectable clock (tests fake it)."""

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self.clock = clock

    def timed(self, fn: Callable, *args, **kwargs):
        """``(result, seconds)`` with an explicit device sync inside the
        timed region — async dispatch can never leak out of the clock."""
        t0 = self.clock()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        return out, self.clock() - t0

    def cold_warm(self, fn: Callable, *args, warm_reps: int = 3, **kwargs):
        """One cold call (compile + execute) then ``warm_reps`` warm calls.

        Returns ``(result, timing)`` where timing separates ``compile_s``
        (the cold call; an upper bound that includes one execution) from
        the warm median — the quantity the perf gate locks.
        """
        out, cold = self.timed(fn, *args, **kwargs)
        warms = [self.timed(fn, *args, **kwargs)[1]
                 for _ in range(warm_reps)]
        return out, {
            "compile_s": round(cold, 6),
            "warm_s_median": round(float(np.median(warms)), 6),
            "warm_s_all": [round(w, 6) for w in warms],
        }


# ---------------------------------------------------------------------------
# The pinned perf-probe cells.  Tiny, seed-pinned, shape-static programs
# covering the two hot paths (the dispatch sweep and the gate-learner
# step), compiled AOT so the probe measures compile and warm wall-clock
# separately AND captures the compiled program's HLO cost analysis for the
# achieved-vs-roofline columns.  Every benchmark stamps probe results into
# its BENCH_*.json; benchmarks/perf_gate.py compares fresh probe warm
# medians against those stored baselines.
# ---------------------------------------------------------------------------

PROBE_SEED = 7
PROBE_HORIZON = 256
PROBE_WARM_REPS = 7


def _probe_batch(n_instances: int = 4):
    """Pinned instance batch + carbon windows shared by the probe cells."""
    rng = np.random.default_rng(PROBE_SEED)
    year = synthesize("AU-SA", days=30, seed=PROBE_SEED)
    packs, intens, cums = [], [], []
    for _ in range(n_instances):
        inst = generate_instance(rng, n_jobs=4, k_tasks=3, n_machines=3)
        packs.append(pack(inst, pad_tasks=12))
        w = year.window(int(rng.integers(0, year.n_epochs - PROBE_HORIZON)),
                        PROBE_HORIZON)
        intens.append(w.intensity)
        cums.append(w.cumulative())
    return (stack_packed(packs), jnp.asarray(np.stack(intens)),
            jnp.asarray(np.stack(cums)))


def _lower_dispatch_probe():
    from repro.core.solvers.online_jax import _sweep
    batch, inten, _ = _probe_batch()
    args = (batch, inten, jnp.asarray([0.3, 0.5], jnp.float32),
            jnp.asarray([48], jnp.int32),
            jnp.asarray([1.25, 1.5], jnp.float32))
    lowered = _sweep.lower(*args, n_epochs=PROBE_HORIZON, max_window=48,
                           machine_rule="earliest_finish")
    return lowered, args


def _lower_learn_probe():
    from repro.learn import LearnConfig
    from repro.learn.train import _train, greedy_reference
    batch, inten, cum = _probe_batch()
    B = int(inten.shape[0])
    ms0, base_c = greedy_reference(batch, cum, PROBE_HORIZON,
                                   "earliest_finish")
    budget = (jnp.float32(1.5) * ms0.astype(jnp.float32)).astype(jnp.int32)
    theta0 = jnp.asarray([0.5], jnp.float32)
    raw0 = jnp.stack([jnp.log(theta0 / (1 - theta0)),
                      jnp.zeros_like(theta0)], axis=1)
    args = (batch, inten, cum, jnp.zeros((B,), jnp.int32),
            jnp.full((B,), 48, jnp.int32), budget, base_c, ms0,
            jnp.zeros(inten.shape, jnp.float32), raw0)
    lowered = _train.lower(*args, cfg=LearnConfig(steps=4), max_window=48,
                           n_epochs=PROBE_HORIZON)
    return lowered, args


def _lower_fitness_probe():
    from repro.core.solvers import common as solver_common
    batch, _, cums = _probe_batch()
    inst = jax.tree.map(lambda a: a[0], batch)
    cum = cums[0]
    k1, k2 = jax.random.split(jax.random.PRNGKey(PROBE_SEED))
    P = 64
    prio = jax.random.normal(k1, (P, inst.T), jnp.float32)
    assign = solver_common.random_allowed_assign(k2, inst, (P,))
    deadline = jnp.int32(PROBE_HORIZON)
    fn = jax.jit(functools.partial(
        solver_common.population_fitness, objective="carbon",
        machine_rule="fixed", sweeps=2, use_kernels=True))
    args = (inst, cum, deadline, prio, assign)
    return fn.lower(*args), args


def _lower_gate_probe():
    from repro.core.solvers.online_jax import dirty_mask
    _, inten, _ = _probe_batch()
    fn = jax.jit(jax.vmap(
        functools.partial(dirty_mask, max_window=48, use_kernels=True),
        in_axes=(0, None, None)))
    args = (inten, jnp.float32(0.4), jnp.int32(48))
    return fn.lower(*args), args


# name -> (entry, builder).  ``entry`` is the dotted path of the function
# the cell actually times — stamped into every BENCH_*.json probe block so
# ``perf_gate --check-provenance`` can fail artifacts whose probes name a
# kernel entry point that no longer exists (benchmark honesty: a probe
# that silently times dead code is worse than no probe).
PROBE_CELLS = {
    "dispatch_sweep": ("repro.core.solvers.online_jax._sweep",
                       _lower_dispatch_probe),
    "learn_step": ("repro.learn.train._train", _lower_learn_probe),
    "fitness_pallas": ("repro.kernels.ops.population_carbon",
                       _lower_fitness_probe),
    "gate_pallas": ("repro.kernels.ops.gate_threshold", _lower_gate_probe),
}


def _probe_cell(build: Callable, timer: BenchTimer) -> dict:
    from repro.launch.hlo_analysis import cost_dict, memory_dict
    from repro.launch.roofline import achieved_vs_roofline
    lowered, args = build()
    t0 = timer.clock()
    compiled = lowered.compile()
    compile_s = timer.clock() - t0
    warms = [timer.timed(compiled, *args)[1]
             for _ in range(PROBE_WARM_REPS)]
    warm_median = float(np.median(warms))
    cost = cost_dict(compiled)
    return {
        "compile_s": round(compile_s, 6),
        # warm_s_min is the gate quantity (noise-robust on shared hosts:
        # the best rep is the program's floor, medians carry OS jitter);
        # the median/all columns stay for reading run-to-run variance.
        "warm_s_min": round(float(np.min(warms)), 6),
        "warm_s_median": round(warm_median, 6),
        "warm_s_all": [round(w, 6) for w in warms],
        "roofline": achieved_vs_roofline(cost, warm_median),
        "memory": memory_dict(compiled),
    }


@functools.lru_cache(maxsize=1)
def _cached_probe() -> dict:
    timer = BenchTimer()
    return {
        "cells": {name: {"entry": entry, **_probe_cell(build, timer)}
                  for name, (entry, build) in PROBE_CELLS.items()},
        "warm_reps": PROBE_WARM_REPS,
        "fingerprint": machine_fingerprint(),
    }


def perf_probe(fresh: bool = False) -> dict:
    """Compile + time the pinned probe cells (cached per process).

    AOT compile is timed apart from ``PROBE_WARM_REPS`` synced warm calls,
    and each cell carries the compiled program's achieved-vs-roofline
    record.  This dict is what benchmarks stamp under ``timing.probe`` and
    what ``benchmarks/perf_gate.py`` compares against stored baselines.
    """
    if fresh:
        _cached_probe.cache_clear()
    return json.loads(json.dumps(_cached_probe()))   # defensive copy


def bench_timing(wall_s: float, probe: bool = True) -> dict:
    """The standard ``timing`` block for a BENCH_*.json record."""
    out = {"wall_s": round(float(wall_s), 3)}
    if probe:
        out["probe"] = perf_probe()
    return out


def run_batch(setup: BenchSetup) -> dict:
    """Solve ``setup.instances`` instances; returns aggregate metrics."""
    rng = np.random.default_rng(setup.seed)
    year = synthesize(setup.region, days=366, seed=2024)
    packs, cums = [], []
    pad = setup.n_jobs * setup.k_tasks
    for _ in range(setup.instances):
        inst: Instance = generate_instance(
            rng, n_jobs=setup.n_jobs, k_tasks=setup.k_tasks,
            n_machines=setup.n_machines,
            heterogeneous=setup.heterogeneous)
        packs.append(pack(inst, pad_tasks=pad))
        start = int(rng.integers(0, year.n_epochs - DEF_HORIZON))
        w: CarbonTrace = year.window(start, DEF_HORIZON)
        cums.append(jnp.asarray(w.cumulative()))
    batch = stack_packed(packs)
    cum = jnp.stack(cums)
    keys = jax.random.split(jax.random.key(setup.seed), setup.instances)

    # Explicit sync inside the timed region (async dispatch must not leak
    # past the clock); host-side np conversion happens after it stops.
    res, dt = BenchTimer().timed(
        solve_bilevel_batch, batch, cum, keys, objective=setup.objective,
        stretch=setup.stretch, cfg1=SA_FAST, cfg2=SA_FAST)
    res = jax.tree.map(np.asarray, res)

    return {
        "setup": setup,
        "seconds": dt,
        "opt_makespan": res.opt_makespan,
        "carbon_savings": res.carbon_savings,
        "energy_savings": res.energy_savings,
        "utilization": res.baseline.utilization,
        "baseline_carbon": res.baseline.carbon,
        "optimized_carbon": res.optimized.carbon,
        "baseline_energy": res.baseline.energy,
        "optimized_energy": res.optimized.energy,
    }


def summarize(r: dict) -> dict:
    return {
        "mean_carbon_savings_pct": 100 * float(r["carbon_savings"].mean()),
        "p10_carbon_savings_pct": 100 * float(
            np.percentile(r["carbon_savings"], 10)),
        "p90_carbon_savings_pct": 100 * float(
            np.percentile(r["carbon_savings"], 90)),
        "mean_energy_savings_pct": 100 * float(r["energy_savings"].mean()),
        "mean_opt_makespan": float(r["opt_makespan"].mean()),
        "mean_utilization_pct": 100 * float(r["utilization"].mean()),
        "seconds": round(r["seconds"], 1),
    }


def is_primary_process() -> bool:
    """True on the rank that owns artifact writes (rank 0; trivially true
    single-process).  Multi-process benchmark results are replicated —
    every rank holds identical values (the bit-exact contract) — so only
    one may write, or concurrent ranks race on the same BENCH_*.json."""
    return jax.process_index() == 0


def write_json(path: str, record: dict) -> str:
    """Write a benchmark record as pretty JSON (e.g. BENCH_online.json).

    Every record is stamped with :func:`provenance` (git SHA, jax/jaxlib,
    device kind/count, process count) unless the caller already provided
    one — no BENCH_*.json leaves the harness untraceable.  On a
    multi-process fleet only rank 0 writes (results are replicated).
    """
    if not is_primary_process():
        return path
    if "provenance" not in record:
        record = {**record, "provenance": provenance()}
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def write_csv(name: str, rows: list[dict]) -> str:
    path = os.path.join(OUT_DIR, f"{name}.csv")
    if not is_primary_process():
        return path
    os.makedirs(OUT_DIR, exist_ok=True)
    if rows:
        keys = list(rows[0])
        with open(path, "w") as f:
            f.write(",".join(keys) + "\n")
            for row in rows:
                f.write(",".join(str(row[k]) for k in keys) + "\n")
    return path
