"""Shared harness for the paper-reproduction benchmarks.

Each benchmark solves a batch of FJSP instances with the bi-level protocol
(Section 3.1): phase 1 optimal makespan (carbon-agnostic baseline), phase 2
carbon/energy under ``makespan <= S x OPT``.  Instances follow the paper's
Section 3.1 setup: n jobs x k tasks, M servers (homogeneous 1 kW or the
5-class heterogeneous menu), exp(7)-epoch durations, arrivals uniform in
24 h, Fig. 3 DAG shapes, AU-SA 2024-style carbon trace, 15-min epochs.

The whole batch is one vmapped XLA program (`solve_bilevel_batch`).  The
paper averages 1000 instances; ``--instances`` trades runtime for CI width
on this 1-core container (defaults keep the full ``benchmarks.run`` under
~15 min; results match the paper's numbers within a few points either way
— see EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import generate_instance, pack, stack_packed, synthesize
from repro.core.carbon import CarbonTrace
from repro.core.instance import Instance
from repro.core.solvers import solve_bilevel_batch
from repro.core.solvers.annealing import SAConfig

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "bench")

# Solver budget per phase (paper: CP-SAT 1-5 min timeouts; our TPU-style
# population search uses fixed iteration budgets).
SA_FAST = SAConfig(pop=96, iters=150, sweeps=2)

DEF_HORIZON = 1500     # epochs of carbon trace per instance window


@dataclasses.dataclass(frozen=True)
class BenchSetup:
    n_jobs: int = 10
    k_tasks: int = 4
    n_machines: int = 5
    heterogeneous: bool = False
    region: str = "AU-SA"
    stretch: float = 1.0
    objective: str = "carbon"
    instances: int = 24
    seed: int = 2024


def run_batch(setup: BenchSetup) -> dict:
    """Solve ``setup.instances`` instances; returns aggregate metrics."""
    rng = np.random.default_rng(setup.seed)
    year = synthesize(setup.region, days=366, seed=2024)
    packs, cums = [], []
    pad = setup.n_jobs * setup.k_tasks
    for _ in range(setup.instances):
        inst: Instance = generate_instance(
            rng, n_jobs=setup.n_jobs, k_tasks=setup.k_tasks,
            n_machines=setup.n_machines,
            heterogeneous=setup.heterogeneous)
        packs.append(pack(inst, pad_tasks=pad))
        start = int(rng.integers(0, year.n_epochs - DEF_HORIZON))
        w: CarbonTrace = year.window(start, DEF_HORIZON)
        cums.append(jnp.asarray(w.cumulative()))
    batch = stack_packed(packs)
    cum = jnp.stack(cums)
    keys = jax.random.split(jax.random.key(setup.seed), setup.instances)

    t0 = time.time()
    res = solve_bilevel_batch(
        batch, cum, keys, objective=setup.objective,
        stretch=setup.stretch, cfg1=SA_FAST, cfg2=SA_FAST)
    res = jax.tree.map(np.asarray, res)
    dt = time.time() - t0

    return {
        "setup": setup,
        "seconds": dt,
        "opt_makespan": res.opt_makespan,
        "carbon_savings": res.carbon_savings,
        "energy_savings": res.energy_savings,
        "utilization": res.baseline.utilization,
        "baseline_carbon": res.baseline.carbon,
        "optimized_carbon": res.optimized.carbon,
        "baseline_energy": res.baseline.energy,
        "optimized_energy": res.optimized.energy,
    }


def summarize(r: dict) -> dict:
    return {
        "mean_carbon_savings_pct": 100 * float(r["carbon_savings"].mean()),
        "p10_carbon_savings_pct": 100 * float(
            np.percentile(r["carbon_savings"], 10)),
        "p90_carbon_savings_pct": 100 * float(
            np.percentile(r["carbon_savings"], 90)),
        "mean_energy_savings_pct": 100 * float(r["energy_savings"].mean()),
        "mean_opt_makespan": float(r["opt_makespan"].mean()),
        "mean_utilization_pct": 100 * float(r["utilization"].mean()),
        "seconds": round(r["seconds"], 1),
    }


def write_json(path: str, record: dict) -> str:
    """Write a benchmark record as pretty JSON (e.g. BENCH_online.json)."""
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def write_csv(name: str, rows: list[dict]) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.csv")
    if rows:
        keys = list(rows[0])
        with open(path, "w") as f:
            f.write(",".join(keys) + "\n")
            for row in rows:
                f.write(",".join(str(row[k]) for k in keys) + "\n")
    return path
