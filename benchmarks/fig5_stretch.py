"""Fig. 5: carbon savings vs stretch factor S, AU-SA, homo + hetero.

Paper: S=1 -> ~25% homo / ~18% hetero; S=2 -> ~54% / ~52%; diminishing
returns past S=1.5.  (Our warm-started solver never goes negative, unlike
the paper's timeout'd CP-SAT at large S — Fig 5b.)
"""
from __future__ import annotations

from benchmarks.common import BenchSetup, run_batch, summarize, write_csv

STRETCHES = (1.0, 1.5, 2.0)


def run(instances: int = 24) -> list[dict]:
    rows = []
    for hetero in (False, True):
        for s in STRETCHES:
            r = run_batch(BenchSetup(heterogeneous=hetero, stretch=s,
                                     instances=instances))
            row = {"bench": "fig5", "setup": "hetero" if hetero else "homo",
                   "stretch": s}
            row.update(summarize(r))
            rows.append(row)
    write_csv("fig5_stretch", rows)
    return rows
