"""Golden replay lock on the streaming dispatch service — both fleet modes.

One tiny seeded stream (bursty arrivals — the shape that exercises queue
back-pressure) run end to end through ``simulate_stream``; the full
per-job event log (arrival, admission, queue delay, budget, completion,
carbon) is locked per fleet mode:

* ``tests/golden/stream_tiny.json`` — partitioned lanes (the original
  engine; this file predates the shared fleet and MUST keep passing
  without regeneration — the ``shared_fleet=False`` bit-exactness
  contract);
* ``tests/golden/stream_contention_tiny.json`` — the same stream on ONE
  shared machine set (``shared_fleet=True``), locking the lane-priority
  scan, the contended admission solve, and the intra-epoch ``mfree``
  threading.

The stream is a pure function of its seed, so ANY drift — in the arrival
sampler, the job generator, the admission solve, the gate thresholds, or
the pool tick — shows up as a diff here.

If a change legitimately moves a log (new generator defaults, different
gate semantics), regenerate with

    PYTHONPATH=src python tests/test_stream_golden.py --write

and explain the shift in the PR.  Ints and orderings are compared exactly;
floats get rtol 1e-4 (platform noise, not semantic change).
"""
import json
import os
import sys

import numpy as np
import pytest

_GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
GOLDEN_PATH = os.path.join(_GOLDEN_DIR, "stream_tiny.json")
CONTENTION_GOLDEN_PATH = os.path.join(_GOLDEN_DIR,
                                      "stream_contention_tiny.json")

EXACT_FIELDS = ("rid", "arrival", "admitted", "queue_delay", "finished",
                "budget", "greedy_makespan", "completed", "truncated")


def _golden_path(shared_fleet: bool) -> str:
    return CONTENTION_GOLDEN_PATH if shared_fleet else GOLDEN_PATH


def _tiny_config(shared_fleet: bool = False):
    from repro.stream import StreamConfig
    return StreamConfig(arrivals="bursty", rate=0.08, horizon=192,
                        n_lanes=3, family="layered", width=3, depth=2,
                        n_machines=3, fleet="tiered", mean_dur=5.0,
                        theta=0.5, window=96, stretch=1.5, seed=2024,
                        shared_fleet=shared_fleet)


def _tiny_run(shared_fleet: bool = False):
    from repro.stream import simulate_stream
    res = simulate_stream(_tiny_config(shared_fleet))
    return {"events": res.events,
            "meta": {k: res.meta[k]
                     for k in ("n_jobs", "n_finished", "pad_tasks",
                               "n_epochs")}}


def _load_golden(path):
    if not os.path.exists(path):
        pytest.fail(f"golden file missing: {path} — regenerate with "
                    "`PYTHONPATH=src python tests/test_stream_golden.py "
                    "--write`")
    with open(path) as f:
        return json.load(f)


def _check_golden(shared_fleet: bool) -> None:
    golden = _load_golden(_golden_path(shared_fleet))
    got = _tiny_run(shared_fleet)
    assert got["meta"] == golden["meta"], \
        f"meta drifted: {got['meta']} != {golden['meta']}"
    want_events = golden["events"]
    assert len(got["events"]) == len(want_events)
    for g, w in zip(got["events"], want_events):
        ctx = f"event[rid={w['rid']}]"
        assert set(g) == set(w), \
            f"{ctx}: field set changed {sorted(set(g) ^ set(w))}"
        for k, wv in w.items():
            gv = g[k]
            if k in EXACT_FIELDS:
                assert gv == wv, f"{ctx}.{k}: {gv!r} != golden {wv!r}"
            else:
                np.testing.assert_allclose(
                    float(gv), float(wv), rtol=1e-4, atol=2e-3,
                    err_msg=f"{ctx}.{k}")


@pytest.mark.parametrize("shared_fleet", [False, True],
                         ids=["partitioned", "shared"])
def test_stream_tiny_matches_golden(shared_fleet):
    _check_golden(shared_fleet)


def test_shared_golden_differs_from_partitioned():
    """The two goldens must not be the same log — if they ever converge,
    the shared-fleet path silently stopped contending."""
    part = _load_golden(GOLDEN_PATH)
    shared = _load_golden(CONTENTION_GOLDEN_PATH)
    assert part["events"] != shared["events"]


def test_stream_tiny_golden_unchanged_under_tracing(monkeypatch):
    """The telemetry bit-exact contract against the stored golden: the
    same stream re-run with ``REPRO_TRACE=1`` must replay the locked event
    log unchanged (and must actually have traced something)."""
    from repro.obs import get_tracer, set_tracer
    monkeypatch.setenv("REPRO_TRACE", "1")
    set_tracer(None)                 # force env re-read -> fresh tracer
    try:
        _check_golden(shared_fleet=False)
        tracer = get_tracer()
        assert tracer.enabled and len(tracer.events) > 0
    finally:
        set_tracer(None)             # do not leak into other tests


def _write_golden():
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    for shared_fleet in (False, True):
        record = _tiny_run(shared_fleet)
        path = _golden_path(shared_fleet)
        with open(path, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {path}: {record['meta']}")


if __name__ == "__main__":
    if "--write" in sys.argv:
        _write_golden()
    else:
        print(__doc__)
