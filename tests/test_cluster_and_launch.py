"""Cluster bridge (energy model, workloads, executor) + launch analysis."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.cluster import (ClusterExecutor, TPU_V5E_CLASSES,
                           make_cluster_instance, task_profile)
from repro.cluster.executor import FaultPlan
from repro.cluster.workloads import sample_daily_batch
from repro.configs import ARCHS
from repro.core import pack, synthesize
from repro.core.carbon import REGIONS, from_csv, sample_window
from repro.launch import hlo_analysis as ha
from repro.launch.sharding import auto_rules, batch_pspecs
from repro.models.common import SHAPES


# ---------------------------------------------------------------------------
# Carbon traces.
# ---------------------------------------------------------------------------

def test_region_profiles_match_paper_narrative():
    tr = {r: synthesize(r, days=30) for r in REGIONS}
    means = {r: float(t.intensity.mean()) for r, t in tr.items()}
    stds = {r: float(t.intensity.std()) for r, t in tr.items()}
    assert means["TEX"] > means["CAL"] > means["AU-SA"] > means["CA-ON"]
    # TEX varies less (relative); AU-SA has high daily variation.
    assert stds["TEX"] / means["TEX"] < stds["AU-SA"] / means["AU-SA"]
    for t in tr.values():
        assert (t.intensity > 0).all()


def test_trace_cumulative_and_csv(tmp_path):
    tr = synthesize("AU-SA", days=2)
    cum = tr.cumulative()
    assert cum.shape[0] == tr.n_epochs + 1
    np.testing.assert_allclose(np.diff(cum),
                               tr.intensity * 0.25, rtol=1e-4, atol=1e-3)
    p = tmp_path / "t.csv"
    p.write_text("ts,gco2\n" + "\n".join(f"{i},{100 + i}" for i in range(48)))
    tr2 = from_csv(str(p))
    assert tr2.n_epochs == 48 * 4 and tr2.intensity[0] == 100


# ---------------------------------------------------------------------------
# Energy model + workloads.
# ---------------------------------------------------------------------------

def test_task_profile_scales_with_machine():
    cfg = ARCHS["deepseek-67b"]
    d, e = task_profile(cfg, "train_4k", 100, TPU_V5E_CLASSES[0])
    d2, e2 = task_profile(cfg, "train_4k", 100, TPU_V5E_CLASSES[-1])
    assert d > d2                     # bigger slice is faster...
    assert e < e2                     # ...but burns more energy (lower MFU)


def test_cluster_instance_shape():
    rng = np.random.default_rng(0)
    specs = sample_daily_batch(rng, n_jobs=4)
    inst = make_cluster_instance(specs, seed=1)
    assert inst.n_jobs == 4 and inst.n_machines == 5
    assert all(len(j.base_durations) >= 3 for j in inst.jobs)
    # speeds are monotone in slice size
    assert list(inst.speeds) == sorted(inst.speeds)


# ---------------------------------------------------------------------------
# Executor: clean run == plan; failure + straggler recovery.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def planned():
    rng = np.random.default_rng(3)
    inst = make_cluster_instance(sample_daily_batch(rng, n_jobs=4), seed=1)
    p = pack(inst)
    tr = synthesize("AU-SA", days=20)
    cum = jnp.asarray(sample_window(tr, rng, 1500).cumulative())
    ex = ClusterExecutor(p, cum, stretch=1.5)
    return ex, ex.plan()


def test_executor_clean_run_matches_plan(planned):
    ex, plan = planned
    rep = ex.execute(plan)
    assert rep.achieved_makespan == plan["makespan"]
    assert rep.achieved_carbon == pytest.approx(plan["carbon"], rel=1e-3)
    assert rep.n_resolves == 0 and rep.n_restarts == 0


def test_executor_machine_failure_recovers(planned):
    ex, plan = planned
    rep = ex.execute(plan, FaultPlan(fail_machine=2,
                                     fail_epoch=plan["makespan"] // 4))
    assert rep.n_resolves == 1
    assert rep.recovery_overhead < 1.0      # recovers within 2x plan


def test_executor_straggler_speculation(planned):
    ex, plan = planned
    rep = ex.execute(plan, FaultPlan(straggle_task=1, straggle_factor=4.0))
    assert rep.n_speculative >= 1
    assert rep.achieved_makespan < plan["makespan"] * 3


def test_executor_rejects_infeasible_resolve(planned, monkeypatch):
    """Every elastic re-solve is validated in-line through the shared
    validator (core.validate.total_violations): a solver that hands back
    an infeasible recovery plan must be caught, not executed."""
    import types

    import jax.numpy as jnp

    import repro.cluster.executor as exmod
    from repro.cluster import ClusterExecutor

    ex0, plan = planned
    # fresh executor: don't mutate the shared fixture's PRNG state
    ex = ClusterExecutor(ex0.inst, jnp.asarray(ex0.cum), stretch=1.5)
    T = ex.inst.T
    # everything at t=0 on machine 0: massive overlap + precedence mass
    bad = types.SimpleNamespace(optimized=types.SimpleNamespace(
        start=jnp.zeros((T,), jnp.int32), assign=jnp.zeros((T,), jnp.int32)))
    monkeypatch.setattr(exmod, "solve_bilevel", lambda *a, **k: bad)
    with pytest.raises(RuntimeError, match="infeasible"):
        ex.execute(plan, FaultPlan(fail_machine=2,
                                   fail_epoch=plan["makespan"] // 4))


# ---------------------------------------------------------------------------
# Launch: sharding rules + HLO analysis.
# ---------------------------------------------------------------------------

class _FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


def test_auto_rules_divisibility():
    r = auto_rules(ARCHS["deepseek-67b"], _FakeMesh())   # 64 q heads, kv 8
    assert r.mesh_axes("heads") == "model"
    assert r.mesh_axes("kv_heads") is None               # 8 % 16 != 0
    r2 = auto_rules(ARCHS["llava-next-34b"], _FakeMesh())  # 56 heads
    assert r2.mesh_axes("heads") is None
    r3 = auto_rules(ARCHS["qwen3-moe-30b-a3b"], _FakeMesh(), zero_stage=3)
    assert r3.mesh_axes("expert") == "model"
    assert r3.mesh_axes("embed") == ("data",)


def test_batch_pspecs_cover_all_inputs():
    mesh = _FakeMesh()
    for arch in ("deepseek-67b", "mamba2-370m", "whisper-base",
                 "hymba-1.5b", "llava-next-34b"):
        cfg = ARCHS[arch]
        for shape in SHAPES:
            from repro.models.common import supports_shape
            if not supports_shape(cfg, shape)[0]:
                continue
            rules = auto_rules(cfg, mesh)
            specs = batch_pspecs(cfg, shape, mesh, rules)
            from repro.models.common import input_specs
            assert set(specs) == set(input_specs(cfg, shape))


HLO_SNIPPET = """
  %ar = f32[16,128]{1,0} all-reduce(f32[16,128] %x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = bf16[4,256]{1,0} all-gather(bf16[1,256] %y), replica_groups=[8,4]<=[32], dimensions={0}
  %rs = f32[8]{0} reduce-scatter(f32[32] %z), replica_groups={{0,1,2,3}}, to_apply=%add
  %cp = f32[64]{0} collective-permute(f32[64] %w), source_target_pairs={{0,1}}
"""


def test_hlo_collective_parser():
    colls = ha.parse_collectives(HLO_SNIPPET)
    ops = {c["op"]: c for c in colls}
    assert ops["all-reduce"]["bytes"] == 16 * 128 * 4
    assert ops["all-reduce"]["group"] == 4
    assert ops["all-reduce"]["wire"] == pytest.approx(2 * 16 * 128 * 4 * 3 / 4)
    assert ops["all-gather"]["group"] == 4
    assert ops["all-gather"]["wire"] == pytest.approx(4 * 256 * 2 * 3 / 4)
    assert ops["reduce-scatter"]["wire"] == pytest.approx(8 * 4 * 3)
    assert ops["collective-permute"]["wire"] == 64 * 4


def test_extrapolation_math():
    assert ha.extrapolate(10.0, 14.0, 5) == pytest.approx(10 + 4 * 4)
    assert ha.extrapolate(10.0, 8.0, 5) == 10.0       # clamped per-layer
