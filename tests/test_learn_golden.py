"""Golden regression lock on the gate-policy learner.

A seed-pinned tiny training run (two scenario cells, two instances each,
40 Adam steps) — everything in the path is deterministic (seeded numpy
generators, no PRNG in the relaxation/loss/optimizer), so the loss curve,
the final thetas and the hard-dispatch evaluation of the learned policy
are all locked:

* **loss / theta curves** at float tolerance (gradient reductions may
  reassociate across platforms);
* **hard-eval savings** tighter — the hard dispatch quantizes starts, so
  a sub-ulp theta drift cannot move them.

If a change legitimately moves these numbers (a different relaxation,
loss weighting, Adam default), regenerate with

    PYTHONPATH=src python tests/test_learn_golden.py --write

and explain the shift in the PR (same convention as
``test_structure_golden.py``).
"""
import functools
import json
import os
import sys

import numpy as np
import pytest

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                           "learn_tiny.json")

STEPS = 40
HORIZON = 600
STRETCH = 1.5
WINDOW = 48
THETA0 = 0.5


@functools.lru_cache(maxsize=None)   # golden + sharded tests share one run
def _tiny_run(devices=None, processes=None):
    """The seed-pinned tiny training run; ``devices`` routes training and
    evaluation through repro.shard (bit-exact with the default
    single-device path — the sharded golden test locks that), and
    ``processes`` spans a ``jax.distributed`` fleet (the multi-process
    parity payloads in ``tests/test_distributed.py`` call this exact
    function, so the fleet reproduces the *same* golden run, not a copy
    of it).  Cached: callers compare, never mutate."""
    import jax.numpy as jnp

    from repro.core import synthesize
    from repro.learn import LearnConfig, evaluate_theta, train_gate
    from repro.scenarios import ScenarioConfig, sample_batch
    from repro.scenarios.batching import pack_aligned

    rng = np.random.default_rng(2024)
    year = synthesize("AU-SA", days=30, seed=2024)
    insts, group = [], []
    families = ("chain", "layered")
    for gi, fam in enumerate(families):
        cfg = ScenarioConfig(family=fam, fleet="tiered", n_jobs=3, width=2,
                             depth=2, n_machines=3)
        insts += sample_batch(rng, cfg, 2)
        group += [gi] * 2
    batch = pack_aligned(insts)
    intens, cums = [], []
    for _ in insts:
        w = year.window(int(rng.integers(0, year.n_epochs - HORIZON)),
                        HORIZON)
        intens.append(w.intensity)
        cums.append(w.cumulative())
    intens = np.stack(intens)
    cums = np.stack(cums)
    group = np.asarray(group)
    window = np.full(len(insts), WINDOW, np.int32)

    if devices is None and processes is None:
        train_fn, eval_fn = train_gate, evaluate_theta
    else:
        import functools

        from repro.shard import eval_theta_sharded, train_sharded
        train_fn = functools.partial(train_sharded, devices=devices,
                                     processes=processes)
        eval_fn = functools.partial(eval_theta_sharded, devices=devices,
                                    processes=processes)
    res = train_fn(batch, intens, cums, group, window, STRETCH,
                   np.full(len(families), THETA0, np.float32),
                   LearnConfig(steps=STEPS))
    sav, _, _, _ = eval_fn(batch, intens, cums,
                           jnp.asarray(res.theta)[group], window,
                           STRETCH)
    sav = np.asarray(sav)
    return {
        "families": list(families),
        "loss_curve": [round(float(v), 6) for v in np.asarray(res.loss_curve)],
        "final_theta": [round(float(v), 6) for v in np.asarray(res.theta)],
        "learned_savings_pct": [
            round(100 * float(sav[group == gi].mean()), 3)
            for gi in range(len(families))],
    }


def _load_golden():
    if not os.path.exists(GOLDEN_PATH):
        pytest.fail(f"golden file missing: {GOLDEN_PATH} — regenerate with "
                    "`PYTHONPATH=src python tests/test_learn_golden.py "
                    "--write`")
    with open(GOLDEN_PATH) as f:
        return json.load(f)


def test_learn_tiny_matches_golden():
    golden = _load_golden()["learn_tiny"]
    got = _tiny_run()
    assert got["families"] == golden["families"]
    np.testing.assert_allclose(
        got["loss_curve"], golden["loss_curve"], rtol=1e-3, atol=2e-4,
        err_msg="loss_curve")
    np.testing.assert_allclose(
        got["final_theta"], golden["final_theta"], rtol=1e-3, atol=2e-3,
        err_msg="final_theta")
    # hard dispatch quantizes: these are exact up to rounding in the file
    np.testing.assert_allclose(
        got["learned_savings_pct"], golden["learned_savings_pct"],
        rtol=1e-4, atol=2e-3, err_msg="learned_savings_pct")


def test_learn_tiny_golden_unchanged_under_tracing(monkeypatch):
    """Telemetry bit-exactness vs the stored golden: the tiny training run
    re-executed with ``REPRO_TRACE=1`` (bypassing the lru_cache) must
    reproduce the locked loss curve / theta / savings, with the learner's
    jitted step captured on the ambient tracer."""
    from repro.obs import get_tracer, set_tracer
    monkeypatch.setenv("REPRO_TRACE", "1")
    set_tracer(None)
    try:
        golden = _load_golden()["learn_tiny"]
        got = _tiny_run.__wrapped__(None)
        tracer = get_tracer()
        assert tracer.enabled
        assert any(e["name"].startswith("xla:") for e in tracer.events)
        assert got["families"] == golden["families"]
        np.testing.assert_allclose(
            got["loss_curve"], golden["loss_curve"], rtol=1e-3, atol=2e-4,
            err_msg="traced loss_curve")
        np.testing.assert_allclose(
            got["final_theta"], golden["final_theta"], rtol=1e-3, atol=2e-3,
            err_msg="traced final_theta")
        np.testing.assert_allclose(
            got["learned_savings_pct"], golden["learned_savings_pct"],
            rtol=1e-4, atol=2e-3, err_msg="traced learned_savings_pct")
    finally:
        set_tracer(None)


def test_learn_tiny_sharded_matches_golden():
    """Golden stability under sharding: the tiny training run through
    repro.shard (all local devices — 8 under the CI forced-device job) is
    **bit-exact** with the single-device run, so the stored golden JSON
    validates it with no ``--write`` regeneration — that is the point of
    the canonical-reduction training parity contract."""
    import jax

    golden = _load_golden()["learn_tiny"]
    got = _tiny_run()
    got_sharded = _tiny_run(devices=jax.device_count())
    # bit-exact vs the single-device run, every rounded value identical
    assert got_sharded == got
    # and the stored golden still validates the sharded outputs
    assert got_sharded["families"] == golden["families"]
    np.testing.assert_allclose(
        got_sharded["loss_curve"], golden["loss_curve"], rtol=1e-3,
        atol=2e-4, err_msg="sharded loss_curve")
    np.testing.assert_allclose(
        got_sharded["final_theta"], golden["final_theta"], rtol=1e-3,
        atol=2e-3, err_msg="sharded final_theta")
    np.testing.assert_allclose(
        got_sharded["learned_savings_pct"], golden["learned_savings_pct"],
        rtol=1e-4, atol=2e-3, err_msg="sharded learned_savings_pct")


def _write_golden():
    record = {
        "_regenerate": "PYTHONPATH=src python tests/test_learn_golden.py"
                       " --write",
        "learn_tiny": _tiny_run(),
    }
    os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
    with open(GOLDEN_PATH, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {GOLDEN_PATH}")


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    if "--write" in sys.argv:
        _write_golden()
    else:
        print(__doc__)
