"""Substrates: optimizer, data pipeline, checkpointing, trainer, serving."""
import os
import shutil

import numpy as np
import pytest
from numpy.testing import assert_allclose

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import ARCHS
from repro.data import DataConfig, SyntheticPipeline
from repro.models.api import build_model
from repro.models.common import ShapeCfg
from repro.models.params import init_params
from repro.models.parallel import ParallelCfg
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         compress_init, compressed_grads, cosine_lr)
from repro.serve import Request, ServeConfig, ServeEngine
from repro.train import TrainConfig, Trainer

PAR = ParallelCfg(mesh=None, remat="none")


# ---------------------------------------------------------------------------
# Optimizer.
# ---------------------------------------------------------------------------

def test_adamw_decreases_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                      weight_decay=0.0)
    state = adamw_init(params, cfg)
    for _ in range(200):
        grads = jax.tree.map(lambda w: 2 * w, params)
        params, state, m = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    assert float(cosine_lr(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(cosine_lr(cfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(cosine_lr(cfg, jnp.int32(100))) == pytest.approx(0.1)


def test_grad_clipping_bounds_update():
    params = {"w": jnp.zeros(3)}
    cfg = AdamWConfig(lr=1.0, warmup_steps=0, clip_norm=1.0)
    state = adamw_init(params, cfg)
    _, _, m = adamw_update(params, {"w": jnp.asarray([1e6, 0, 0])}, state,
                           cfg)
    assert float(m["grad_norm"]) == pytest.approx(1e6)


def test_compress_error_feedback_preserves_signal():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=1000),
                          jnp.float32)}
    state = compress_init(g)
    total_deq = jnp.zeros(1000)
    for _ in range(8):
        deq, state, _ = compressed_grads(g, state)
        total_deq += deq["w"]
    # error feedback: accumulated dequantized sum converges to 8*g
    err = jnp.abs(total_deq - 8 * g["w"]).max()
    assert float(err) < 0.05 * float(jnp.abs(g["w"]).max())


# ---------------------------------------------------------------------------
# Data pipeline.
# ---------------------------------------------------------------------------

def test_pipeline_determinism_and_restart():
    cfg = ARCHS["qwen1.5-0.5b"].reduced()
    shape = ShapeCfg("t", "train", 32, 4)
    p1 = SyntheticPipeline(cfg, shape)
    batches = [p1.next_batch() for _ in range(3)]
    p2 = SyntheticPipeline(cfg, shape)
    p2.load_state_dict({"step": 2})
    b2 = p2.next_batch()
    assert_allclose(np.asarray(b2["tokens"]), np.asarray(batches[2]["tokens"]))
    # labels are next-token shifted
    t = np.asarray(batches[0]["tokens"])
    l = np.asarray(batches[0]["labels"])
    assert (l[:, :-1] == t[:, 1:]).all() and (l[:, -1] == -1).all()


def test_pipeline_emits_frontend_stubs():
    cfg = ARCHS["llava-next-34b"].reduced()
    b = SyntheticPipeline(cfg, ShapeCfg("t", "train", 64, 2)).next_batch()
    assert "patch_embeds" in b and b["patch_embeds"].dtype == jnp.bfloat16
    cfg = ARCHS["whisper-base"].reduced()
    b = SyntheticPipeline(cfg, ShapeCfg("t", "train", 64, 2)).next_batch()
    assert "frame_embeds" in b


# ---------------------------------------------------------------------------
# Checkpointing.
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(5), "b": {"c": jnp.ones((2, 2))},
            "step": jnp.int32(7)}
    for s in (1, 2, 3):
        mgr.save(s, tree, blocking=True)
    assert mgr.all_steps() == [2, 3]                  # keep-k GC
    out = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    assert_allclose(np.asarray(out["a"]), np.arange(5))
    assert int(out["step"]) == 7


def test_checkpoint_ignores_incomplete_tmp(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(5, {"x": jnp.ones(3)}, blocking=True)
    os.makedirs(tmp_path / "step_00000009.tmp")      # simulated crash
    assert mgr.latest() == 5


# ---------------------------------------------------------------------------
# Trainer: convergence, microbatch equivalence, preemption recovery.
# ---------------------------------------------------------------------------

def _mini_trainer(tmp, steps=6, micro=1, fault_hook=None):
    cfg = ARCHS["qwen1.5-0.5b"].reduced()
    m = build_model(cfg)
    tc = TrainConfig(steps=steps, microbatches=micro, ckpt_every=2,
                     log_every=1,
                     opt=AdamWConfig(lr=1e-3, warmup_steps=1,
                                     total_steps=steps))
    return Trainer(m, cfg, PAR, tc, shape=ShapeCfg("t", "train", 64, 4),
                   ckpt_dir=tmp, fault_hook=fault_hook), cfg


def test_trainer_loss_decreases(tmp_path):
    tr, _ = _mini_trainer(str(tmp_path), steps=10)
    tr.resume()
    hist = tr.run()
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_microbatch_equivalence(tmp_path):
    h = []
    for micro in (1, 2):
        tr, _ = _mini_trainer(None, steps=3, micro=micro)
        tr.init(seed=0)
        h.append(tr.run())
    assert h[0][-1]["loss"] == pytest.approx(h[1][-1]["loss"], rel=2e-3)


def test_preemption_recovery(tmp_path):
    """Crash at step 4; a fresh Trainer resumes from the checkpoint and the
    final loss matches an uninterrupted run."""
    class Crash(Exception):
        pass

    def bomb(step):
        if step == 4:
            raise Crash()

    tr, _ = _mini_trainer(str(tmp_path), steps=6, fault_hook=bomb)
    tr.resume()
    with pytest.raises(Crash):
        tr.run()
    tr2, _ = _mini_trainer(str(tmp_path), steps=6)
    start = tr2.resume()
    # the step-4 save is async: depending on whether it completed before
    # the crash, we resume from 4 or fall back to the step-2 checkpoint —
    # both are correct "latest complete" semantics.
    assert start in (2, 4)
    hist = tr2.run()

    tr3, _ = _mini_trainer(None, steps=6)
    tr3.init(seed=0)
    ref = tr3.run()
    assert hist[-1]["loss"] == pytest.approx(ref[-1]["loss"], rel=1e-4)


# ---------------------------------------------------------------------------
# Serving.
# ---------------------------------------------------------------------------

def test_serve_continuous_batching_matches_single_lane():
    cfg = ARCHS["qwen1.5-0.5b"].reduced()
    m = build_model(cfg)
    params = init_params(jax.random.key(0), m.defs)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
               for _ in range(3)]

    def serve(slots):
        eng = ServeEngine(m, params, cfg, PAR,
                          ServeConfig(batch_slots=slots, max_len=32))
        reqs = [Request(rid=i, prompt=p.copy(), max_new=4)
                for i, p in enumerate(prompts)]
        return {r.rid: r.out_tokens for r in eng.run(reqs)}

    batched = serve(slots=3)
    single = serve(slots=1)
    assert batched == single
