"""Shared hypothesis strategies + seeded case builders for the test suite.

One home for "give me an instance" in tests, drawing from *every* scenario
family and fleet (``repro.scenarios``) instead of the per-file ad-hoc
builders this replaces: property tests across the suite now exercise
chain / fanout / diamond / layered / tpch DAGs on homogeneous, tiered and
mixed fleets.

Two layers:

* :func:`scenario_case` and friends — plain seeded builders (no hypothesis
  needed), used by fixed-seed parametrized tests and inside ``@given``
  bodies (the suite's property tests draw small ints/labels and build
  deterministically from them, keeping shrinking effective and examples
  reproducible as plain function calls).
* strategies (``seeds``, ``family_names``, ``scenario_configs``,
  ``instances``) — for tests that want hypothesis to draw whole objects.

Import order: ``tests/conftest.py`` installs the hypothesis stub *before*
test modules load, so importing ``hypothesis`` here is safe without the
real dependency (strategies become inert placeholders and ``@given`` tests
skip).

Padding note: builders accept ``pad_tasks`` / ``pad_machines`` so a test
module can pin ONE static shape across all its cases (one XLA compile per
module instead of one per drawn size) — padding is inert by the
PackedInstance contract, which ``tests/test_scenarios.py`` itself verifies.
"""
from __future__ import annotations

import numpy as np

from hypothesis import strategies as st

from repro.core import pack, synthesize
from repro.core.carbon import CarbonTrace, sample_window
from repro.core.instance import Instance, PackedInstance
from repro.scenarios import (FAMILY_NAMES, FLEET_NAMES, ScenarioConfig,
                             sample_instance)

# Shared bounds for drawn scenario cells: small enough that every test
# suite stays fast, wide enough to cover every family's structure.  (Test
# modules that pin a static pad shape size it to their own largest case —
# the diamond family is the driver at depth * (width + 2) tasks per job.)
MAX_JOBS = 4
MAX_WIDTH = 3
MAX_DEPTH = 3
MAX_MACHINES = 5


def scenario_config(seed: int, family: str | None = None,
                    fleet: str | None = None, n_jobs: int = 4,
                    width: int = 2, depth: int = 2,
                    n_machines: int = 3) -> ScenarioConfig:
    """A concrete cell; ``family``/``fleet`` None == seeded random choice."""
    rng = np.random.default_rng((seed, 0xC0FFEE))
    if family is None:
        family = FAMILY_NAMES[int(rng.integers(len(FAMILY_NAMES)))]
    if fleet is None:
        fleet = FLEET_NAMES[int(rng.integers(len(FLEET_NAMES)))]
    return ScenarioConfig(family=family, fleet=fleet, n_jobs=n_jobs,
                          width=width, depth=depth, n_machines=n_machines)


def scenario_instance(seed: int, **kw) -> Instance:
    """Deterministic instance from a seed (kwargs as scenario_config)."""
    cfg = scenario_config(seed, **kw)
    return sample_instance(np.random.default_rng(seed), cfg)


def scenario_case(seed: int, family: str | None = None,
                  fleet: str | None = None, n_jobs: int = 4, width: int = 2,
                  depth: int = 2, n_machines: int = 3,
                  pad_tasks: int | None = None,
                  pad_machines: int | None = None, horizon: int = 700,
                  region: str = "AU-SA"
                  ) -> tuple[PackedInstance, CarbonTrace]:
    """Deterministic (packed instance, carbon window) — the shared `_case`.

    Equal arguments give bit-identical cases across processes; the carbon
    window is drawn from the same seeded stream as the instance.
    """
    rng = np.random.default_rng(seed)
    cfg = scenario_config(seed, family=family, fleet=fleet, n_jobs=n_jobs,
                          width=width, depth=depth, n_machines=n_machines)
    inst = sample_instance(rng, cfg)
    p = pack(inst, pad_tasks=pad_tasks, pad_machines=pad_machines)
    w = sample_window(synthesize(region, days=10), rng, horizon)
    return p, w


# ---------------------------------------------------------------------------
# hypothesis strategies (inert under the conftest stub).
# ---------------------------------------------------------------------------

def seeds():
    return st.integers(0, 10_000)


def family_names():
    return st.sampled_from(FAMILY_NAMES)


def fleet_names():
    return st.sampled_from(FLEET_NAMES)


@st.composite
def scenario_configs(draw, max_jobs: int = MAX_JOBS,
                     max_width: int = MAX_WIDTH, max_depth: int = MAX_DEPTH,
                     max_machines: int = MAX_MACHINES):
    return ScenarioConfig(
        family=draw(family_names()),
        fleet=draw(fleet_names()),
        n_jobs=draw(st.integers(1, max_jobs)),
        width=draw(st.integers(1, max_width)),
        depth=draw(st.integers(1, max_depth)),
        n_machines=draw(st.integers(1, max_machines)))


@st.composite
def instances(draw, **kw):
    """A whole Instance drawn via (config, seed) — shrinks toward tiny cells."""
    cfg = draw(scenario_configs(**kw))
    seed = draw(seeds())
    return sample_instance(np.random.default_rng(seed), cfg)
