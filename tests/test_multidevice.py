"""Real multi-device execution (not just compile): 8 host devices.

Device count is locked at first jax init, so this test runs its payload
in a subprocess (via the shared :func:`tests.harness.run_forced_devices`
spawn path) with XLA_FLAGS=--xla_force_host_platform_device_count=8.
The payload jits a reduced MoE train step over a (2, 4) ("data","model")
mesh — exercising GSPMD sharding constraints AND the shard_map
expert-parallel path with a real psum — and checks the loss matches the
single-device run of the same step to bf16 tolerance.
"""
import pytest

from tests.harness import run_forced_devices

PAYLOAD = r"""
import json
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ARCHS
from repro.launch.sharding import auto_rules, make_parallel
from repro.models.api import build_model
from repro.models.common import ShapeCfg, input_specs
from repro.models.params import init_params, param_pspecs
from repro.models.parallel import ParallelCfg

cfg = ARCHS["qwen3-moe-30b-a3b"].reduced()
model = build_model(cfg)
params = init_params(jax.random.key(0), model.defs)
rng = np.random.default_rng(0)
sc = ShapeCfg("t", "train", 64, 8)
batch = {
    "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 64)), jnp.int32),
}
batch["labels"] = jnp.concatenate(
    [batch["tokens"][:, 1:], jnp.full((8, 1), -1, jnp.int32)], 1)

# single device reference
par0 = ParallelCfg(mesh=None, remat="none")
loss0 = jax.jit(lambda p, b: model.loss(p, b, cfg, par0))(params, batch)

# 8-device mesh: (2 data, 4 model), MoE EP via shard_map (8 experts / 4)
mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
par = make_parallel(cfg, mesh, remat="none")
rules = par.effective_rules()
pshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                      param_pspecs(model.defs, rules))
params_s = jax.device_put(params, pshard)
batch_s = jax.device_put(batch, NamedSharding(mesh, P(("data",), None)))
with mesh:
    loss1 = jax.jit(lambda p, b: model.loss(p, b, cfg, par),
                    in_shardings=(pshard, NamedSharding(mesh, P(("data",), None)))
                    )(params_s, batch_s)
print(json.dumps({"loss0": float(loss0), "loss1": float(loss1),
                  "devices": jax.device_count()}))
"""


@pytest.mark.slow
def test_moe_train_step_on_8_devices():
    res = run_forced_devices(PAYLOAD, devices=8, timeout=900)
    assert res["devices"] == 8
    assert abs(res["loss0"] - res["loss1"]) < 0.05, res


DRYRUN_PAYLOAD = r"""
import json
from repro.launch.dryrun import run_cell   # sets XLA_FLAGS on import
rec = run_cell("qwen1.5-0.5b", "decode_32k", multi_pod=False, probes=False)
print(json.dumps({"status": rec["status"],
                  "arg": rec.get("memory", {}).get("argument_bytes", 0),
                  "err": rec.get("error", "")}))
"""


@pytest.mark.slow
def test_dryrun_cell_compiles_on_production_mesh():
    """One real dry-run cell (512-device mesh) end to end in a subprocess
    (the dryrun import overrides the harness's forced device count)."""
    res = run_forced_devices(DRYRUN_PAYLOAD, devices=8, timeout=900)
    assert res["status"] == "ok", res
    assert res["arg"] > 0
