"""Multi-process parity: the bit-exact contract across a process fleet.

The headline (ISSUE 10): **sharded == single-device bit-for-bit at any
(process count, device count)** — the same total device budget carved into
1x8, 2x4 or 4x2 (processes x devices) must reproduce the stored
``structure_tiny.json`` / ``learn_tiny.json`` goldens exactly, with no
golden rewritten.  Every heavy test here spawns a real coordinator +
worker fleet via :func:`tests.harness.run_distributed` (CPU, gloo
collectives, fake devices per worker); the harness itself asserts
cross-process agreement on every payload's result, so each test is
simultaneously a parity check and a replication check.

Also locked:

* process-*permutation* invariance — rank identity comes from the env
  contract and mesh position from canonical process-major order, so
  neither OS spawn order nor an explicit ``process_order`` permutation
  may change a number;
* the dead-worker failure mode — a rank that dies before the
  coordination barrier must surface as a :class:`TimeoutError` naming the
  rank(s) left hanging, not a silent 300 s stall;
* the harness's own disagreement detection (a rank-dependent payload must
  fail loudly).

Cheap in-process unit tests of :mod:`repro.shard.distributed` (env
parsing, mesh-order validation) run unmarked; the fleet tests carry
``@pytest.mark.distributed`` so the tier-1 CI job can deselect them while
the dedicated ``distributed`` job runs them.
"""
import pytest

from tests.harness import DISTRIBUTED_PRELUDE, run_distributed

# The parity matrix: one total budget (8 devices), every process split.
MATRIX = [(1, 8), (2, 4), (4, 2)]

# ---------------------------------------------------------------------------
# Payloads (stdout protocol: last line is the JSON result; rank-invariant
# by construction so the harness's cross-process agreement check bites).
# ---------------------------------------------------------------------------

GOLDEN_PAYLOAD = DISTRIBUTED_PRELUDE + r"""
import json, os
import jax
from tests.harness import REPO_ROOT
from benchmarks.structure_sweep import make_spec
from repro.scenarios import sweep_structure
from tests.test_learn_golden import _tiny_run

P, D = jax.process_count(), len(jax.local_devices())
rows, meta = sweep_structure(make_spec(tiny=True), offline=False,
                             devices=D, processes=P)
with open(os.path.join(REPO_ROOT, "tests", "golden",
                       "structure_tiny.json")) as f:
    sg = json.load(f)["structure_tiny"]
learn = _tiny_run.__wrapped__(D, P)
with open(os.path.join(REPO_ROOT, "tests", "golden",
                       "learn_tiny.json")) as f:
    lg = json.load(f)["learn_tiny"]
print(json.dumps({
    "procs": P, "devices": D, "total_devices": len(jax.devices()),
    "structure_golden_exact": rows == sg["cells"],
    "pads_ok": (meta["pad_tasks"] == sg["pad_tasks"]
                and meta["pad_machines"] == sg["pad_machines"]),
    "meta": [meta["devices"], meta["processes"]],
    "learn_golden_exact": learn == lg,
}))
"""

PARITY_PAYLOAD = DISTRIBUTED_PRELUDE + r"""
import json
import numpy as np
import jax, jax.numpy as jnp
from repro.core import synthesize
from repro.core.carbon import sample_window
from repro.core.instance import pack, stack_packed
from repro.core.solvers import solve_bilevel_batch
from repro.core.solvers.annealing import SAConfig
from repro.core.solvers.online_jax import sweep_policies
from repro.scenarios import FAMILY_NAMES, FLEET_NAMES, ScenarioConfig, \
    sample_instance
from repro.shard import bilevel_sharded, dispatch_sharded
from repro.shard.batch import run_rows_sharded
from repro.shard.dispatch import _per_shard_sweep

# no tests.strategies here: payloads have no conftest, so the hypothesis
# soft-dep shim is unavailable — build cases directly (as test_shard does).
year = synthesize("AU-SA", days=10)
packs, intens, cums = [], [], []
for s in range(5):
    rng = np.random.default_rng(s)
    cfg = ScenarioConfig(family=FAMILY_NAMES[s % 5],
                         fleet=FLEET_NAMES[s % 3], n_jobs=3, width=2,
                         depth=2, n_machines=3)
    packs.append(pack(sample_instance(rng, cfg), pad_tasks=24,
                      pad_machines=5))
    w = sample_window(year, rng, 500)
    intens.append(np.asarray(w.intensity))
    cums.append(np.asarray(w.cumulative()))
batch = stack_packed(packs)
inten = jnp.asarray(np.stack(intens)); cum = jnp.asarray(np.stack(cums))

P, D = jax.process_count(), len(jax.local_devices())
eq = lambda a, b: bool(jax.tree.all(jax.tree.map(
    lambda x, y: bool((np.asarray(x) == np.asarray(y)).all()), a, b)))

ref = sweep_policies(batch, inten, (0.3, 0.6), (48,), (1.5,))
got = dispatch_sharded(batch, inten, (0.3, 0.6), (48,), (1.5,),
                       devices=D, processes=P)
ok_dispatch = eq(ref, got)

# Explicit process_order permutation: mesh position is a function of
# canonical process id, so reversing the order must not move a bit.
per_shard = _per_shard_sweep((0.3, 0.6), (48,), (1.5,),
                             int(inten.shape[-1]), 48, "earliest_finish")
got_perm = run_rows_sharded(per_shard, (batch, inten), devices=D,
                            processes=P,
                            process_order=tuple(reversed(range(P))))
ok_perm = eq(ref, got_perm)

keys = jax.random.split(jax.random.key(3), 5)
kw = dict(objective="carbon", stretch=1.5,
          cfg1=SAConfig(pop=8, iters=10, sweeps=1),
          cfg2=SAConfig(pop=8, iters=10, sweeps=1))
bref = solve_bilevel_batch(batch, cum, keys, **kw)
bgot = bilevel_sharded(batch, cum, keys, devices=D, processes=P, **kw)
ok_bilevel = eq(bref, bgot)

print(json.dumps({"procs": P, "devices": D, "ok_dispatch": ok_dispatch,
                  "ok_perm": ok_perm, "ok_bilevel": ok_bilevel}))
"""

# No jax import: rank 0 dies instantly, rank 1 blocks — the harness must
# kill the fleet at its deadline and say who hung.
DEAD_WORKER_PAYLOAD = r"""
import os, sys, time
if int(os.environ["REPRO_PROCESS_ID"]) == 0:
    sys.exit(0)
time.sleep(600)
"""

DISAGREE_PAYLOAD = r"""
import json, os
print(json.dumps({"rank": int(os.environ["REPRO_PROCESS_ID"])}))
"""


# ---------------------------------------------------------------------------
# The parity matrix: goldens reproduced bit-exactly at every process split.
# ---------------------------------------------------------------------------

@pytest.mark.distributed
@pytest.mark.slow
@pytest.mark.parametrize("procs,devs", MATRIX)
def test_parity_matrix_reproduces_goldens(procs, devs):
    results = run_distributed(GOLDEN_PAYLOAD, processes=procs, devices=devs,
                              timeout=900)
    assert set(results) == set(range(procs))
    res = results[0]
    assert res["procs"] == procs and res["devices"] == devs
    assert res["total_devices"] == procs * devs == 8
    assert res["meta"] == [devs, procs]
    assert res["pads_ok"], res
    assert res["structure_golden_exact"], (
        f"structure_tiny golden drifted at {procs} proc x {devs} dev")
    assert res["learn_golden_exact"], (
        f"learn_tiny golden drifted at {procs} proc x {devs} dev")


# ---------------------------------------------------------------------------
# Entry-point parity + permutation invariance on a genuine fleet (2 x 4).
# ---------------------------------------------------------------------------

@pytest.mark.distributed
@pytest.mark.slow
def test_fleet_parity_and_process_order_invariance():
    results = run_distributed(PARITY_PAYLOAD, processes=2, devices=4,
                              timeout=900)
    res = results[0]
    assert res == {"procs": 2, "devices": 4, "ok_dispatch": True,
                   "ok_perm": True, "ok_bilevel": True}


@pytest.mark.distributed
@pytest.mark.slow
def test_spawn_order_does_not_matter():
    """Launch the workers in reversed OS order: rank identity comes from
    the env contract, mesh position from canonical process-major order —
    the numbers (checked against in-payload single-device references)
    cannot move."""
    results = run_distributed(PARITY_PAYLOAD, processes=2, devices=4,
                              timeout=900, spawn_order=(1, 0))
    res = results[0]
    assert res["ok_dispatch"] and res["ok_perm"] and res["ok_bilevel"], res


# ---------------------------------------------------------------------------
# Failure modes the harness must surface loudly.
# ---------------------------------------------------------------------------

@pytest.mark.distributed
def test_dead_worker_times_out_naming_the_hung_rank():
    with pytest.raises(TimeoutError, match=r"rank\(s\) \[1\] still running"):
        run_distributed(DEAD_WORKER_PAYLOAD, processes=2, devices=1,
                        timeout=8)


@pytest.mark.distributed
def test_harness_flags_cross_process_disagreement():
    with pytest.raises(AssertionError, match="disagreement"):
        run_distributed(DISAGREE_PAYLOAD, processes=2, devices=1,
                        timeout=120)


# ---------------------------------------------------------------------------
# In-process unit tests of repro.shard.distributed (no fleet spawned).
# ---------------------------------------------------------------------------

def test_initialize_requires_full_contract(monkeypatch):
    from repro.shard import distributed
    for var in (distributed.ENV_COORDINATOR, distributed.ENV_NUM_PROCESSES,
                distributed.ENV_PROCESS_ID):
        monkeypatch.delenv(var, raising=False)
    assert not distributed.is_initialized()
    with pytest.raises(ValueError, match="coordinator"):
        distributed.initialize(num_processes=2, process_id=0)
    assert distributed.initialize_from_env() is False


def test_mesh_devices_validates_order_and_count():
    from repro.shard import distributed
    with pytest.raises(ValueError, match="not a permutation"):
        distributed.mesh_devices(process_order=(1,))
    with pytest.raises(ValueError, match=">= 1"):
        distributed.mesh_devices(devices_per_process=0)
    import jax
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        distributed.mesh_devices(
            devices_per_process=len(jax.devices()) + 1)
    devs = distributed.mesh_devices()
    assert devs == list(jax.devices())


def test_instance_mesh_rejects_process_count_mismatch():
    import jax

    from repro.shard.batch import instance_mesh
    with pytest.raises(ValueError, match="jax process"):
        instance_mesh(devices=1, processes=jax.process_count() + 1)