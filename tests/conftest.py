"""Shared fixtures + a soft-dependency shim for ``hypothesis``.

Tier-1 must *collect and run* in a clean environment.  When ``hypothesis``
is installed (see requirements-dev.txt) the property tests use the real
library; when it is absent, a minimal stand-in is injected into
``sys.modules`` before test modules import it, and every ``@given`` test
skips at call time with a clear reason instead of failing collection.

When the real library is present, two settings profiles are registered:
``dev`` (fast local runs) and ``ci`` (raised ``max_examples``, per ROADMAP's
property-test-depth item).  ``ci`` loads automatically when the ``CI`` env
var is set (GitHub Actions exports it); ``HYPOTHESIS_PROFILE`` overrides.
Tests that pin ``max_examples`` explicitly (the derandomized exact-equality
suites) keep their pinned budget; profile defaults fill the rest.

NOTE: no XLA_FLAGS here — smoke tests and benches must see the real single
CPU device; only launch/dryrun.py forces 512.
"""
import os

import numpy as np
import pytest

import jax

try:
    import hypothesis  # noqa: F401

    hypothesis.settings.register_profile(
        "dev", max_examples=20, deadline=None)
    # 100 (was 75): the learn-subsystem property tests (temp->0
    # bit-exactness, grad-vs-FD) widen the drawn surface — PR 4.
    hypothesis.settings.register_profile(
        "ci", max_examples=100, deadline=None)
    hypothesis.settings.load_profile(os.environ.get(
        "HYPOTHESIS_PROFILE", "ci" if os.environ.get("CI") else "dev"))
except ModuleNotFoundError:
    import sys
    import types

    _SKIP_REASON = ("hypothesis not installed — property test skipped "
                    "(pip install -r requirements-dev.txt)")

    class _Strategy:
        """Inert placeholder; only ever carried through decorators."""

        def __init__(self, *args, **kwargs):
            pass

        def __repr__(self):
            return "<hypothesis stub strategy>"

        # @st.composite-decorated functions are *called* at module scope to
        # build strategies — collection must survive that.
        def __call__(self, *a, **k):
            return self

        # chained combinators used in strategy expressions
        def map(self, *a, **k):
            return self

        def filter(self, *a, **k):
            return self

        def flatmap(self, *a, **k):
            return self

    _st = types.ModuleType("hypothesis.strategies")
    for _name in ("integers", "booleans", "floats", "sampled_from", "lists",
                  "tuples", "just", "one_of", "none", "text", "composite"):
        setattr(_st, _name, lambda *a, **k: _Strategy())

    def _given(*_args, **_kwargs):
        def deco(fn):
            def skipper():  # zero-arg: strategy params must not look like fixtures
                pytest.skip(_SKIP_REASON)
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def _settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    _settings.register_profile = lambda *a, **k: None
    _settings.load_profile = lambda *a, **k: None

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.assume = lambda *a, **k: True
    _hyp.note = lambda *a, **k: None
    _hyp.example = lambda *a, **k: (lambda fn: fn)
    _hyp.HealthCheck = types.SimpleNamespace(
        too_slow=None, data_too_large=None, filter_too_much=None)
    _hyp.__is_repro_stub__ = True
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.key(0)
