"""Scenario subsystem: family validity, seeded determinism, inert padding.

The three properties the ISSUE pins:

* every generated instance is acyclic (families emit topological edges; the
  packed ``pred`` matrix is strictly lower-triangular),
* its greedy dispatch passes the shared validator (Eqs. 4-8),
* the padder round-trips: padded vs. unpadded ``online_jax`` dispatch is
  **bit-exact** on the real tasks, for task AND machine padding, across all
  families and fleets.

Property tests (hypothesis) randomize; parametrized fixed-seed tests keep
every family/fleet covered when hypothesis is absent.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import pack, stack_packed, validate
from repro.core.instance import INF_DUR, HETERO_POWERS_KW, HETERO_SPEEDS
from repro.core.objectives import evaluate, utilization
from repro.core.solvers.online_jax import (online_carbon_gated_jax,
                                           online_greedy_jax, policy_grid,
                                           sweep_policies)
from repro.scenarios import (FAMILY_NAMES, FLEET_NAMES, ScenarioConfig,
                             aligned_shape, build_dag, build_fleet,
                             pack_aligned, sample_instance)
from repro.scenarios.batching import pad_stacked, padding_rows
from tests.strategies import (scenario_case, scenario_config,
                              scenario_instance, family_names, fleet_names,
                              seeds, scenario_configs)

HORIZON = 700
# Generous dispatch horizon for completeness checks (greedy needs no trace).
LONG_HORIZON = 5000


# ---------------------------------------------------------------------------
# DAG families: topological by construction, acyclic when packed.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", FAMILY_NAMES)
@pytest.mark.parametrize("width,depth", [(1, 1), (2, 3), (3, 2)])
def test_families_topological_fixed(family, width, depth):
    rng = np.random.default_rng(0)
    for _ in range(3):
        k, edges = build_dag(family, rng, width, depth)
        assert k >= 1
        assert len(set(edges)) == len(edges)
        for (u, v) in edges:
            assert 0 <= u < v < k


@settings(max_examples=30, deadline=None, derandomize=True)
@given(family=family_names(), width=st.integers(1, 6),
       depth=st.integers(1, 6), seed=seeds())
def test_families_topological_property(family, width, depth, seed):
    k, edges = build_dag(family, np.random.default_rng(seed), width, depth)
    for (u, v) in edges:
        assert 0 <= u < v < k
    # every non-source task is reachable from some source (layer-connected
    # families) — at minimum, no isolated duplicate edges
    assert len(set(edges)) == len(edges)


@pytest.mark.parametrize("family", FAMILY_NAMES)
def test_packed_instance_acyclic(family):
    p = pack(scenario_instance(3, family=family))
    pred = np.asarray(p.pred)
    iu = np.triu_indices(p.T)
    assert not pred[iu].any(), "pred must be strictly lower-triangular"


# ---------------------------------------------------------------------------
# Fleets.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fleet", FLEET_NAMES)
@pytest.mark.parametrize("m", [1, 2, 5, 9])
def test_fleets_valid(fleet, m):
    powers, speeds = build_fleet(fleet, np.random.default_rng(0), m)
    assert len(powers) == len(speeds) == m
    menu = set(zip(HETERO_POWERS_KW, HETERO_SPEEDS)) | {(1.0, 1.0)}
    assert set(zip(powers, speeds)) <= menu
    if fleet == "mixed":
        assert speeds[0] == 1.0      # pinned baseline reference server


# ---------------------------------------------------------------------------
# Generator: determinism + validator-clean greedy dispatch.
# ---------------------------------------------------------------------------

def test_seeded_determinism():
    for seed in range(4):
        a = scenario_instance(seed)
        b = scenario_instance(seed)
        assert a == b
        pa, pb = pack(a), pack(b)
        for f in pa._fields:
            np.testing.assert_array_equal(np.asarray(getattr(pa, f)),
                                          np.asarray(getattr(pb, f)))


@pytest.mark.parametrize("family", FAMILY_NAMES)
@pytest.mark.parametrize("fleet", FLEET_NAMES)
def test_greedy_dispatch_validator_clean_fixed(family, fleet):
    p = pack(scenario_instance(1, family=family, fleet=fleet))
    g = online_greedy_jax(p, LONG_HORIZON)
    assert bool(np.asarray(g.scheduled | ~p.task_mask).all())
    assert int(validate.total_violations(p, g.start, g.assign)) == 0
    validate.assert_feasible_np(p, np.asarray(g.start), np.asarray(g.assign),
                                ctx=f"{family}/{fleet}")


@settings(max_examples=20, deadline=None, derandomize=True)
@given(cfg=scenario_configs(), seed=seeds())
def test_greedy_dispatch_validator_clean_property(cfg, seed):
    inst = sample_instance(np.random.default_rng(seed), cfg)
    p = pack(inst)
    g = online_greedy_jax(p, LONG_HORIZON)
    assert bool(np.asarray(g.scheduled | ~p.task_mask).all())
    assert int(validate.total_violations(p, g.start, g.assign)) == 0


# ---------------------------------------------------------------------------
# Padding round-trip: bit-exact dispatch, invariant objectives.
# ---------------------------------------------------------------------------

def _assert_padding_inert(seed, family, fleet, pad_t, pad_m):
    p, w = scenario_case(seed, family=family, fleet=fleet, horizon=HORIZON)
    pp, _ = scenario_case(seed, family=family, fleet=fleet, horizon=HORIZON,
                          pad_tasks=p.T + pad_t, pad_machines=p.M + pad_m)
    T = p.T
    assert pp.T == T + pad_t and pp.M == p.M + pad_m

    g, gp = online_greedy_jax(p, HORIZON), online_greedy_jax(pp, HORIZON)
    np.testing.assert_array_equal(np.asarray(g.scheduled),
                                  np.asarray(gp.scheduled[:T]))
    np.testing.assert_array_equal(np.asarray(g.start),
                                  np.asarray(gp.start[:T]))
    np.testing.assert_array_equal(np.asarray(g.assign),
                                  np.asarray(gp.assign[:T]))

    c = online_carbon_gated_jax(p, w.intensity, theta=0.4, stretch=1.5)
    cp = online_carbon_gated_jax(pp, w.intensity, theta=0.4, stretch=1.5)
    np.testing.assert_array_equal(np.asarray(c.scheduled),
                                  np.asarray(cp.scheduled[:T]))
    np.testing.assert_array_equal(np.asarray(c.start),
                                  np.asarray(cp.start[:T]))
    np.testing.assert_array_equal(np.asarray(c.assign),
                                  np.asarray(cp.assign[:T]))

    # objectives and the validator agree across the pad
    if bool(np.asarray(g.scheduled | ~p.task_mask).all()):
        cum = jnp.asarray(w.cumulative())
        a, b = (evaluate(p, g.start, g.assign, cum),
                evaluate(pp, gp.start, gp.assign, cum))
        assert int(a.makespan) == int(b.makespan)
        np.testing.assert_allclose(float(a.carbon), float(b.carbon),
                                   rtol=1e-6)
        np.testing.assert_allclose(float(a.energy), float(b.energy),
                                   rtol=1e-6)
        # utilization is exactly invariant: int-valued sums, same counts
        assert float(utilization(p, g.start, g.assign)) == \
            float(utilization(pp, gp.start, gp.assign))
    assert int(validate.total_violations(pp, gp.start, gp.assign)) == 0
    assert int(validate.total_violations(pp, cp.start, cp.assign)) == 0


@pytest.mark.parametrize("family", FAMILY_NAMES)
@pytest.mark.parametrize("fleet,pad_t,pad_m", [("homog", 5, 0),
                                               ("tiered", 0, 3),
                                               ("mixed", 7, 2)])
def test_padding_roundtrip_bitexact_fixed(family, fleet, pad_t, pad_m):
    _assert_padding_inert(0, family, fleet, pad_t, pad_m)


@settings(max_examples=20, deadline=None, derandomize=True)
@given(seed=seeds(), family=family_names(), fleet=fleet_names(),
       pad_t=st.integers(0, 9), pad_m=st.integers(0, 4))
def test_padding_roundtrip_bitexact_property(seed, family, fleet, pad_t,
                                             pad_m):
    _assert_padding_inert(seed, family, fleet, pad_t, pad_m)


def test_padded_machine_columns_inert_by_construction():
    inst = scenario_instance(2, family="tpch", fleet="tiered", n_machines=3)
    p = pack(inst, pad_machines=6)
    allowed = np.asarray(p.allowed)
    dur = np.asarray(p.dur)
    mask = np.asarray(p.task_mask)
    assert not allowed[:, 3:].any()
    assert (dur[mask][:, 3:] == INF_DUR).all()
    assert (np.asarray(p.power)[3:] == 0.0).all()


# ---------------------------------------------------------------------------
# Batcher: mixed families/fleets to one stacked shape.
# ---------------------------------------------------------------------------

def test_pack_aligned_mixed_batch():
    rng = np.random.default_rng(0)
    insts = [sample_instance(rng, scenario_config(i, family=f, fleet=fl,
                                                  n_machines=2 + i % 4))
             for i, (f, fl) in enumerate(
                 (f, fl) for f in FAMILY_NAMES for fl in FLEET_NAMES)]
    T, M = aligned_shape(insts)
    assert T == max(i.n_tasks for i in insts)
    assert M == max(i.n_machines for i in insts)
    b = pack_aligned(insts)
    assert b.dur.shape == (len(insts), T, M)
    assert b.T == T and b.M == M
    # overriding with a larger shape aligns independent batches
    b2 = pack_aligned(insts, pad_tasks=T + 3, pad_machines=M + 1)
    assert b2.dur.shape == (len(insts), T + 3, M + 1)


def _assert_batch_padding_inert(seeds_, pad_b):
    """Batch-axis padding contract: pack_aligned(pad_batch=...) appends
    inert rows — dispatch of the padded batch is bit-exact with the
    unpadded batch on the real rows (the device-multiple alignment
    repro.shard relies on)."""
    insts = [scenario_instance(s, family=FAMILY_NAMES[s % 5],
                               fleet=FLEET_NAMES[s % 3]) for s in seeds_]
    B = len(insts)
    base = pack_aligned(insts)
    padded = pack_aligned(insts, pad_batch=B + pad_b)
    assert padded.dur.shape[0] == B + pad_b
    # padded rows follow the padded-task convention: fully masked, zero
    # power, machine-0-only
    pmask = np.asarray(padded.task_mask)
    assert not pmask[B:].any()
    assert (np.asarray(padded.power)[B:] == 0.0).all()
    # real rows are byte-identical to the unpadded stack
    for f in base._fields:
        np.testing.assert_array_equal(np.asarray(getattr(base, f)),
                                      np.asarray(getattr(padded, f))[:B],
                                      err_msg=f"field {f}")

    inten = np.stack([np.asarray(scenario_case(s, horizon=HORIZON)[1]
                                 .intensity) for s in seeds_])
    inten_p = np.concatenate(
        [inten, np.zeros((pad_b,) + inten.shape[1:], inten.dtype)])
    res = sweep_policies(base, jnp.asarray(inten), [0.3, 0.5], [48], [1.5])
    res_p = sweep_policies(padded, jnp.asarray(inten_p), [0.3, 0.5], [48],
                           [1.5])
    for got, want, name in (
            (res_p.greedy, res.greedy, "greedy"),
            (res_p.gated, res.gated, "gated")):
        for f in want._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(want, f)),
                np.asarray(getattr(got, f))[:B], err_msg=f"{name}.{f}")
    np.testing.assert_array_equal(np.asarray(res.greedy_makespan),
                                  np.asarray(res_p.greedy_makespan)[:B])
    np.testing.assert_array_equal(np.asarray(res.budget),
                                  np.asarray(res_p.budget)[:B])
    # padded rows dispatch to nothing: all-masked, so "scheduled" is
    # trivially complete and the validator has nothing to flag
    v = validate.total_violations_batch(padded, res_p.greedy.start,
                                        res_p.greedy.assign)
    assert int(np.asarray(v).sum()) == 0


def test_batch_padding_inert_fixed():
    _assert_batch_padding_inert(list(range(3)), pad_b=5)


@settings(max_examples=10, deadline=None, derandomize=True)
@given(seed=seeds(), pad_b=st.integers(1, 6))
def test_batch_padding_inert_property(seed, pad_b):
    _assert_batch_padding_inert([seed, seed + 1], pad_b)


def test_pad_stacked_validates_and_noops():
    insts = [scenario_instance(s) for s in range(2)]
    b = pack_aligned(insts)
    assert pad_stacked(b, 2) is b                     # no-op at equal rows
    with pytest.raises(ValueError, match="rows=1 < batch size"):
        pad_stacked(b, 1)
    rows = padding_rows(3, b.T, b.M)
    assert rows.dur.shape == (3, b.T, b.M)
    assert not np.asarray(rows.task_mask).any()
    assert np.asarray(rows.allowed)[:, :, 0].all()


def test_stack_packed_rejects_mixed_shapes():
    a = pack(scenario_instance(0, family="chain"))
    b = pack(scenario_instance(0, family="diamond"))
    with pytest.raises(ValueError, match="pad_tasks/pad_machines"):
        stack_packed([a, b])
    with pytest.raises(ValueError, match="empty"):
        stack_packed([])


# ---------------------------------------------------------------------------
# Batched validator over padded sweeps.
# ---------------------------------------------------------------------------

def test_total_violations_batch_matches_per_instance():
    insts = [scenario_instance(s, family=f, fleet="tiered", n_machines=2 + s)
             for s, f in enumerate(("chain", "tpch"))]
    batch = pack_aligned(insts)
    rng = np.random.default_rng(0)
    inten = jnp.asarray(np.stack(
        [np.asarray(scenario_case(s, horizon=HORIZON)[1].intensity)
         for s in range(2)]))
    res = sweep_policies(batch, inten, [0.3, 0.5], [48], [1.5])

    v_greedy = np.asarray(validate.total_violations_batch(
        batch, res.greedy.start, res.greedy.assign))
    v_gated = np.asarray(validate.total_violations_batch(
        batch, res.gated.start, res.gated.assign, deadline=res.budget))
    assert v_greedy.shape == (2,)
    assert v_gated.shape == (2, 2)
    for b in range(2):
        one = jax.tree.map(lambda x: x[b], batch)
        assert int(v_greedy[b]) == int(validate.total_violations(
            one, res.greedy.start[b], res.greedy.assign[b]))
        for j in range(2):
            assert int(v_gated[b, j]) == int(validate.total_violations(
                one, res.gated.start[b, j], res.gated.assign[b, j],
                deadline=res.budget[b, j]))
    assert int(v_greedy.sum()) == 0


def test_total_violations_batch_flags_bad_schedules():
    insts = [scenario_instance(s, family="chain") for s in range(2)]
    batch = pack_aligned(insts)
    T = batch.T
    start = jnp.zeros((2, T), jnp.int32)       # everything at t=0: overlaps
    assign = jnp.zeros((2, T), jnp.int32)
    v = np.asarray(validate.total_violations_batch(batch, start, assign))
    assert (v > 0).all()
