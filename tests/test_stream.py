"""Streaming dispatch service: arrival families + engine contracts.

Three contract groups (see ``src/repro/stream``):

* **arrival families** — sorted in-range epochs, seeded determinism, and
  the configured rate honored in expectation, per family;
* **closed-batch bit-exactness** — with every arrival at t=0 and enough
  lanes, each job's streamed schedule (start/assign/scheduled and the
  stretch budget) is bit-identical to the batched
  ``online_carbon_gated_jax`` on the same padded instance, across DAG
  families x fleets — the streaming tick IS the batched simulator's loop
  body, and this is the test that keeps it so;
* **service semantics** — FIFO admission with back-pressure (queue delay
  appears exactly when jobs outnumber lanes), arrivals respected, engine
  re-entrancy, forecast-banded gating, and whole-run determinism.
"""
import dataclasses

import numpy as np
import pytest

from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.carbon import sample_window, synthesize
from repro.core.instance import Instance, pack
from repro.core.solvers.online_jax import online_carbon_gated_jax
from repro.scenarios import FAMILY_NAMES, FLEET_NAMES
from repro.scenarios.fleets import build_fleet
from repro.scenarios.generator import ScenarioConfig, sample_job
from repro.stream import (ARRIVAL_NAMES, StreamConfig, StreamEngine,
                          sample_arrivals, simulate_stream)
from tests.strategies import family_names, fleet_names, seeds

# One static shape for every engine case in this module: 3 machines,
# pad_tasks sized to the largest drawn job (diamond, depth 2 x (width 2
# + 2) = 8 tasks) — one XLA compile for the whole suite.
N_MACHINES = 3
PAD_TASKS = 8
HORIZON = 400


def _jobs(seed: int, family: str, fleet: str, n: int, arrival: int = 0):
    rng = np.random.default_rng(seed)
    scen = ScenarioConfig(family=family, n_jobs=1, width=2, depth=2,
                          n_machines=N_MACHINES, fleet=fleet).validate()
    jobs = [dataclasses.replace(sample_job(rng, scen), arrival=arrival)
            for _ in range(n)]
    powers, speeds = build_fleet(fleet, rng, N_MACHINES)
    trace = sample_window(synthesize("AU-SA", days=10, seed=7), rng, HORIZON)
    return jobs, powers, speeds, trace


# ---------------------------------------------------------------------------
# Arrival-process families.
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None, derandomize=True)
@given(seed=seeds(), family=st.sampled_from(ARRIVAL_NAMES),
       rate10=st.integers(1, 30), horizon=st.integers(8, 600))
def test_arrivals_sorted_in_range_deterministic(seed, family, rate10,
                                                horizon):
    rate = rate10 / 100.0
    a = sample_arrivals(family, np.random.default_rng(seed), rate, horizon)
    assert a.dtype == np.int32
    assert np.all(np.diff(a) >= 0), "arrival epochs must be sorted"
    if a.size:
        assert 0 <= a[0] and a[-1] < horizon
    b = sample_arrivals(family, np.random.default_rng(seed), rate, horizon)
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("family", ARRIVAL_NAMES)
def test_arrivals_honor_rate_in_expectation(family):
    """Mean job count over many seeded streams ~= rate * horizon for every
    family (bursty and diurnal redistribute arrivals, not mass)."""
    rate, horizon, n_seeds = 0.1, 512, 40
    counts = [sample_arrivals(family, np.random.default_rng(s), rate,
                              horizon).size for s in range(n_seeds)]
    mean = float(np.mean(counts))
    expect = rate * horizon
    assert abs(mean - expect) / expect < 0.15, \
        f"{family}: mean count {mean:.1f} vs expected {expect:.1f}"


def test_arrivals_validation_errors():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError, match="unknown arrival family"):
        sample_arrivals("nope", rng, 0.1, 10)
    with pytest.raises(ValueError, match="rate must be positive"):
        sample_arrivals("poisson", rng, 0.0, 10)
    with pytest.raises(ValueError, match="horizon"):
        sample_arrivals("poisson", rng, 0.1, 0)
    from repro.stream import diurnal
    with pytest.raises(ValueError, match="amp"):
        diurnal(rng, 0.1, 10, amp=1.5)


# ---------------------------------------------------------------------------
# Closed-batch bit-exactness: streaming == batched gate at t=0.
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None, derandomize=True)
@given(seed=seeds(), family=family_names(), fleet=fleet_names())
def test_stream_matches_batched_gate_at_t0(seed, family, fleet):
    jobs, powers, speeds, trace = _jobs(seed, family, fleet, n=3)
    eng = StreamEngine(trace, powers, speeds, n_lanes=4,
                       pad_tasks=PAD_TASKS, theta=0.5, window=96,
                       stretch=1.5)
    sjobs = eng.run(jobs)
    assert all(sj.finished for sj in sjobs)
    for sj in sjobs:
        inst = pack(Instance(jobs=(sj.job,), powers_kw=powers,
                             speeds=speeds), pad_tasks=PAD_TASKS)
        ref = online_carbon_gated_jax(inst, jnp.asarray(trace.intensity),
                                      theta=0.5, window=96, stretch=1.5)
        np.testing.assert_array_equal(sj.start, np.asarray(ref.start),
                                      err_msg=f"rid={sj.rid} start")
        np.testing.assert_array_equal(sj.assign, np.asarray(ref.assign),
                                      err_msg=f"rid={sj.rid} assign")


@pytest.mark.parametrize("machine_rule", ["earliest_finish", "min_energy"])
def test_stream_matches_batched_gate_both_rules(machine_rule):
    jobs, powers, speeds, trace = _jobs(3, "layered", "tiered", n=4)
    eng = StreamEngine(trace, powers, speeds, n_lanes=4,
                       pad_tasks=PAD_TASKS, machine_rule=machine_rule)
    for sj in eng.run(jobs):
        assert sj.finished
        inst = pack(Instance(jobs=(sj.job,), powers_kw=powers,
                             speeds=speeds), pad_tasks=PAD_TASKS)
        ref = online_carbon_gated_jax(inst, jnp.asarray(trace.intensity),
                                      machine_rule=machine_rule)
        np.testing.assert_array_equal(sj.start, np.asarray(ref.start))
        np.testing.assert_array_equal(sj.assign, np.asarray(ref.assign))


# ---------------------------------------------------------------------------
# Service semantics.
# ---------------------------------------------------------------------------

def test_backpressure_queue_delay():
    """More t=0 jobs than lanes: the overflow jobs wait for evictions —
    strictly positive queue delay, admission in rid (FIFO) order, and every
    admission only after its arrival."""
    jobs, powers, speeds, trace = _jobs(5, "layered", "homog", n=6)
    eng = StreamEngine(trace, powers, speeds, n_lanes=2,
                       pad_tasks=PAD_TASKS)
    sjobs = eng.run(jobs)
    assert all(sj.finished for sj in sjobs)
    assert all(sj.admitted >= sj.arrival for sj in sjobs)
    admits = [sj.admitted for sj in sjobs]
    assert admits == sorted(admits), "FIFO admission order broken"
    assert sum(sj.queue_delay > 0 for sj in sjobs) >= 4, \
        "6 jobs on 2 lanes must leave >= 4 jobs queueing"
    # lanes never over-committed: at most n_lanes jobs in flight at once
    for t in range(HORIZON):
        in_flight = sum(sj.admitted <= t < sj.completed for sj in sjobs)
        assert in_flight <= 2


def test_engine_run_reentry():
    """Back-to-back run() calls on one engine are independent (the pool
    drains + resets): the second run reproduces the first bit-exactly."""
    jobs, powers, speeds, trace = _jobs(9, "fanout", "tiered", n=3)
    eng = StreamEngine(trace, powers, speeds, n_lanes=2,
                       pad_tasks=PAD_TASKS)
    a, b = eng.run(jobs), eng.run(jobs)
    for x, y in zip(a, b):
        assert (x.admitted, x.completed, x.budget) == \
            (y.admitted, y.completed, y.budget)
        np.testing.assert_array_equal(x.start, y.start)
        np.testing.assert_array_equal(x.assign, y.assign)


def test_simulate_stream_deterministic_and_seed_sensitive():
    cfg = StreamConfig(arrivals="bursty", rate=0.06, horizon=192,
                       n_lanes=3, seed=13)
    r1, r2 = simulate_stream(cfg), simulate_stream(cfg)
    assert r1.events == r2.events, "same seed must replay identically"
    r3 = simulate_stream(dataclasses.replace(cfg, seed=14))
    assert r1.events != r3.events, "different seed must move the stream"
    assert r1.meta["n_finished"] >= 1


def test_simulate_stream_forecast_banded():
    """The forecast-banded gate option is a drop-in: runs end to end,
    deterministic, and actually changes the gate relative to day-ahead
    when the forecast noise is large."""
    base = StreamConfig(arrivals="poisson", rate=0.05, horizon=192,
                        n_lanes=3, seed=21)
    banded = dataclasses.replace(base, forecast_every=24,
                                 forecast_scale=2.0)
    rb1, rb2 = simulate_stream(banded), simulate_stream(banded)
    assert rb1.events == rb2.events
    assert rb1.meta["n_finished"] >= 1
    completions = [e.get("completed") for e in rb1.events]
    base_completions = [e.get("completed")
                        for e in simulate_stream(base).events]
    # not asserting inequality per-job (noise may cancel), but the runs
    # must at least agree on the job population
    assert len(completions) == len(base_completions)


def test_stream_job_too_large_rejected():
    jobs, powers, speeds, trace = _jobs(1, "layered", "homog", n=1)
    eng = StreamEngine(trace, powers, speeds, n_lanes=2, pad_tasks=2)
    with pytest.raises(ValueError, match="exceeds pad_tasks"):
        eng.run(jobs)


def test_late_arrival_rejected_not_wedged():
    """A job arriving too close to the trace end to finish even greedily
    surfaces finished=False/admitted=-1 instead of raising or wedging."""
    jobs, powers, speeds, trace = _jobs(2, "layered", "homog", n=1,
                                        arrival=HORIZON - 2)
    eng = StreamEngine(trace, powers, speeds, n_lanes=2,
                       pad_tasks=PAD_TASKS)
    (sj,) = eng.run(jobs)
    assert not sj.finished and sj.admitted == -1


def test_stream_result_summary_never_aliases():
    """Regression: ``StreamResult.summary`` once defaulted to a mutable
    ``{}`` — ONE dict object shared by every result constructed without a
    summary, so mutating one run's summary leaked into all others.  The
    default is now immutable (mutation raises instead of leaking) and real
    constructions carry a fresh dict per result."""
    from repro.stream.engine import StreamResult
    a = StreamResult(jobs=[], events=[], meta={})
    b = StreamResult(jobs=[], events=[], meta={})
    assert dict(a.summary) == {}
    with pytest.raises(TypeError):
        a.summary["leak"] = 1        # pre-fix: silently mutated b too
    assert dict(b.summary) == {}
    cfg = StreamConfig(arrivals="poisson", rate=0.05, horizon=128,
                       n_lanes=2, seed=3)
    r1, r2 = simulate_stream(cfg), simulate_stream(cfg)
    assert r1.summary is not r2.summary
    r1.summary["leak"] = True        # real summaries are per-run dicts
    assert "leak" not in r2.summary


def test_truncated_completion_surfaced_not_dropped():
    """Regression for the end-of-stream silent drop: a job FULLY PLACED by
    the final tick whose completion epoch lands past it used to surface
    ``finished=False`` with no schedule or carbon stats, even though its
    dispatch is complete and feasible.  It now surfaces finished with
    ``truncated=True`` (mirroring serve's ``Request.truncated``)."""
    from repro.core.instance import Job
    # Single long task arriving late: placeable (so admission accepts and
    # the dispatcher schedules it) but running well past the trace end.
    job = Job(arrival=HORIZON - 50, base_durations=(300,), edges=())
    _, powers, speeds, trace = _jobs(4, "layered", "homog", n=1)
    eng = StreamEngine(trace, powers, speeds, n_lanes=2,
                       pad_tasks=PAD_TASKS, theta=1.0)
    (sj,) = eng.run([job])
    assert sj.finished, "fully-placed job must not be silently dropped"
    assert sj.truncated
    assert sj.completed > HORIZON - 1, "completes past the final tick"
    assert sj.start is not None and sj.carbon > 0.0
    assert eng.summary()["jobs_truncated"] == 1
    # A job that completes inside the stream is NOT flagged.
    jobs2, powers, speeds, trace = _jobs(5, "layered", "homog", n=1)
    (sj2,) = StreamEngine(trace, powers, speeds, n_lanes=2,
                          pad_tasks=PAD_TASKS).run(jobs2)
    assert sj2.finished and not sj2.truncated


def test_stream_config_validation():
    with pytest.raises(ValueError, match="unknown arrival family"):
        StreamConfig(arrivals="nope").validate()
    with pytest.raises(ValueError, match="n_lanes"):
        StreamConfig(n_lanes=0).validate()
    assert set(ARRIVAL_NAMES) == {"poisson", "bursty", "diurnal"}
    assert len(FAMILY_NAMES) >= 5 and len(FLEET_NAMES) >= 3
