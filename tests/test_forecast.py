"""Forecast subsystem: model invariants + rolling re-quantile regression.

The acceptance anchor is *bit-exactness*: a zero-noise rolling forecast must
reproduce the day-ahead ``online_jax`` dispatch exactly, for every replan
interval — locked here on fixed seeds (and widened by hypothesis when it is
installed).  The second anchor is *monotonicity*: on fixed seeds, realized
carbon of the rolling gate never improves as forecast error grows.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import generate_instance, pack, synthesize, validate
from repro.core.carbon import sample_window
from repro.core.instance import DAG_SHAPES
from repro.core.objectives import evaluate
from repro.core.solvers.online_jax import (dirty_mask,
                                           online_carbon_gated_jax)
from repro.forecast import (AR1_RHO, issue, lead_quantiles, n_replans,
                            online_rolling_gated_jax, rolling_dirty_mask,
                            day_ahead_dirty_mask)

HORIZON = 700


def _case(seed, shape=None, hetero=False, n_jobs=4, k_tasks=3, n_machines=3):
    rng = np.random.default_rng(seed)
    inst = generate_instance(rng, n_jobs=n_jobs, k_tasks=k_tasks,
                             n_machines=n_machines, heterogeneous=hetero,
                             shape=shape)
    p = pack(inst)
    w = sample_window(synthesize("AU-SA", days=10), rng, HORIZON)
    return p, jnp.asarray(w.intensity)


# ---------------------------------------------------------------------------
# Forecast model invariants.
# ---------------------------------------------------------------------------

def test_observed_prefix_and_lead0_exact():
    _, truth = _case(0)
    key = jax.random.key(3)
    for model in ("oracle_ar1", "persistence", "diurnal"):
        fc = issue(truth, jnp.int32(150), key=key, model=model, scale=1.0)
        np.testing.assert_array_equal(np.asarray(fc.point)[:151],
                                      np.asarray(truth)[:151])
        assert float(fc.std[150]) == 0.0
        assert float(fc.std[250]) > 0.0


def test_error_std_saturating_monotone():
    _, truth = _case(1)
    fc = issue(truth, jnp.int32(50), key=jax.random.key(0), scale=0.8)
    std = np.asarray(fc.std)
    assert (np.diff(std[50:]) >= -1e-6).all()        # non-decreasing in lead
    assert std[-1] <= 0.8 * float(jnp.std(truth)) + 1e-4  # saturates at scale


def test_zero_scale_is_oracle_bitexact():
    _, truth = _case(2)
    for model in ("oracle_ar1", "persistence", "diurnal"):
        fc = issue(truth, jnp.int32(0), key=jax.random.key(1), model=model,
                   scale=0.0)
        if model == "oracle_ar1":
            np.testing.assert_array_equal(np.asarray(fc.point),
                                          np.asarray(truth))
        assert float(fc.std.max()) == 0.0


def test_quantiles_ordered_and_collapse_on_prefix():
    _, truth = _case(3)
    fc = issue(truth, jnp.int32(100), key=jax.random.key(2), scale=1.0)
    q = np.asarray(lead_quantiles(fc, (0.1, 0.5, 0.9)))
    assert q.shape == (3, HORIZON)
    assert (q[0] <= q[1] + 1e-5).all() and (q[1] <= q[2] + 1e-5).all()
    np.testing.assert_allclose(q[:, :101],
                               np.broadcast_to(np.asarray(truth)[:101],
                                               (3, 101)), rtol=1e-6)


def test_diurnal_exact_on_periodic_trace():
    """A perfectly 96-periodic trace makes the seasonal-naive model exact."""
    day = np.abs(np.sin(np.arange(96) / 96 * 2 * np.pi)) * 100 + 50
    truth = jnp.asarray(np.tile(day, 6), jnp.float32)
    fc = issue(truth, jnp.int32(100), model="diurnal", scale=1.0)
    np.testing.assert_array_equal(np.asarray(fc.point), np.asarray(truth))


def test_persistence_flat_after_issue():
    _, truth = _case(4)
    t0 = 123
    fc = issue(truth, jnp.int32(t0), model="persistence", scale=1.0)
    pt = np.asarray(fc.point)
    assert (pt[t0:] == pt[t0]).all()
    assert pt[t0] == float(truth[t0])


def test_n_replans():
    assert n_replans(512, 96) == 6
    assert n_replans(96, 96) == 1
    assert n_replans(97, 96) == 2
    with pytest.raises(ValueError):
        n_replans(96, 0)


# ---------------------------------------------------------------------------
# Zero-noise rolling == day-ahead, bit-exact (the acceptance regression).
# ---------------------------------------------------------------------------

def _assert_zero_noise_bitexact(p, truth, theta, window, stretch, every):
    key = jax.random.key(11)
    d0 = dirty_mask(truth, jnp.float32(theta), jnp.int32(window),
                    max_window=window)
    dr = rolling_dirty_mask(truth, jnp.float32(theta), jnp.int32(window),
                            key, jnp.float32(0.0), every=every,
                            max_window=window)
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(dr))
    da = day_ahead_dirty_mask(truth, jnp.float32(theta), jnp.int32(window),
                              key, jnp.float32(0.0), max_window=window)
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(da))

    c = online_carbon_gated_jax(p, truth, theta=theta, window=window,
                                stretch=stretch)
    r = online_rolling_gated_jax(p, truth, key, theta=theta, window=window,
                                 stretch=stretch, every=every, scale=0.0)
    np.testing.assert_array_equal(np.asarray(c.start), np.asarray(r.start))
    np.testing.assert_array_equal(np.asarray(c.assign), np.asarray(r.assign))
    assert int(validate.total_violations(p, r.start, r.assign)) == 0


@pytest.mark.parametrize("every", [24, 48, 96])
@pytest.mark.parametrize("seed,shape,hetero", [(0, "chain", False),
                                               (1, "fanout", True)])
def test_zero_noise_rolling_matches_day_ahead_fixed(seed, shape, hetero,
                                                    every):
    p, truth = _case(seed, shape, hetero)
    _assert_zero_noise_bitexact(p, truth, 0.4, 96, 1.5, every)


@settings(max_examples=20, deadline=None, derandomize=True)
@given(seed=st.integers(0, 10_000), shape=st.sampled_from(DAG_SHAPES),
       hetero=st.booleans(), theta=st.sampled_from([0.25, 0.3, 0.5, 0.75]),
       window=st.sampled_from([24, 48, 96]),
       stretch=st.sampled_from([1.25, 1.5, 2.0]),
       every=st.sampled_from([16, 24, 48, 96, 200]))
def test_zero_noise_rolling_matches_day_ahead_property(seed, shape, hetero,
                                                       theta, window,
                                                       stretch, every):
    p, truth = _case(seed, shape, hetero)
    _assert_zero_noise_bitexact(p, truth, theta, window, stretch, every)


# ---------------------------------------------------------------------------
# Rolling gate behaviour under error.
# ---------------------------------------------------------------------------

def test_rolling_gate_schedules_feasible_under_noise():
    p, truth = _case(5, n_jobs=5, k_tasks=3, n_machines=4)
    for scale in (0.5, 1.5):
        r = online_rolling_gated_jax(p, truth, jax.random.key(4), theta=0.3,
                                     stretch=1.5, every=24, scale=scale)
        assert bool(np.asarray(r.scheduled | ~p.task_mask).all())
        assert int(validate.total_violations(p, r.start, r.assign)) == 0


def test_realized_carbon_monotone_in_forecast_quality():
    """On fixed seeds, worse forecasts never *reduce* realized carbon (mean
    over instances x error seeds) for the rolling gate."""
    rng = np.random.default_rng(0)
    year = synthesize("AU-SA", days=30)
    cases = []
    for seed in range(4):
        p, truth = _case(seed + 10, n_jobs=5, k_tasks=3, n_machines=4)
        w = sample_window(year, rng, HORIZON)
        cases.append((p, jnp.asarray(w.intensity),
                      jnp.asarray(w.cumulative())))
    keys = [jax.random.key(100 + s) for s in range(3)]
    means = []
    for scale in (0.0, 1.0, 2.5):
        tot = []
        for p, truth, cum in cases:
            for key in keys:
                r = online_rolling_gated_jax(p, truth, key, theta=0.3,
                                             stretch=1.5, every=24,
                                             scale=scale)
                tot.append(float(evaluate(p, r.start, r.assign, cum).carbon))
        means.append(np.mean(tot))
    assert means[0] <= means[1] + 1e-6 <= means[2] + 2e-6, means
