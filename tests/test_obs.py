"""Telemetry subsystem contracts (``src/repro/obs`` + the bench harness).

Four groups:

* **metrics** — counter/gauge/histogram semantics, snapshot shape, the
  get-or-create registry (type conflicts are errors), reset;
* **tracer** — event capture, the Chrome-trace export contract (the JSON
  Perfetto opens: sim epochs on one pid at 1 ms/epoch, wall spans on
  another, metadata + counter tracks), ``REPRO_TRACE`` activation, and
  the null tracer's zero-surface;
* **bit-exactness** — the subsystem's hard contract: telemetry ON must
  not change a single computed value.  Property-tested over DAG families
  x fleets x both machine rules by running the same stream twice;
* **harness** — fake-clock BenchTimer (cold/warm split is arithmetic,
  locked without real timing), perf-gate verdict logic on fake probes
  (regression / pass / fingerprint-skip / no-baseline skip), provenance
  checks, and the roofline arithmetic.
"""
import dataclasses
import json

import numpy as np
import pytest

from hypothesis import given, settings, strategies as st

from benchmarks.common import BenchTimer
from benchmarks.perf_gate import (check_provenance, extract_probe,
                                  gate_verdict)
from repro.obs import (MetricsRegistry, NULL_TRACER, Tracer, get_tracer,
                       set_tracer, trace_enabled, traced_xla_call)
from repro.scenarios.fleets import build_fleet
from repro.scenarios.generator import ScenarioConfig, sample_job
from repro.core.carbon import sample_window, synthesize
from repro.stream import StreamEngine
from tests.strategies import family_names, fleet_names, seeds

N_MACHINES = 3
PAD_TASKS = 8
HORIZON = 400


def _stream_case(seed, family, fleet, n=3, arrival_step=0):
    rng = np.random.default_rng(seed)
    scen = ScenarioConfig(family=family, n_jobs=1, width=2, depth=2,
                          n_machines=N_MACHINES, fleet=fleet).validate()
    jobs = [dataclasses.replace(sample_job(rng, scen), arrival=i * arrival_step)
            for i in range(n)]
    powers, speeds = build_fleet(fleet, rng, N_MACHINES)
    trace = sample_window(synthesize("AU-SA", days=10, seed=7), rng, HORIZON)
    return jobs, powers, speeds, trace


# ---------------------------------------------------------------------------
# Metrics registry.
# ---------------------------------------------------------------------------

def test_counter_gauge_semantics():
    reg = MetricsRegistry()
    c = reg.counter("jobs")
    c.inc()
    c.inc(3)
    assert c.value == 4
    assert reg.counter("jobs") is c            # get-or-create returns same
    g = reg.gauge("occupancy")
    g.set(2)
    g.set(7)
    assert g.value == 7


def test_histogram_percentiles_and_snapshot():
    reg = MetricsRegistry()
    h = reg.histogram("delay")
    for v in [1, 2, 3, 4, 5, 6, 7, 8, 9, 10]:
        h.observe(v)
    snap = h.snapshot()
    assert snap["count"] == 10
    assert snap["mean"] == pytest.approx(5.5)
    assert snap["p50"] == pytest.approx(np.percentile(range(1, 11), 50))
    assert snap["p90"] == pytest.approx(np.percentile(range(1, 11), 90))
    assert snap["max"] == 10


def test_registry_snapshot_flat_sorted_json_safe():
    reg = MetricsRegistry()
    reg.counter("b").inc()
    reg.gauge("a").set(1.5)
    reg.histogram("c").observe(2.0)
    snap = reg.snapshot()
    assert list(snap) == sorted(snap)
    json.dumps(snap)                            # plain python scalars only
    reg.reset()
    assert reg.counter("b").value == 0
    assert reg.histogram("c").snapshot()["count"] == 0


def test_registry_type_conflict_is_error():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.histogram("x")


# ---------------------------------------------------------------------------
# Tracer + Chrome-trace export.
# ---------------------------------------------------------------------------

def test_tracer_chrome_export_contract(tmp_path):
    tr = Tracer(clock=iter(np.arange(0.0, 10.0, 0.5)).__next__)
    tr.instant("admit", 3, rid=0, lane=1)
    tr.span("job:0", 3, 17, lane=1, rid=0)
    tr.counter("queue_len", 5, 2.0)
    out = tr.timed("probe", lambda: 41 + 1)
    assert out == 42
    doc = tr.to_chrome_trace(lane_names={1: "lane 1"})
    path = tmp_path / "trace.json"
    tr.export(str(path), lane_names={1: "lane 1"})
    on_disk = json.loads(path.read_text())
    assert on_disk == doc

    ev = doc["traceEvents"]
    meta = [e for e in ev if e["ph"] == "M"]
    assert any(e["args"].get("name") == "lane 1" for e in meta
               if e["name"] == "thread_name")
    span = next(e for e in ev if e["ph"] == "X" and e["name"] == "job:0")
    assert span["ts"] == 3 * 1000 and span["dur"] == (17 - 3) * 1000
    inst = next(e for e in ev if e["ph"] == "i" and e["name"] == "admit")
    assert inst["ts"] == 3 * 1000 and inst["args"]["rid"] == 0
    ctr = next(e for e in ev if e["ph"] == "C")
    assert ctr["args"] == {"value": 2.0}
    wall = next(e for e in ev if e["name"] == "xla:probe")
    assert wall["ph"] == "X" and wall["dur"] == pytest.approx(0.5e6)
    assert wall["args"]["first_call"] is True


def test_null_tracer_records_nothing():
    assert NULL_TRACER.enabled is False
    NULL_TRACER.instant("x", 0)
    NULL_TRACER.span("x", 0, 1)
    NULL_TRACER.counter("x", 0, 1.0)
    assert NULL_TRACER.timed("x", lambda: 7) == 7
    assert NULL_TRACER.events == []


def test_get_tracer_honors_repro_trace_env(monkeypatch):
    set_tracer(None)
    monkeypatch.delenv("REPRO_TRACE", raising=False)
    assert get_tracer() is NULL_TRACER
    assert not trace_enabled()
    monkeypatch.setenv("REPRO_TRACE", "1")
    tr = get_tracer()
    assert tr.enabled and trace_enabled()
    assert get_tracer() is tr                   # env activation is sticky
    set_tracer(None)
    monkeypatch.setenv("REPRO_TRACE", "0")
    assert get_tracer() is NULL_TRACER
    set_tracer(None)


def test_traced_xla_call_passthrough_and_capture():
    set_tracer(None)
    assert traced_xla_call("f", lambda a, b: a + b, 2, b=3) == 5
    tr = Tracer()
    set_tracer(tr)
    try:
        assert traced_xla_call("f", lambda a, b: a + b, 2, b=3) == 5
        assert [e["name"] for e in tr.events] == ["xla:f"]
    finally:
        set_tracer(None)


# ---------------------------------------------------------------------------
# The hard contract: telemetry ON is bit-exact to telemetry OFF.
# ---------------------------------------------------------------------------

def _assert_stream_bit_exact(seed, family, fleet, machine_rule):
    jobs, powers, speeds, trace = _stream_case(seed, family, fleet, n=3,
                                               arrival_step=5)

    def run(tracer):
        eng = StreamEngine(trace, powers, speeds, n_lanes=2,
                           pad_tasks=PAD_TASKS, machine_rule=machine_rule,
                           tracer=tracer)
        return eng.run(list(jobs)), eng

    off, _ = run(NULL_TRACER)
    on, eng_on = run(Tracer())
    assert len(eng_on.tracer.events) > 0
    assert len(off) == len(on)
    for a, b in zip(off, on):
        assert (a.admitted, a.completed, a.finished, a.budget) == \
               (b.admitted, b.completed, b.finished, b.budget)
        assert a.carbon == b.carbon and a.energy == b.energy
        if a.start is not None:
            np.testing.assert_array_equal(a.start, b.start)
            np.testing.assert_array_equal(a.assign, b.assign)


@settings(max_examples=6, deadline=None, derandomize=True)
@given(seed=seeds(), family=family_names(), fleet=fleet_names(),
       machine_rule=st.sampled_from(["earliest_finish", "min_energy"]))
def test_stream_bit_exact_with_tracing(seed, family, fleet, machine_rule):
    _assert_stream_bit_exact(seed, family, fleet, machine_rule)


# Fixed-seed grid so the contract holds in CI even under the hypothesis
# stub (where @given property tests skip): one cell per DAG family x a
# fleet, crossed with both machine rules.
@pytest.mark.parametrize("machine_rule", ["earliest_finish", "min_energy"])
@pytest.mark.parametrize("family,fleet", [
    ("chain", "homog"), ("fanout", "tiered"), ("diamond", "mixed"),
    ("layered", "tiered"), ("tpch", "homog")])
def test_stream_bit_exact_with_tracing_grid(family, fleet, machine_rule):
    _assert_stream_bit_exact(17, family, fleet, machine_rule)


def test_stream_summary_matches_job_list():
    jobs, powers, speeds, trace = _stream_case(11, "layered", "tiered", n=5,
                                               arrival_step=3)
    eng = StreamEngine(trace, powers, speeds, n_lanes=2, pad_tasks=PAD_TASKS)
    sjobs = eng.run(jobs)
    s = eng.summary()
    assert s["jobs_admitted"] == sum(1 for sj in sjobs if sj.admitted >= 0)
    assert s["jobs_completed"] == sum(1 for sj in sjobs if sj.finished)
    assert s["jobs_rejected"] == 0
    assert s["queue_delay_epochs"]["count"] == s["jobs_admitted"]
    assert s["carbon_savings_pct"]["count"] == s["jobs_completed"]
    assert s["ticks"] > 0
    json.dumps(s)
    # Re-entrancy: a second run resets the registry, not accumulates.
    eng.run(jobs)
    assert eng.summary()["jobs_admitted"] == s["jobs_admitted"]


# ---------------------------------------------------------------------------
# Bench harness: fake-clock timer, perf-gate verdicts, provenance checks.
# ---------------------------------------------------------------------------

class FakeClock:
    """Deterministic clock: each call returns the next scripted tick."""

    def __init__(self, step=1.0):
        self.t, self.step = 0.0, step

    def __call__(self):
        self.t += self.step
        return self.t


def test_bench_timer_fake_clock_cold_warm_split():
    timer = BenchTimer(clock=FakeClock(step=1.0))
    out, timing = timer.cold_warm(lambda x: x * 2, 21, warm_reps=3)
    assert out == 42
    # Each timed() consumes exactly two ticks of the fake clock, so every
    # measured duration is exactly 1.0 — the split is pure bookkeeping.
    assert timing["compile_s"] == pytest.approx(1.0)
    assert timing["warm_s_median"] == pytest.approx(1.0)
    assert timing["warm_s_all"] == [1.0, 1.0, 1.0]


def test_bench_timer_timed_returns_result_and_duration():
    timer = BenchTimer(clock=FakeClock(step=0.25))
    out, secs = timer.timed(sum, [1, 2, 3])
    assert out == 6 and secs == pytest.approx(0.25)


def _probe(fp, dispatch=0.010, learn=0.020):
    return {"fingerprint": fp,
            "cells": {"dispatch_sweep": {"warm_s_median": dispatch},
                      "learn_step": {"warm_s_median": learn}}}


FP = {"backend": "cpu", "device_kind": "cpu", "device_count": 1}
FP_OTHER = {"backend": "tpu", "device_kind": "v5e", "device_count": 4}


def test_gate_passes_within_tolerance():
    v = gate_verdict(_probe(FP, 0.012, 0.021),
                     [("BENCH_a.json", _probe(FP))], tolerance=0.30)
    assert v["ok"] and len(v["compared"]) == 2
    assert all(r["ok"] for r in v["compared"])


def test_gate_detects_regression():
    v = gate_verdict(_probe(FP, dispatch=0.014),
                     [("BENCH_a.json", _probe(FP, dispatch=0.010))],
                     tolerance=0.30)
    row = next(r for r in v["compared"] if r["cell"] == "dispatch_sweep")
    assert not row["ok"] and not v["ok"]
    assert row["ratio"] == pytest.approx(1.4)


def test_gate_uses_best_stored_baseline():
    v = gate_verdict(_probe(FP, dispatch=0.012),
                     [("BENCH_slow.json", _probe(FP, dispatch=0.020)),
                      ("BENCH_fast.json", _probe(FP, dispatch=0.010))])
    row = next(r for r in v["compared"] if r["cell"] == "dispatch_sweep")
    assert row["baseline_warm_s"] == 0.010
    assert row["baseline_from"] == "BENCH_fast.json"


def test_gate_skips_foreign_fingerprints():
    v = gate_verdict(_probe(FP), [("BENCH_tpu.json", _probe(FP_OTHER))])
    assert v["ok"] and v["compared"] == []      # skip path: pass, no rows
    assert v["skipped"][0]["path"] == "BENCH_tpu.json"
    # --cross-machine forces the comparison through.
    v2 = gate_verdict(_probe(FP), [("BENCH_tpu.json", _probe(FP_OTHER))],
                      cross_machine=True)
    assert len(v2["compared"]) == 2 and v2["skipped"] == []


def test_gate_skip_when_no_baselines():
    v = gate_verdict(_probe(FP), [])
    assert v["ok"] and v["compared"] == [] and v["skipped"] == []


def test_extract_probe_shapes():
    assert extract_probe({}) is None
    assert extract_probe({"timing": {"wall_s": 1.0}}) is None
    p = _probe(FP)
    assert extract_probe({"timing": {"probe": p}}) == p


def test_check_provenance(tmp_path):
    good = {"bench": "x", "provenance": {
        "git_sha": "abc", "jax": "0.4", "jaxlib": "0.4", "backend": "cpu",
        "device_kind": "cpu", "device_count": 1}}
    bad = {"bench": "y", "provenance": {"git_sha": "abc"}}
    none = {"bench": "z"}
    for name, rec in [("good.json", good), ("bad.json", bad),
                      ("none.json", none)]:
        (tmp_path / name).write_text(json.dumps(rec))
    assert check_provenance([str(tmp_path / "good.json")]) == []
    missing = check_provenance([str(tmp_path / "bad.json")])
    assert any("jaxlib" in m for m in missing)
    assert any("missing provenance block" in m
               for m in check_provenance([str(tmp_path / "none.json")]))
    assert check_provenance([str(tmp_path / "nope-*.json")])  # no match fails


def test_roofline_achieved_columns():
    from repro.launch.roofline import (HBM_BW, PEAK_FLOPS,
                                       achieved_vs_roofline)
    cost = {"flops": 2 * PEAK_FLOPS, "bytes": HBM_BW / 2}
    out = achieved_vs_roofline(cost, warm_s=4.0)
    assert out["roofline_compute_s"] == pytest.approx(2.0)
    assert out["roofline_memory_s"] == pytest.approx(0.5)
    assert out["dominant"] == "compute"
    assert out["roofline_bound_s"] == pytest.approx(2.0)
    assert out["roofline_frac"] == pytest.approx(0.5)
    assert out["achieved_flops_per_s"] == pytest.approx(PEAK_FLOPS / 2)
