"""Carbon-trace ingestion + windowing regressions (this PR's trace fixes).

* ``sample_window`` draws from ``0 .. n_epochs - horizon`` *inclusive* —
  the final window used to be unreachable (exclusive ``rng.integers``
  bound without the ``+ 1``), silently biasing every windowed experiment
  away from the end of its trace;
* ``from_csv`` keeps the time axis aligned on NaN holes: interior gaps
  are linearly interpolated (dropping rows would shift every later hour),
  edge gaps and all-NaN files raise instead of inventing data.
"""
import numpy as np
import pytest

from repro.core.carbon import (EPOCHS_PER_HOUR, CarbonTrace, from_csv,
                               sample_window)


def _arange_trace(n: int) -> CarbonTrace:
    return CarbonTrace("test", np.arange(n, dtype=np.float32))


# ---------------------------------------------------------------------------
# sample_window
# ---------------------------------------------------------------------------

def test_sample_window_last_window_reachable():
    """n=6, horizon=4: valid starts are 0, 1, 2 — the last window
    (intensity[2:6]) must actually be drawable."""
    trace = _arange_trace(6)
    starts = {int(sample_window(trace, np.random.default_rng(s), 4)
                  .intensity[0]) for s in range(200)}
    assert starts == {0, 1, 2}, \
        f"reachable starts {sorted(starts)} != {{0, 1, 2}}"


def test_sample_window_full_trace_window():
    """horizon == n_epochs: exactly one valid window — the whole trace."""
    trace = _arange_trace(5)
    w = sample_window(trace, np.random.default_rng(0), 5)
    np.testing.assert_array_equal(w.intensity, trace.intensity)


def test_sample_window_keeps_horizon_length():
    trace = _arange_trace(100)
    for s in range(5):
        w = sample_window(trace, np.random.default_rng(s), 17)
        assert w.n_epochs == 17
        # window content is a contiguous slice of the parent
        start = int(w.intensity[0])
        np.testing.assert_array_equal(
            w.intensity, trace.intensity[start:start + 17])


# ---------------------------------------------------------------------------
# from_csv
# ---------------------------------------------------------------------------

def _write_csv(tmp_path, rows):
    p = tmp_path / "trace.csv"
    p.write_text("timestamp,gco2_per_kwh\n"
                 + "\n".join(f"t{i},{v}" for i, v in enumerate(rows)) + "\n")
    return str(p)


def test_from_csv_interpolates_interior_nans(tmp_path):
    """NaN holes are filled in place: the epoch axis stays aligned (hour i
    is still row i) and the filled values are the linear interpolants."""
    path = _write_csv(tmp_path, ["100.0", "", "300.0", "nan", "nan",
                                 "600.0"])
    trace = from_csv(path)
    assert trace.n_epochs == 6 * EPOCHS_PER_HOUR, \
        "rows must be filled, never dropped"
    hourly = trace.intensity[::EPOCHS_PER_HOUR]
    np.testing.assert_allclose(
        hourly, [100.0, 200.0, 300.0, 400.0, 500.0, 600.0], rtol=1e-6)


def test_from_csv_clean_file_roundtrip(tmp_path):
    path = _write_csv(tmp_path, ["10.5", "20.5", "30.5"])
    trace = from_csv(path)
    np.testing.assert_allclose(trace.intensity[::EPOCHS_PER_HOUR],
                               [10.5, 20.5, 30.5], rtol=1e-6)
    assert trace.n_epochs == 3 * EPOCHS_PER_HOUR


def test_from_csv_edge_gap_raises(tmp_path):
    for rows in (["", "20.0", "30.0"], ["10.0", "20.0", "nan"]):
        with pytest.raises(ValueError, match="edges"):
            from_csv(_write_csv(tmp_path, rows))


def test_from_csv_all_nan_raises(tmp_path):
    with pytest.raises(ValueError, match="no finite"):
        from_csv(_write_csv(tmp_path, ["nan", "", "nan"]))


def test_from_csv_adjacent_nan_runs_interpolate_independently(tmp_path):
    """Two NaN runs separated by one finite anchor: each run interpolates
    against its *own* bracketing anchors — the shared middle anchor must
    not smear one run's slope into the other."""
    path = _write_csv(tmp_path, ["100.0", "nan", "nan", "200.0", "nan",
                                 "600.0"])
    hourly = from_csv(path).intensity[::EPOCHS_PER_HOUR]
    # run 1 ramps 100 -> 200 (slope ~33/row); run 2 ramps 200 -> 600
    # (slope 200/row) — different slopes on either side of the anchor.
    np.testing.assert_allclose(
        hourly, [100.0, 400 / 3, 500 / 3, 200.0, 400.0, 600.0], rtol=1e-6)


def test_from_csv_single_row_raises(tmp_path):
    with pytest.raises(ValueError, match="at least 2 rows"):
        from_csv(_write_csv(tmp_path, ["250.0"]))


def test_from_csv_all_nan_column_in_multicolumn_file_raises(tmp_path):
    """A real export can have one dead sensor column while others are fine
    — selecting it must raise about *that column*, not succeed on garbage."""
    p = tmp_path / "multi.csv"
    p.write_text("timestamp,gco2_per_kwh,price\n"
                 + "\n".join(f"t{i},nan,{10 * i}.0" for i in range(4)) + "\n")
    with pytest.raises(ValueError, match="column 1"):
        from_csv(str(p), column=1)
    # the healthy neighbouring column still ingests
    trace = from_csv(str(p), column=2)
    np.testing.assert_allclose(trace.intensity[::EPOCHS_PER_HOUR],
                               [0.0, 10.0, 20.0, 30.0], rtol=1e-6)
