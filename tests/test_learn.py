"""Gate-policy learning: the relaxation contract + gradient correctness.

Three pillars (see the contract in ``repro/learn/__init__.py``):

* **temp -> 0 == hard gate** — ``soft_dispatch``'s hard schedule is
  bit-exact with ``online_carbon_gated_jax`` across every scenario family x
  fleet, and the sigmoid mask thresholded at 0.5 equals the boolean
  quantile gate (hypothesis property + fixed-seed parametrization so the
  contract holds even without hypothesis installed);
* **gradients are real** — ``jax.grad`` of the (soft) carbon loss w.r.t.
  theta matches a central finite difference, and straight-through forward
  values equal the exact hard-dispatch objectives / validator masses;
* **the loop learns** — a short deterministic training run decreases the
  loss and never leaves (0, 1).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import validate
from repro.core.objectives import carbon, makespan, soft_carbon, soft_makespan
from repro.core.solvers.online_jax import (dirty_mask,
                                           online_carbon_gated_jax,
                                           sorted_windows)
from repro.learn import (LearnConfig, expected_wait, gate_loss, soft_dispatch,
                         train_gate)
from repro.scenarios import FAMILY_NAMES, FLEET_NAMES
from tests.strategies import family_names, fleet_names, scenario_case, seeds

HORIZON = 700
# One static shape for the whole module (one XLA program per kernel).
PAD_T, PAD_M = 64, 5


def _case(seed, family=None, fleet=None, **kw):
    kw.setdefault("n_jobs", 4)
    kw.setdefault("width", 2)
    kw.setdefault("depth", 2)
    kw.setdefault("n_machines", 3)
    return scenario_case(seed, family=family, fleet=fleet, horizon=HORIZON,
                         pad_tasks=PAD_T, pad_machines=PAD_M, **kw)


def _assert_temp0_bitexact(p, w, theta, window, stretch):
    hard = online_carbon_gated_jax(p, w.intensity, theta=theta,
                                   window=window, stretch=stretch)
    sd = soft_dispatch(p, jnp.asarray(w.intensity), jnp.float32(theta),
                       jnp.int32(window), jnp.float32(stretch),
                       max_window=window, temp=1e-6)
    # hard forward path: bit-exact with the hard dispatcher at ANY temp
    np.testing.assert_array_equal(np.asarray(hard.start),
                                  np.asarray(sd.hard.start))
    np.testing.assert_array_equal(np.asarray(hard.assign),
                                  np.asarray(sd.hard.assign))
    np.testing.assert_array_equal(np.asarray(hard.scheduled),
                                  np.asarray(sd.hard.scheduled))
    # the relaxed mask collapses onto the boolean quantile gate
    dm = dirty_mask(jnp.asarray(w.intensity), jnp.float32(theta),
                    jnp.int32(window), max_window=window)
    np.testing.assert_array_equal(np.asarray(sd.dirty > 0.5), np.asarray(dm))


@pytest.mark.parametrize("family", FAMILY_NAMES)
@pytest.mark.parametrize("seed,fleet", [(0, "homog"), (1, "tiered")])
def test_soft_dispatch_temp0_bitexact_fixed_seeds(seed, family, fleet):
    p, w = _case(seed, family, fleet)
    _assert_temp0_bitexact(p, w, theta=0.4, window=48, stretch=1.5)


@settings(max_examples=25, deadline=None, derandomize=True)
@given(seed=seeds(),
       family=family_names(),
       fleet=fleet_names(),
       theta=st.sampled_from([0.25, 0.3, 0.5, 0.75]),
       window=st.sampled_from([24, 48, 96]),
       stretch=st.sampled_from([1.25, 1.5, 2.0]))
def test_soft_dispatch_temp0_bitexact_property(seed, family, fleet, theta,
                                               window, stretch):
    p, w = _case(seed, family, fleet)
    _assert_temp0_bitexact(p, w, theta, window, stretch)


def _loss_parts(seed, family, fleet, dtype=jnp.float32):
    p, w = _case(seed, family, fleet)
    inten = jnp.asarray(w.intensity, dtype)
    cum = jnp.asarray(w.cumulative(), dtype)
    sd = soft_dispatch(p, inten, jnp.asarray(0.4, dtype), jnp.int32(48),
                       jnp.asarray(1.5, dtype), max_window=48)
    sv, n = sorted_windows(inten, jnp.int32(48), 48)
    return p, inten, cum, sv, n, sd.budget


def _assert_grad_matches_fd(seed, family, theta):
    """jax.grad of the soft carbon loss vs a central finite difference.

    Runs in float64 with a 1e-6 step: the loss is piecewise-smooth (interp /
    min / max kinks dense at float32 FD scales), so a meaningful central
    difference needs f64 resolution; theta values sit away from the
    quantile-interpolation knots ``j / (n - 1)``.
    """
    with jax.experimental.enable_x64():
        p, inten, cum, sv, n, budget = _loss_parts(seed, family, "tiered",
                                                   dtype=jnp.float64)
        E = int(inten.shape[0])

        def L(th):
            t = gate_loss(p, cum, inten, sv, n, th, budget,
                          jnp.float64(0.3), E, straight_through=False)
            return t.carbon

        g = float(jax.grad(L)(jnp.float64(theta)))
        h = 1e-6
        fd = float((L(jnp.float64(theta + h)) - L(jnp.float64(theta - h)))
                   / (2 * h))
    scale = max(abs(g), abs(fd), 1e-3)
    assert abs(g - fd) / scale < 0.05, (seed, family, theta, g, fd)


# The FD domain is a finite grid (seeds x families x thetas) so the
# hypothesis draw below can never leave territory this parametrization (and
# the pre-commit exhaustive sweep) hasn't pinned.
FD_SEEDS = (0, 1, 2, 3, 5, 8, 13, 21)
FD_THETAS = (0.23, 0.37, 0.61)


@pytest.mark.parametrize("theta", FD_THETAS)
@pytest.mark.parametrize("family", FAMILY_NAMES)
def test_carbon_loss_grad_matches_central_fd(family, theta):
    _assert_grad_matches_fd(2, family, theta)


@settings(max_examples=15, deadline=None, derandomize=True)
@given(seed=st.sampled_from(FD_SEEDS), family=family_names(),
       theta=st.sampled_from(FD_THETAS))
def test_carbon_loss_grad_matches_central_fd_property(seed, family, theta):
    _assert_grad_matches_fd(seed, family, theta)


def test_straight_through_forward_values_are_exact():
    """ST loss forward == hard-dispatch carbon; ST penalty == validator."""
    for seed, family in enumerate(FAMILY_NAMES):
        p, inten, cum, sv, n, budget = _loss_parts(seed, family, "mixed")
        E = int(inten.shape[0])
        t = gate_loss(p, cum, inten, sv, n, jnp.float32(0.4), budget,
                      jnp.float32(0.3), E, straight_through=True)
        hard = online_carbon_gated_jax(p, inten, theta=0.4, window=48,
                                       stretch=1.5)
        want_c = carbon(p, hard.start, hard.assign, cum)
        want_p = validate.total_violations(p, hard.start, hard.assign,
                                           deadline=budget)
        np.testing.assert_allclose(float(t.carbon), float(want_c), rtol=1e-6)
        np.testing.assert_allclose(float(t.penalty), float(want_p),
                                   atol=1e-6)


def test_soft_objectives_equal_hard_at_integer_starts():
    for seed in range(3):
        p, w = _case(seed, FAMILY_NAMES[seed], FLEET_NAMES[seed % 3])
        cum = jnp.asarray(w.cumulative())
        hard = online_carbon_gated_jax(p, w.intensity, theta=0.4, window=48,
                                       stretch=1.5)
        s_f = hard.start.astype(jnp.float32)
        np.testing.assert_allclose(
            float(soft_carbon(p, s_f, hard.assign, cum)),
            float(carbon(p, hard.start, hard.assign, cum)), rtol=1e-6)
        assert float(soft_makespan(p, s_f, hard.assign)) == float(
            makespan(p, hard.start, hard.assign))


def test_expected_wait_counts_dirty_runs_on_hard_masks():
    rng = np.random.default_rng(0)
    dirty = (rng.random(64) < 0.5).astype(np.float32)
    w = np.asarray(expected_wait(jnp.asarray(dirty)))
    ref = np.zeros(64)
    for e in range(64):
        run = 0
        while e + run < 64 and dirty[e + run] > 0.5:
            run += 1
        ref[e] = run
    np.testing.assert_allclose(w, ref, atol=1e-5)


def test_train_gate_decreases_loss_and_stays_in_unit_interval():
    from repro.scenarios.batching import pack_aligned
    from repro.scenarios import ScenarioConfig, sample_batch
    from repro.core import synthesize

    rng = np.random.default_rng(11)
    year = synthesize("AU-SA", days=20, seed=11)
    insts, group = [], []
    for gi, fam in enumerate(("chain", "layered")):
        cfg = ScenarioConfig(family=fam, fleet="tiered", n_jobs=3, width=2,
                             depth=2, n_machines=3)
        insts += sample_batch(rng, cfg, 2)
        group += [gi] * 2
    batch = pack_aligned(insts)
    H = 600
    intens, cums = [], []
    for _ in insts:
        w = year.window(int(rng.integers(0, year.n_epochs - H)), H)
        intens.append(w.intensity)
        cums.append(w.cumulative())
    # deliberately bad init (0.85: gate nearly always open) — the gradient
    # signal toward more gating is strong, so the loss must come down.
    res = train_gate(batch, np.stack(intens), np.stack(cums),
                     np.asarray(group), np.full(len(insts), 48, np.int32),
                     1.5, np.full(2, 0.85, np.float32),
                     LearnConfig(steps=40))
    losses = np.asarray(res.loss_curve)
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] - 1e-3, losses
    th = np.asarray(res.theta_curve)
    assert ((th > 0.0) & (th < 1.0)).all()
    # deterministic: a second identical run reproduces bit-for-bit
    res2 = train_gate(batch, np.stack(intens), np.stack(cums),
                      np.asarray(group), np.full(len(insts), 48, np.int32),
                      1.5, np.full(2, 0.85, np.float32),
                      LearnConfig(steps=40))
    np.testing.assert_array_equal(losses, np.asarray(res2.loss_curve))
    np.testing.assert_array_equal(np.asarray(res.theta),
                                  np.asarray(res2.theta))
