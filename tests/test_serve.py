"""ServeEngine semantics regressions (the PR's serve-side bugfixes).

Locks the three contracts the streaming-dispatch work exposed:

* ``max_new`` counts **decode** tokens — a non-EOS, un-truncated request
  returns ``1 + max_new`` ids (prefill-sampled continuation + max_new
  decode steps), where the old loop stopped one decode token short;
* a request hitting the ``max_len`` KV horizon is surfaced with
  ``truncated=True`` instead of silently coming back short;
* ``run`` drains the lane pool before returning, so back-to-back ``run``
  calls on one engine serve fresh requests instead of re-serving stale
  lanes.

Plus the LanePool unit contracts both engines (serve + stream) sit on.
"""
import numpy as np
import pytest

import jax

from repro.configs import ARCHS
from repro.models.api import build_model
from repro.models.params import init_params
from repro.models.parallel import ParallelCfg
from repro.serve import LanePool, Request, ServeConfig, ServeEngine

PAR = ParallelCfg(mesh=None, remat="none")


@pytest.fixture(scope="module")
def lm():
    cfg = ARCHS["qwen1.5-0.5b"].reduced()
    model = build_model(cfg)
    params = init_params(jax.random.key(0), model.defs)
    return model, params, cfg


def _reqs(cfg, n, prompt_len=8, max_new=5, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(
        0, cfg.vocab_size, prompt_len).astype(np.int32), max_new=max_new)
        for i in range(n)]


def _engine(lm, **sc):
    model, params, cfg = lm
    sc.setdefault("batch_slots", 2)
    sc.setdefault("max_len", 64)
    return ServeEngine(model, params, cfg, PAR, ServeConfig(**sc))


# ---------------------------------------------------------------------------
# max_new / truncation semantics.
# ---------------------------------------------------------------------------

def test_max_new_counts_decode_tokens(lm):
    """eos_id=-1 never fires, max_len is roomy: every request must come
    back with exactly 1 + max_new tokens (the prefill-sampled token is in
    addition to, not part of, the max_new decode budget)."""
    _, _, cfg = lm
    eng = _engine(lm)
    done = eng.run(_reqs(cfg, 3, max_new=5))
    assert len(done) == 3
    for r in done:
        assert r.done and not r.truncated
        assert len(r.out_tokens) == 1 + r.max_new, \
            f"rid={r.rid}: {len(r.out_tokens)} tokens != 1 + max_new"


def test_max_len_horizon_surfaces_truncation(lm):
    """A lane hitting the max_len KV horizon before max_new/EOS is evicted
    with truncated=True — shorter output, never silent."""
    _, _, cfg = lm
    eng = _engine(lm, max_len=12)
    (r,) = eng.run(_reqs(cfg, 1, prompt_len=8, max_new=50))
    assert r.done and r.truncated
    assert len(r.out_tokens) < 1 + r.max_new


def test_truncated_flag_false_on_exact_finish(lm):
    """Finishing max_new on the same tick the horizon arrives is a normal
    finish, not a truncation."""
    _, _, cfg = lm
    # pos after prefill = 8; decode ticks at pos 8,9,10 -> horizon at
    # max_len-1 = 11 coincides with n_decode == max_new == 3
    eng = _engine(lm, max_len=12)
    (r,) = eng.run(_reqs(cfg, 1, prompt_len=8, max_new=3))
    assert r.done and not r.truncated
    assert len(r.out_tokens) == 1 + r.max_new


# ---------------------------------------------------------------------------
# run() re-entry.
# ---------------------------------------------------------------------------

def test_run_reentry_serves_fresh_requests(lm):
    """Second run() on one engine: only its own requests come back, with
    the same outputs a fresh engine produces (no stale lanes)."""
    _, _, cfg = lm
    eng = _engine(lm)
    a = eng.run(_reqs(cfg, 3, max_new=4, seed=1))
    b = eng.run(_reqs(cfg, 2, max_new=4, seed=2))
    assert sorted(r.rid for r in a) == [0, 1, 2]
    assert sorted(r.rid for r in b) == [0, 1]
    fresh = _engine(lm).run(_reqs(cfg, 2, max_new=4, seed=2))
    for got, want in zip(sorted(b, key=lambda r: r.rid),
                         sorted(fresh, key=lambda r: r.rid)):
        assert got.out_tokens == want.out_tokens, \
            "re-entered engine diverged from a fresh engine"


def test_run_drains_unfinished_and_stays_reentrant(lm):
    """max_ticks too small to finish: requests surface done=False, lanes
    are freed, and the next run() still serves correctly."""
    _, _, cfg = lm
    eng = _engine(lm)
    out = eng.run(_reqs(cfg, 2, max_new=30), max_ticks=3)
    assert len(out) == 2 and all(not r.done for r in out)
    again = eng.run(_reqs(cfg, 2, max_new=4))
    assert all(r.done and len(r.out_tokens) == 5 for r in again)


# ---------------------------------------------------------------------------
# LanePool (the occupancy bookkeeping both engines share).
# ---------------------------------------------------------------------------

def test_lane_pool_contracts():
    pool = LanePool(2)
    assert pool.free_lanes() == [0, 1] and not pool.any_active()
    queue = ["a", "b", "c"]
    placed = pool.admit(queue)
    assert placed == [(0, "a"), (1, "b")] and queue == ["c"]
    with pytest.raises(ValueError, match="occupied"):
        pool.insert(0, "x")
    assert pool.payload(1) == "b"
    assert pool.evict(0) == "a"
    with pytest.raises(ValueError, match="already free"):
        pool.evict(0)
    # ready-gating: FIFO stops at the first not-ready item
    assert pool.admit(queue, ready=lambda _: False) == []
    assert queue == ["c"]
    assert pool.drain() == ["b"]
    assert not pool.any_active() and pool.free_lanes() == [0, 1]
    with pytest.raises(ValueError):
        LanePool(0)


def test_lane_pool_admit_accepts_deque():
    """Regression for the O(n^2) backlog pop: ``admit`` used ``pop(0)``,
    which shifts the whole list per admission AND raises TypeError on a
    ``collections.deque`` (whose ``pop`` takes no index) — the stream
    engine's queue is a deque now, so this locks the O(1) popleft path
    with the FIFO/ready contract intact."""
    import collections
    pool = LanePool(2)
    queue = collections.deque(["a", "b", "c"])
    assert pool.admit(queue) == [(0, "a"), (1, "b")]
    assert list(queue) == ["c"]
    assert pool.evict(0) == "a"
    # ready-gating unchanged on a deque
    assert pool.admit(queue, ready=lambda _: False) == []
    assert list(queue) == ["c"]
    assert pool.admit(queue, ready=lambda _: True) == [(0, "c")]
    assert not queue


def test_lane_pool_admission_policy_hook():
    """``select`` reorders admissions within the READY prefix only, and an
    out-of-prefix pick is rejected loudly."""
    pool = LanePool(2)
    import collections
    queue = collections.deque([("x", 9), ("y", 1), ("z", 0)])
    # ready: first two only; select: smallest weight among ready
    placed = pool.admit(queue, ready=lambda p: p[0] in ("x", "y"),
                        select=lambda ready: min(
                            range(len(ready)), key=lambda i: ready[i][1]))
    assert placed == [(0, ("y", 1)), (1, ("x", 9))], \
        "policy picks within the ready prefix; ('z', 0) must not jump"
    assert list(queue) == [("z", 0)]
    pool.drain()
    bad = LanePool(1)
    with pytest.raises(ValueError, match="outside the ready prefix"):
        bad.admit(collections.deque([1, 2]), ready=lambda p: p == 1,
                  select=lambda ready: 1)
