"""Online dispatchers (beyond-paper): feasibility + budget + savings."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import generate_instance, pack, synthesize, validate
from repro.core.carbon import constant, sample_window
from repro.core.objectives import evaluate
from repro.core.solvers.online import online_carbon_gated, online_greedy


@settings(deadline=None)
@given(seed=st.integers(0, 10_000), hetero=st.booleans())
def test_online_schedules_feasible(seed, hetero):
    rng = np.random.default_rng(seed)
    inst = generate_instance(rng, n_jobs=4, k_tasks=3, n_machines=3,
                             heterogeneous=hetero)
    p = pack(inst)
    w = sample_window(synthesize("AU-SA", days=10), rng, 1500)
    s0, a0 = online_greedy(p)
    validate.assert_feasible_np(p, s0, a0, ctx="online_greedy")
    assert int(validate.total_violations(p, jnp.asarray(s0),
                                         jnp.asarray(a0))) == 0
    sg, ag = online_carbon_gated(p, w.intensity, stretch=1.5)
    validate.assert_feasible_np(p, sg, ag, ctx="online_carbon_gated")


def test_gate_respects_makespan_budget():
    rng = np.random.default_rng(3)
    inst = generate_instance(rng, n_jobs=6, k_tasks=4, n_machines=5)
    p = pack(inst)
    w = sample_window(synthesize("AU-SA", days=10), rng, 2000)
    cum = jnp.asarray(w.cumulative())
    s0, a0 = online_greedy(p)
    ms0 = int(evaluate(p, jnp.asarray(s0), jnp.asarray(a0), cum).makespan)
    for stretch in (1.25, 1.5, 2.0):
        sg, ag = online_carbon_gated(p, w.intensity, theta=0.3,
                                     stretch=stretch)
        ms = int(evaluate(p, jnp.asarray(sg), jnp.asarray(ag), cum).makespan)
        # critical-path gating bounds the makespan up to machine-contention
        # tails (each task's chain fits the budget when released)
        assert ms <= stretch * ms0 * 1.10 + 1


def test_gate_saves_carbon_on_variable_trace():
    rng = np.random.default_rng(5)
    savings = []
    for i in range(3):
        inst = generate_instance(rng, n_jobs=6, k_tasks=4, n_machines=5)
        p = pack(inst)
        w = sample_window(synthesize("AU-SA", days=10), rng, 1500)
        cum = jnp.asarray(w.cumulative())
        s0, a0 = online_greedy(p)
        sg, ag = online_carbon_gated(p, w.intensity, theta=0.4, stretch=1.5)
        b = evaluate(p, jnp.asarray(s0), jnp.asarray(a0), cum)
        g = evaluate(p, jnp.asarray(sg), jnp.asarray(ag), cum)
        savings.append(1 - float(g.carbon) / float(b.carbon))
    assert np.mean(savings) > 0.05


def test_gate_noop_on_flat_trace():
    """Constant intensity -> nothing is ever 'dirty' -> greedy behaviour."""
    rng = np.random.default_rng(7)
    inst = generate_instance(rng, n_jobs=4, k_tasks=3, n_machines=3)
    p = pack(inst)
    tr = constant(200.0, 2000)
    s0, a0 = online_greedy(p)
    sg, ag = online_carbon_gated(p, tr.intensity, theta=0.4, stretch=2.0)
    cum = jnp.asarray(tr.cumulative())
    c0 = float(evaluate(p, jnp.asarray(s0), jnp.asarray(a0), cum).carbon)
    cg = float(evaluate(p, jnp.asarray(sg), jnp.asarray(ag), cum).carbon)
    assert cg == pytest.approx(c0, rel=1e-6)
