"""Shared-fleet contention contracts for the streaming engine.

The shared-fleet tick (``shared_fleet=True``) threads ONE pool-global
machine free-time vector through a ``lax.scan`` over lanes in priority
order, so lanes contend for machines *within* an epoch.  Contracts:

* **partitioned bit-exactness** — ``shared_fleet=False`` (the default) is
  the pre-shared-fleet engine unchanged: streamed schedules still match
  the batched ``online_carbon_gated_jax`` bit-exactly at t=0 across DAG
  families x fleets x machine rules (plus the byte-locked
  ``stream_tiny.json`` golden in ``test_stream_golden.py``);
* **intra-epoch contention is real** — on one shared machine, two jobs
  serialize; partitioned lanes would run them concurrently;
* **lane-order determinism** — the scanned epoch step depends only on the
  job *priority order*, never on which physical lane a job occupies;
* **admission sees the contention** — a job admitted into a busy shared
  fleet gets a later stretch deadline than on an idle fleet;
* **admission policy hook** — ``admission="scpf"`` reorders the backlog by
  critical path; unknown policies are rejected at config and engine level.
"""
import dataclasses

import numpy as np
import pytest

from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core.carbon import sample_window, synthesize
from repro.core.instance import Instance, Job, PackedInstance, pack
from repro.core.solvers.online_jax import (LaneState,
                                           downstream_critical_path,
                                           online_carbon_gated_jax)
from repro.scenarios.batching import padding_rows
from repro.scenarios.fleets import build_fleet
from repro.scenarios.generator import ScenarioConfig, sample_job
from repro.stream import StreamConfig, StreamEngine, simulate_stream
from repro.stream.engine import _pool_tick_shared
from tests.strategies import family_names, fleet_names, seeds

N_MACHINES = 3
PAD_TASKS = 8
HORIZON = 400


def _trace(seed: int, horizon: int = HORIZON):
    rng = np.random.default_rng(seed)
    return sample_window(synthesize("AU-SA", days=10, seed=7), rng, horizon)


def _chain_job(durs, arrival=0):
    """A linear-chain job (critical path == sum of durations)."""
    return Job(arrival=arrival, base_durations=tuple(durs),
               edges=tuple((i, i + 1) for i in range(len(durs) - 1)))


# ---------------------------------------------------------------------------
# Partitioned mode is the pre-shared-fleet engine, bit-exactly.
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None, derandomize=True)
@given(seed=seeds(), family=family_names(), fleet=fleet_names(),
       machine_rule=st.sampled_from(["earliest_finish", "min_energy"]))
def test_partitioned_matches_batched_gate(seed, family, fleet, machine_rule):
    """Explicit ``shared_fleet=False`` across families x fleets x machine
    rules: every streamed schedule is bit-identical to the batched gated
    dispatcher on the same padded instance — the refactored tick is still
    the batched simulator's loop body."""
    rng = np.random.default_rng(seed)
    scen = ScenarioConfig(family=family, n_jobs=1, width=2, depth=2,
                          n_machines=N_MACHINES, fleet=fleet).validate()
    jobs = [dataclasses.replace(sample_job(rng, scen), arrival=0)
            for _ in range(3)]
    powers, speeds = build_fleet(fleet, rng, N_MACHINES)
    trace = _trace(seed)
    eng = StreamEngine(trace, powers, speeds, n_lanes=3,
                       pad_tasks=PAD_TASKS, machine_rule=machine_rule,
                       shared_fleet=False)
    for sj in eng.run(jobs):
        assert sj.finished
        inst = pack(Instance(jobs=(sj.job,), powers_kw=powers,
                             speeds=speeds), pad_tasks=PAD_TASKS)
        ref = online_carbon_gated_jax(inst, jnp.asarray(trace.intensity),
                                      machine_rule=machine_rule)
        np.testing.assert_array_equal(sj.start, np.asarray(ref.start),
                                      err_msg=f"rid={sj.rid} start")
        np.testing.assert_array_equal(sj.assign, np.asarray(ref.assign),
                                      err_msg=f"rid={sj.rid} assign")


# ---------------------------------------------------------------------------
# The shared fleet actually contends.
# ---------------------------------------------------------------------------

def _one_machine_engine(shared_fleet, n_lanes=2, seed=11, **kw):
    trace = _trace(seed)
    return StreamEngine(trace, powers_kw=(1.0,), speeds=(1.0,),
                        n_lanes=n_lanes, pad_tasks=2, theta=1.0,
                        shared_fleet=shared_fleet, **kw)


def test_intra_epoch_contention_on_one_machine():
    """Two single-task jobs, ONE machine, gate open.  Partitioned lanes
    each own a copy of the machine -> both start at 0.  Shared fleet ->
    the priority-order scan serializes them: the second job's start is
    pushed past the first's completion."""
    jobs = [_chain_job([4]), _chain_job([4])]
    part = _one_machine_engine(False).run([dataclasses.replace(j) for j in jobs])
    shared = _one_machine_engine(True).run([dataclasses.replace(j) for j in jobs])
    assert all(sj.finished for sj in part + shared)
    assert [int(sj.start[0]) for sj in part] == [0, 0]
    s0, s1 = (int(sj.start[0]) for sj in shared)
    assert s0 == 0 and s1 >= 4, \
        f"shared fleet must serialize: starts ({s0}, {s1})"


def test_shared_admission_budget_reflects_contention():
    """A job admitted while the shared machine is busy gets a later stretch
    deadline (and a worse greedy baseline) than the same job admitted into
    an idle partitioned lane — admission's greedy solve warm-starts from
    the live shared free-times."""
    jobs = [_chain_job([20], arrival=0), _chain_job([4], arrival=2)]
    part = _one_machine_engine(False).run([dataclasses.replace(j) for j in jobs])
    shared = _one_machine_engine(True).run([dataclasses.replace(j) for j in jobs])
    assert all(sj.finished for sj in part + shared)
    # rid 1 admitted at t=2 in both modes; the fleet it sees differs.
    assert shared[1].admitted == part[1].admitted == 2
    assert shared[1].greedy_makespan > part[1].greedy_makespan
    assert shared[1].budget > part[1].budget
    assert int(shared[1].start[0]) >= 20      # waits for the machine


def test_shared_fleet_eviction_overlap_validated():
    """validate_evictions=True (the default above) ran the cross-lane
    overlap check on every eviction of the contention cases — rerun one
    densely loaded shared stream end to end and let the validator police
    the no-overlap invariant."""
    cfg = StreamConfig(arrivals="bursty", rate=0.1, horizon=192, n_lanes=4,
                       n_machines=2, fleet="homog", seed=5,
                       shared_fleet=True)
    res = simulate_stream(cfg)
    assert res.meta["n_finished"] >= 1   # the validator raised on overlap


# ---------------------------------------------------------------------------
# Lane-order determinism of the scanned epoch step.
# ---------------------------------------------------------------------------

def _stack_insts(insts):
    return PackedInstance(*(jnp.stack([getattr(i, f) for i in insts])
                            for f in PackedInstance._fields))


def test_pool_tick_shared_lane_permutation_invariant():
    """The scanned step's result depends only on which JOBS the priority
    order ranks, never on the physical lanes they occupy: permuting jobs
    across lanes (with the order array permuted to match) yields identical
    per-job rows and the identical shared mfree, tick after tick."""
    powers, speeds = (1.0, 2.0), (1.0, 1.0)
    T, M, E = 4, 2, 64
    job_a = _chain_job([3, 5])
    job_b = _chain_job([4, 2])
    ia = pack(Instance(jobs=(job_a,), powers_kw=powers, speeds=speeds),
              pad_tasks=T)
    ib = pack(Instance(jobs=(job_b,), powers_kw=powers, speeds=speeds),
              pad_tasks=T)
    pad = jax.tree.map(lambda x: x[0], padding_rows(1, T, M))
    dirty = jnp.zeros((E,), bool)
    budget = jnp.full((3,), 10**6, jnp.int32)

    def fresh(insts):
        pool = _stack_insts(insts)
        cp = jnp.stack([downstream_critical_path(i) for i in insts])
        lstate = LaneState(jnp.zeros((3, T), bool),
                           jnp.zeros((3, T), jnp.int32),
                           jnp.zeros((3, T), jnp.int32),
                           jnp.zeros((3, T), jnp.int32))
        return pool, cp, lstate, jnp.zeros((M,), jnp.int32)

    # Arrangement 1: [A, B, pad], priority A > B.  Arrangement 2: the same
    # jobs shuffled to lanes [B, pad, A], priority still A > B.
    pool1, cp1, ls1, mf1 = fresh([ia, ib, pad])
    pool2, cp2, ls2, mf2 = fresh([ib, pad, ia])
    order1 = jnp.asarray([0, 1, 2], jnp.int32)
    order2 = jnp.asarray([2, 0, 1], jnp.int32)
    for t in range(10):
        ls1, mf1, done1, comp1 = _pool_tick_shared(
            pool1, cp1, ls1, mf1, dirty, budget, jnp.int32(t), order1,
            machine_rule="earliest_finish")
        ls2, mf2, done2, comp2 = _pool_tick_shared(
            pool2, cp2, ls2, mf2, dirty, budget, jnp.int32(t), order2,
            machine_rule="earliest_finish")
        np.testing.assert_array_equal(np.asarray(mf1), np.asarray(mf2),
                                      err_msg=f"t={t} mfree")
        for f in LaneState._fields:
            x1, x2 = np.asarray(getattr(ls1, f)), np.asarray(getattr(ls2, f))
            np.testing.assert_array_equal(x1[0], x2[2],
                                          err_msg=f"t={t} job A {f}")
            np.testing.assert_array_equal(x1[1], x2[0],
                                          err_msg=f"t={t} job B {f}")
        assert bool(done1[0]) == bool(done2[2])
        assert bool(done1[1]) == bool(done2[0])
        assert int(comp1[0]) == int(comp2[2])
        assert int(comp1[1]) == int(comp2[0])


def test_engine_priority_is_admission_order_not_lane_index():
    """Engine-level corollary: with more jobs than lanes, lane reuse means
    later jobs land on arbitrary physical lanes — the run must still be a
    pure function of the seed (replay-identical), with evictions validated
    against the shared fleet throughout."""
    cfg = StreamConfig(arrivals="poisson", rate=0.08, horizon=192,
                       n_lanes=3, n_machines=2, seed=31, shared_fleet=True)
    r1, r2 = simulate_stream(cfg), simulate_stream(cfg)
    assert r1.events == r2.events


# ---------------------------------------------------------------------------
# Admission-policy hook.
# ---------------------------------------------------------------------------

def test_scpf_admits_short_critical_path_first():
    """Backlog of two t=0 jobs on ONE lane: FIFO admits rid order; scpf
    admits the short-critical-path job first."""
    jobs = [_chain_job([10, 10]), _chain_job([2])]     # cp 20 vs cp 2
    fifo = _one_machine_engine(False, n_lanes=1).run(
        [dataclasses.replace(j) for j in jobs])
    scpf = _one_machine_engine(False, n_lanes=1, admission="scpf").run(
        [dataclasses.replace(j) for j in jobs])
    assert all(sj.finished for sj in fifo + scpf)
    assert fifo[0].admitted < fifo[1].admitted, "FIFO: rid 0 first"
    assert scpf[1].admitted < scpf[0].admitted, "scpf: short job first"


def test_scpf_never_admits_future_arrivals():
    """The policy hook only reorders the READY prefix: a short job that has
    not arrived yet cannot jump an already-arrived long one."""
    jobs = [_chain_job([10, 10], arrival=0), _chain_job([2], arrival=50)]
    scpf = _one_machine_engine(False, n_lanes=1, admission="scpf").run(
        [dataclasses.replace(j) for j in jobs])
    assert scpf[0].admitted == 0
    assert scpf[1].admitted >= 50


def test_admission_policy_validation():
    with pytest.raises(ValueError, match="admission policy"):
        StreamConfig(admission="nope").validate()
    with pytest.raises(ValueError, match="admission policy"):
        _one_machine_engine(False, admission="nope")
