"""Core FJSP layer: instances, objectives, decoders, solvers.

Property tests (hypothesis) pin the feasibility invariants of the SGS
decoder and timing sweep; the exact oracle certifies optimality on tiny
instances (replacing the paper's CP-SAT ground truth).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import generate_instance, pack, synthesize, validate
from repro.core.carbon import constant, sample_window
from repro.core.decoder import sgs, timing_sweep, upward_rank
from repro.core.instance import DAG_SHAPES, Job, Instance
from repro.core.objectives import (carbon, energy, evaluate, makespan,
                                   utilization)
from repro.core.validate import check_feasible_np, total_violations as violations
from repro.core.solvers import solve_bilevel, solve_ga, solve_sa
from repro.core.solvers.annealing import SAConfig
from repro.core.solvers.common import decode_full
from repro.core.solvers.exact import exact_carbon, exact_makespan
from repro.core.solvers.genetic import GAConfig


def _trace_cum(rng, horizon=600, region="AU-SA"):
    tr = synthesize(region, days=10)
    return jnp.asarray(sample_window(tr, rng, horizon).cumulative())


# ---------------------------------------------------------------------------
# Instances + packing.
# ---------------------------------------------------------------------------

def test_pack_shapes_and_padding(rng):
    inst = generate_instance(rng, n_jobs=4, k_tasks=3, n_machines=5,
                             heterogeneous=True)
    p = pack(inst, pad_tasks=20)
    assert p.T == 20 and p.M == 5
    assert int(p.task_mask.sum()) == 12
    assert bool(p.allowed[12:, 0].all())          # padding on machine 0
    # topological indexing: predecessors have smaller index
    pr = np.asarray(p.pred)
    assert not np.triu(pr).any()


def test_hetero_durations_scale(rng):
    inst = generate_instance(rng, n_jobs=2, k_tasks=2, heterogeneous=True)
    d = inst.durations_matrix()
    # slowest machine (speed 1/3) takes ~3x the baseline machine (speed 1)
    assert (d[:, 0] >= d[:, 2]).all() and (d[:, 4] <= d[:, 2]).all()


# ---------------------------------------------------------------------------
# Feasibility properties of the decoders (hypothesis).
# ---------------------------------------------------------------------------

@settings(deadline=None)
@given(seed=st.integers(0, 10_000), k=st.integers(2, 5),
       n=st.integers(2, 5), rule=st.sampled_from(
           ["earliest_finish", "min_energy", "fixed"]))
def test_sgs_always_feasible(seed, k, n, rule):
    rng = np.random.default_rng(seed)
    inst = generate_instance(rng, n_jobs=n, k_tasks=k, n_machines=3,
                             heterogeneous=bool(seed % 2))
    p = pack(inst)
    prio = jnp.asarray(rng.normal(size=p.T), jnp.float32)
    assign = jnp.asarray(rng.integers(0, 3, p.T), jnp.int32)
    dec = sgs(p, prio, assign, machine_rule=rule)
    assert int(violations(p, dec.start, dec.assign)) == 0
    assert not check_feasible_np(p, dec.start, dec.assign)


@settings(deadline=None)
@given(seed=st.integers(0, 10_000))
def test_timing_sweep_feasible_and_monotone(seed):
    rng = np.random.default_rng(seed)
    inst = generate_instance(rng, n_jobs=3, k_tasks=4, n_machines=3)
    p = pack(inst)
    cum = _trace_cum(rng)
    dec = sgs(p, jnp.asarray(rng.normal(size=p.T), jnp.float32))
    ms0 = makespan(p, dec.start, dec.assign)
    c0 = carbon(p, dec.start, dec.assign, cum)
    deadline = ms0 + 20
    start2 = timing_sweep(p, dec.start, dec.assign, cum,
                          jnp.int32(deadline), sweeps=2)
    assert int(violations(p, start2, dec.assign)) == 0
    assert int(makespan(p, start2, dec.assign)) <= int(deadline)
    assert float(carbon(p, start2, dec.assign, cum)) <= float(c0) + 1e-3


@settings(deadline=None)
@given(seed=st.integers(0, 10_000), slack=st.integers(0, 40))
def test_timing_sweep_docstring_invariants(seed, slack):
    """What the timing_sweep docstring promises: carbon is monotone
    non-increasing as sweeps stack, feasibility (shared validator) is
    preserved, and the deadline is never exceeded."""
    rng = np.random.default_rng(seed)
    inst = generate_instance(rng, n_jobs=3, k_tasks=4, n_machines=3,
                             heterogeneous=bool(seed % 2))
    p = pack(inst)
    cum = _trace_cum(rng)
    dec = sgs(p, jnp.asarray(rng.normal(size=p.T), jnp.float32))
    deadline = jnp.int32(int(makespan(p, dec.start, dec.assign)) + slack)
    prev = float(carbon(p, dec.start, dec.assign, cum))
    for sweeps in (1, 2, 3):
        s = timing_sweep(p, dec.start, dec.assign, cum, deadline,
                         sweeps=sweeps)
        rep = validate.violation_report(p, s, dec.assign, deadline)
        assert all(int(v) == 0 for v in rep)     # feasible incl. deadline
        assert not validate.check_feasible_np(p, s, dec.assign,
                                              int(deadline))
        c = float(carbon(p, s, dec.assign, cum))
        assert c <= prev + 1e-3                  # monotone across sweeps
        prev = c


def test_upward_rank_tops_roots(rng):
    inst = generate_instance(rng, n_jobs=1, k_tasks=4, shape="chain")
    p = pack(inst)
    r = np.asarray(upward_rank(p))
    assert r[0] == r[:4].max()        # chain root has the longest path


# ---------------------------------------------------------------------------
# Objectives.
# ---------------------------------------------------------------------------

def test_objectives_hand_example():
    # 2 tasks chained on 1 machine: dur 2 then 3, intensity constant 100.
    job = Job(arrival=0, base_durations=(2, 3), edges=((0, 1),))
    inst = Instance(jobs=(job,), powers_kw=(2.0,), speeds=(1.0,))
    p = pack(inst)
    cum = jnp.asarray(constant(100.0, 50).cumulative())
    start = jnp.asarray([0, 2], jnp.int32)
    assign = jnp.zeros(2, jnp.int32)
    obj = evaluate(p, start, assign, cum)
    assert int(obj.makespan) == 5
    assert float(obj.energy) == pytest.approx(2.0 * 5 * 0.25)
    assert float(obj.carbon) == pytest.approx(2.0 * 5 * 0.25 * 100.0)
    assert float(utilization(p, start, assign)) == pytest.approx(1.0)


def test_violations_detects_each_constraint():
    job = Job(arrival=2, base_durations=(2, 2), edges=((0, 1),))
    inst = Instance(jobs=(job,), powers_kw=(1.0, 1.0), speeds=(1.0, 1.0))
    p = pack(inst)
    ok = jnp.asarray([2, 4], jnp.int32), jnp.asarray([0, 1], jnp.int32)
    assert int(violations(p, *ok)) == 0
    # arrival violation
    assert int(violations(p, jnp.asarray([0, 4], jnp.int32), ok[1])) > 0
    # dependency violation
    assert int(violations(p, jnp.asarray([2, 3], jnp.int32), ok[1])) > 0
    # overlap violation (same machine, same time)
    assert int(violations(p, jnp.asarray([2, 2], jnp.int32),
                          jnp.asarray([0, 0], jnp.int32))) > 0


# ---------------------------------------------------------------------------
# Solvers vs. the exact oracle (the CP-SAT stand-in).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("solver", ["sa", "ga"])
def test_solver_reaches_exact_makespan(solver, rng):
    inst = generate_instance(np.random.default_rng(7), n_jobs=2, k_tasks=2,
                             n_machines=2, heterogeneous=True,
                             arrival_horizon=1)
    p = pack(inst)
    opt = exact_makespan(p)
    cum = _trace_cum(np.random.default_rng(7))
    fn = solve_sa if solver == "sa" else solve_ga
    cfgs = dict(sa=SAConfig(pop=64, iters=120), ga=GAConfig(pop=64, gens=80))
    out = fn(p, cum, jnp.int32(1 << 27), jax.random.key(1),
             objective="makespan", machine_rule="earliest_finish",
             cfg=cfgs[solver])
    res = decode_full(p, cum, jnp.int32(1 << 27), out.prio, out.assign,
                      objective="makespan",
                      machine_rule="earliest_finish", sweeps=0)
    assert int(res.makespan) == opt


def test_bilevel_matches_exact_carbon_on_tiny():
    rng = np.random.default_rng(3)
    job = Job(arrival=0, base_durations=(2, 2), edges=((0, 1),))
    inst = Instance(jobs=(job,), powers_kw=(1.0, 1.0), speeds=(1.0, 1.0))
    p = pack(inst)
    tr = synthesize("AU-SA", days=2)
    cum_np = sample_window(tr, rng, 16).cumulative()
    cum = jnp.asarray(cum_np)
    res = solve_bilevel(p, cum, jax.random.key(0), objective="carbon",
                        stretch=2.0, cfg1=SAConfig(pop=64, iters=100),
                        cfg2=SAConfig(pop=64, iters=100))
    deadline = int(res.deadline)
    c_exact, _, _ = exact_carbon(p, cum_np, deadline)
    assert float(res.optimized.carbon) <= c_exact * 1.02 + 1e-6


def test_bilevel_invariants(rng):
    inst = generate_instance(np.random.default_rng(11), n_jobs=6, k_tasks=4,
                             n_machines=5, heterogeneous=True)
    p = pack(inst)
    cum = _trace_cum(np.random.default_rng(11), horizon=800)
    res = solve_bilevel(p, cum, jax.random.key(2), objective="carbon",
                        stretch=1.5, cfg1=SAConfig(pop=48, iters=60),
                        cfg2=SAConfig(pop=48, iters=60))
    # savings never negative (warm start guard), deadline respected
    assert float(res.carbon_savings) >= -1e-6
    assert int(res.optimized.makespan) <= int(res.deadline)
    assert not check_feasible_np(p, np.asarray(res.optimized.start),
                                 np.asarray(res.optimized.assign))


def test_constant_trace_carbon_equals_energy_times_intensity(rng):
    inst = generate_instance(np.random.default_rng(5), n_jobs=3, k_tasks=3)
    p = pack(inst)
    cum = jnp.asarray(constant(250.0, 600).cumulative())
    dec = sgs(p, upward_rank(p))
    c = float(carbon(p, dec.start, dec.assign, cum))
    e = float(energy(p, dec.assign))
    assert c == pytest.approx(e * 250.0, rel=1e-5)
