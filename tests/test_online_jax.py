"""Batched JAX online dispatcher vs the sequential numpy oracle.

The `online_jax` scan simulator must reproduce `online.py` *exactly* —
same (start, assign) arrays — on every DAG shape, homogeneous and
heterogeneous machine menus, and across the gate-policy grid.  Property
tests (hypothesis) randomize; the parametrized tests pin fixed seeds so the
equivalence is exercised even without hypothesis installed.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import generate_instance, pack, stack_packed, synthesize, validate
from repro.core.carbon import sample_window
from repro.core.instance import DAG_SHAPES
from repro.core.objectives import evaluate
from repro.core.solvers.online import (_critical_path, online_carbon_gated,
                                       online_greedy)
from repro.core.solvers.online_jax import (downstream_critical_path,
                                           dirty_mask, online_carbon_gated_jax,
                                           online_greedy_jax, policy_grid,
                                           sweep_policies)

HORIZON = 700


def _case(seed, shape, hetero, n_jobs=4, k_tasks=3, n_machines=3):
    rng = np.random.default_rng(seed)
    inst = generate_instance(rng, n_jobs=n_jobs, k_tasks=k_tasks,
                             n_machines=n_machines, heterogeneous=hetero,
                             shape=shape)
    p = pack(inst)
    w = sample_window(synthesize("AU-SA", days=10), rng, HORIZON)
    return p, w


def _assert_equiv(p, w, theta, window, stretch,
                  machine_rule="earliest_finish"):
    s0, a0 = online_greedy(p, machine_rule=machine_rule)
    g = online_greedy_jax(p, HORIZON, machine_rule=machine_rule)
    assert bool(np.asarray(g.scheduled | ~p.task_mask).all())
    np.testing.assert_array_equal(s0, np.asarray(g.start))
    np.testing.assert_array_equal(a0, np.asarray(g.assign))

    sg, ag = online_carbon_gated(p, w.intensity, theta=theta, window=window,
                                 stretch=stretch, machine_rule=machine_rule)
    c = online_carbon_gated_jax(p, w.intensity, theta=theta, window=window,
                                stretch=stretch, machine_rule=machine_rule)
    np.testing.assert_array_equal(sg, np.asarray(c.start))
    np.testing.assert_array_equal(ag, np.asarray(c.assign))
    # and both are validator-clean (Eqs. 4-8)
    assert int(validate.total_violations(p, c.start, c.assign)) == 0


@pytest.mark.parametrize("rule", ["earliest_finish", "min_energy"])
@pytest.mark.parametrize("shape", DAG_SHAPES)
@pytest.mark.parametrize("seed,hetero", [(0, False), (1, True)])
def test_online_jax_matches_numpy_fixed_seeds(seed, shape, hetero, rule):
    p, w = _case(seed, shape, hetero)
    _assert_equiv(p, w, theta=0.4, window=96, stretch=1.5, machine_rule=rule)


def test_min_energy_rule_saves_energy_on_hetero():
    """Fixed-seed regression: min-energy dispatch picks the cheaper machine
    per decision, which on these heterogeneous seeds yields lower total
    energy than earliest-finish.  (Not a universal dominance — greedy
    occupancy effects can invert it — so failures here after input changes
    mean re-pin the seeds, not a dispatcher bug.)"""
    from repro.core.objectives import energy
    for seed in range(4):
        p, _ = _case(seed, None, hetero=True, n_jobs=5, k_tasks=3,
                     n_machines=5)
        ge = online_greedy_jax(p, HORIZON, machine_rule="earliest_finish")
        gm = online_greedy_jax(p, HORIZON, machine_rule="min_energy")
        assert bool(np.asarray(gm.scheduled | ~p.task_mask).all())
        assert float(energy(p, gm.assign)) <= float(energy(p, ge.assign)) + 1e-5


# derandomize: exact (start, assign) equality is float-fragile only in the
# astronomically thin band where intensity[t] sits within a float32 ulp of
# the float64 np.quantile threshold — a fixed example set keeps the property
# meaningful without that band ever flaking CI on a fresh random seed.
@settings(max_examples=25, deadline=None, derandomize=True)
@given(seed=st.integers(0, 10_000),
       shape=st.sampled_from(DAG_SHAPES),
       hetero=st.booleans(),
       theta=st.sampled_from([0.25, 0.3, 0.5, 0.75]),
       window=st.sampled_from([24, 48, 96]),
       stretch=st.sampled_from([1.25, 1.5, 2.0]),
       rule=st.sampled_from(["earliest_finish", "min_energy"]))
def test_online_jax_matches_numpy_property(seed, shape, hetero, theta,
                                           window, stretch, rule):
    p, w = _case(seed, shape, hetero)
    _assert_equiv(p, w, theta, window, stretch, machine_rule=rule)


def test_critical_path_matches_numpy():
    for seed in range(5):
        p, _ = _case(seed, DAG_SHAPES[seed % 3], bool(seed % 2))
        dur = np.asarray(p.dur)
        cp_np = _critical_path(dur, np.asarray(p.allowed), np.asarray(p.pred),
                               np.asarray(p.task_mask))
        cp_jax = np.asarray(downstream_critical_path(p))
        np.testing.assert_array_equal(cp_np, cp_jax)


def test_dirty_mask_matches_np_quantile():
    rng = np.random.default_rng(3)
    w = sample_window(synthesize("CAL", days=10), rng, 300)
    inten = w.intensity
    for theta in (0.25, 0.4, 0.5, 0.9):
        for window in (16, 96):
            ref = np.zeros(len(inten), bool)
            for t in range(len(inten)):
                win = inten[t:min(t + window, len(inten))]
                ref[t] = inten[t] > np.quantile(win, theta) + 1e-9
            got = np.asarray(dirty_mask(jnp.asarray(inten),
                                        jnp.float32(theta),
                                        jnp.int32(window),
                                        max_window=window))
            np.testing.assert_array_equal(ref, got)


def test_sweep_matches_single_instance_calls():
    packs, intens = [], []
    for seed in range(3):
        p, w = _case(seed, DAG_SHAPES[seed], hetero=bool(seed % 2),
                     n_jobs=3, k_tasks=3)
        packs.append(p)
        intens.append(w.intensity)
    batch = stack_packed(packs)
    inten = jnp.asarray(np.stack(intens))
    thetas, windows, stretches = [0.3, 0.5], [48, 96], [1.5]
    res = sweep_policies(batch, inten, thetas, windows, stretches)
    th, wi, sx = (np.asarray(a) for a in
                  policy_grid(thetas, windows, stretches))
    assert res.gated.start.shape[:2] == (3, len(th))
    for b, p in enumerate(packs):
        g = online_greedy_jax(p, HORIZON)
        np.testing.assert_array_equal(np.asarray(g.start),
                                      np.asarray(res.greedy.start[b]))
        for j in range(len(th)):
            c = online_carbon_gated_jax(p, intens[b], theta=float(th[j]),
                                        window=int(wi[j]),
                                        stretch=float(sx[j]))
            np.testing.assert_array_equal(np.asarray(c.start),
                                          np.asarray(res.gated.start[b, j]))
            np.testing.assert_array_equal(np.asarray(c.assign),
                                          np.asarray(res.gated.assign[b, j]))
    assert bool(np.asarray(res.gated.scheduled
                           | ~batch.task_mask[:, None, :]).all())


def test_gated_jax_saves_carbon_and_respects_stretch():
    rng = np.random.default_rng(5)
    savings = []
    for seed in range(3):
        p, w = _case(seed, None, False, n_jobs=6, k_tasks=4, n_machines=5)
        cum = jnp.asarray(w.cumulative())
        g = online_greedy_jax(p, HORIZON)
        c = online_carbon_gated_jax(p, w.intensity, theta=0.4, stretch=1.5)
        base = evaluate(p, g.start, g.assign, cum)
        gated = evaluate(p, c.start, c.assign, cum)
        savings.append(1 - float(gated.carbon) / float(base.carbon))
        # critical-path gating bounds makespan up to machine-contention tails
        assert int(gated.makespan) <= 1.5 * int(base.makespan) * 1.10 + 1
    assert np.mean(savings) > 0.05
