"""Batched JAX online dispatcher vs the sequential numpy oracle.

The `online_jax` scan simulator must reproduce `online.py` *exactly* —
same (start, assign) arrays — on every scenario DAG family (chain, fanout,
diamond, layered, tpch), every fleet menu, and across the gate-policy grid.
Cases come from the shared seeded builders in ``tests/strategies``
(replacing this file's old ad-hoc ``_case``); everything is padded to ONE
static (T, M) so the whole module reuses a single XLA program per
dispatcher — padding is inert by the PackedInstance contract
(property-tested in ``tests/test_scenarios.py``).

Property tests (hypothesis) randomize; the parametrized tests pin fixed
seeds so the equivalence is exercised even without hypothesis installed.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import validate
from repro.core.objectives import evaluate
from repro.core.instance import stack_packed
from repro.core.solvers.online import (_critical_path, online_carbon_gated,
                                       online_greedy)
from repro.core.solvers.online_jax import (downstream_critical_path,
                                           dirty_mask, online_carbon_gated_jax,
                                           online_greedy_jax, policy_grid,
                                           sweep_policies)
from repro.scenarios import FAMILY_NAMES, FLEET_NAMES
from tests.strategies import scenario_case, family_names, fleet_names, seeds

HORIZON = 700
# One static shape for every case in this module (largest draw: diamond at
# width 2 / depth 3 x 5 jobs in the min-energy test = 60 tasks).
PAD_T, PAD_M = 64, 5


def _case(seed, family=None, fleet=None, **kw):
    kw.setdefault("n_jobs", 4)
    kw.setdefault("width", 2)
    kw.setdefault("depth", 2)
    kw.setdefault("n_machines", 3)
    return scenario_case(seed, family=family, fleet=fleet, horizon=HORIZON,
                         pad_tasks=PAD_T, pad_machines=PAD_M, **kw)


def _assert_equiv(p, w, theta, window, stretch,
                  machine_rule="earliest_finish"):
    s0, a0 = online_greedy(p, machine_rule=machine_rule)
    g = online_greedy_jax(p, HORIZON, machine_rule=machine_rule)
    assert bool(np.asarray(g.scheduled | ~p.task_mask).all())
    np.testing.assert_array_equal(s0, np.asarray(g.start))
    np.testing.assert_array_equal(a0, np.asarray(g.assign))

    sg, ag = online_carbon_gated(p, w.intensity, theta=theta, window=window,
                                 stretch=stretch, machine_rule=machine_rule)
    c = online_carbon_gated_jax(p, w.intensity, theta=theta, window=window,
                                stretch=stretch, machine_rule=machine_rule)
    np.testing.assert_array_equal(sg, np.asarray(c.start))
    np.testing.assert_array_equal(ag, np.asarray(c.assign))
    # and both are validator-clean (Eqs. 4-8)
    assert int(validate.total_violations(p, c.start, c.assign)) == 0


@pytest.mark.parametrize("rule", ["earliest_finish", "min_energy"])
@pytest.mark.parametrize("family", FAMILY_NAMES)
@pytest.mark.parametrize("seed,fleet", [(0, "homog"), (1, "tiered")])
def test_online_jax_matches_numpy_fixed_seeds(seed, family, fleet, rule):
    p, w = _case(seed, family, fleet)
    _assert_equiv(p, w, theta=0.4, window=96, stretch=1.5, machine_rule=rule)


def test_min_energy_rule_saves_energy_on_hetero():
    """Fixed-seed regression: min-energy dispatch picks the cheaper machine
    per decision, which on these heterogeneous seeds yields lower total
    energy than earliest-finish.  (Not a universal dominance — greedy
    occupancy effects can invert it — so failures here after input changes
    mean re-pin the seeds, not a dispatcher bug.)"""
    from repro.core.objectives import energy
    for seed in range(4):
        p, _ = _case(seed, None, fleet="tiered", n_jobs=5, depth=3,
                     n_machines=5)
        ge = online_greedy_jax(p, HORIZON, machine_rule="earliest_finish")
        gm = online_greedy_jax(p, HORIZON, machine_rule="min_energy")
        assert bool(np.asarray(gm.scheduled | ~p.task_mask).all())
        assert float(energy(p, gm.assign)) <= float(energy(p, ge.assign)) + 1e-5


# derandomize: exact (start, assign) equality is float-fragile only in the
# astronomically thin band where intensity[t] sits within a float32 ulp of
# the float64 np.quantile threshold — a fixed example set keeps the property
# meaningful without that band ever flaking CI on a fresh random seed.
@settings(max_examples=25, deadline=None, derandomize=True)
@given(seed=seeds(),
       family=family_names(),
       fleet=fleet_names(),
       theta=st.sampled_from([0.25, 0.3, 0.5, 0.75]),
       window=st.sampled_from([24, 48, 96]),
       stretch=st.sampled_from([1.25, 1.5, 2.0]),
       rule=st.sampled_from(["earliest_finish", "min_energy"]))
def test_online_jax_matches_numpy_property(seed, family, fleet, theta,
                                           window, stretch, rule):
    p, w = _case(seed, family, fleet)
    _assert_equiv(p, w, theta, window, stretch, machine_rule=rule)


def test_critical_path_matches_numpy():
    for seed in range(5):
        p, _ = _case(seed, FAMILY_NAMES[seed % len(FAMILY_NAMES)],
                     FLEET_NAMES[seed % len(FLEET_NAMES)])
        dur = np.asarray(p.dur)
        cp_np = _critical_path(dur, np.asarray(p.allowed), np.asarray(p.pred),
                               np.asarray(p.task_mask))
        cp_jax = np.asarray(downstream_critical_path(p))
        np.testing.assert_array_equal(cp_np, cp_jax)


def test_dirty_mask_matches_np_quantile():
    from repro.core import synthesize
    from repro.core.carbon import sample_window
    rng = np.random.default_rng(3)
    w = sample_window(synthesize("CAL", days=10), rng, 300)
    inten = w.intensity
    for theta in (0.25, 0.4, 0.5, 0.9):
        for window in (16, 96):
            ref = np.zeros(len(inten), bool)
            for t in range(len(inten)):
                win = inten[t:min(t + window, len(inten))]
                ref[t] = inten[t] > np.quantile(win, theta) + 1e-9
            got = np.asarray(dirty_mask(jnp.asarray(inten),
                                        jnp.float32(theta),
                                        jnp.int32(window),
                                        max_window=window))
            np.testing.assert_array_equal(ref, got)


def test_sweep_matches_single_instance_calls():
    packs, intens = [], []
    for seed in range(3):
        p, w = _case(seed, FAMILY_NAMES[seed], FLEET_NAMES[seed % 3],
                     n_jobs=3)
        packs.append(p)
        intens.append(w.intensity)
    batch = stack_packed(packs)
    inten = jnp.asarray(np.stack(intens))
    thetas, windows, stretches = [0.3, 0.5], [48, 96], [1.5]
    res = sweep_policies(batch, inten, thetas, windows, stretches)
    th, wi, sx = (np.asarray(a) for a in
                  policy_grid(thetas, windows, stretches))
    assert res.gated.start.shape[:2] == (3, len(th))
    for b, p in enumerate(packs):
        g = online_greedy_jax(p, HORIZON)
        np.testing.assert_array_equal(np.asarray(g.start),
                                      np.asarray(res.greedy.start[b]))
        for j in range(len(th)):
            c = online_carbon_gated_jax(p, intens[b], theta=float(th[j]),
                                        window=int(wi[j]),
                                        stretch=float(sx[j]))
            np.testing.assert_array_equal(np.asarray(c.start),
                                          np.asarray(res.gated.start[b, j]))
            np.testing.assert_array_equal(np.asarray(c.assign),
                                          np.asarray(res.gated.assign[b, j]))
    assert bool(np.asarray(res.gated.scheduled
                           | ~batch.task_mask[:, None, :]).all())


def test_gated_jax_saves_carbon_and_respects_stretch():
    savings = []
    for seed in range(3):
        p, w = _case(seed, "layered", "homog", n_jobs=6, width=3,
                     n_machines=5)
        cum = jnp.asarray(w.cumulative())
        g = online_greedy_jax(p, HORIZON)
        c = online_carbon_gated_jax(p, w.intensity, theta=0.4, stretch=1.5)
        base = evaluate(p, g.start, g.assign, cum)
        gated = evaluate(p, c.start, c.assign, cum)
        savings.append(1 - float(gated.carbon) / float(base.carbon))
        # critical-path gating bounds makespan up to machine-contention tails
        assert int(gated.makespan) <= 1.5 * int(base.makespan) * 1.10 + 1
    assert np.mean(savings) > 0.05
