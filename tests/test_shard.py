"""Instance-axis sharding: bit-exact parity with the single-device paths.

The contract :mod:`repro.shard` ships (the ISSUE's headline): for every
public sharded entry point — gated dispatch sweep, offline bi-level bound,
gate-policy training, hard-theta evaluation — the sharded-on-N-devices
output equals the single-device output **exactly**, across all scenario
families x fleets, for every device count, with the batch axis padded to a
device multiple by inert rows.  Two layers of tests:

* in-process tests run against however many devices the platform exposes
  (1 in a plain tier-1 run; 8 under the CI job's
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``) — parity vs. the
  single-device reference plus device-count-invariance metamorphic checks
  (1/2/4/8 all identical);
* one subprocess test forces 8 fake host devices regardless, so multi-
  device parity is exercised even in a plain tier-1 run (device count
  locks at first jax init, hence the spawn) — via the shared
  :func:`tests.harness.run_forced_devices` spawn path, the same one the
  multi-process suite (``tests/test_distributed.py``) builds on.

Property tests (hypothesis) randomize the drawn cells; parametrized
fixed-seed tests keep every family x fleet covered when hypothesis is
absent.  One static padded shape per module (one XLA program per entry
point).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core.solvers import solve_bilevel_batch
from repro.core.solvers.annealing import SAConfig
from repro.core.solvers.online_jax import sweep_policies
from repro.learn import LearnConfig, evaluate_theta, train_gate
from repro.scenarios import FAMILY_NAMES, FLEET_NAMES
from repro.shard import (bilevel_sharded, dispatch_sharded,
                         eval_theta_sharded, train_sharded)
from tests.harness import run_forced_devices
from tests.strategies import scenario_case, seeds, family_names, fleet_names

# One static shape for every case in this module (diamond at n_jobs=3,
# width<=2, depth<=2 is the driver: 3 * 2 * (2 + 2) = 24 tasks).
PAD_T, PAD_M = 24, 5
HORIZON = 500
N_JOBS = 3

# Device counts to exercise: every power of two the platform exposes.
DEVICE_COUNTS = [d for d in (1, 2, 4, 8) if d <= jax.device_count()]

THETAS, WINDOWS, STRETCHES = (0.3, 0.6), (48,), (1.5,)
SA_TINY = SAConfig(pop=8, iters=10, sweeps=1)


def _batch_case(cases):
    """Stack scenario_case instances (shared static shape) + traces."""
    from repro.core.instance import PackedInstance, stack_packed
    packs, intens, cums = [], [], []
    for seed, family, fleet in cases:
        p, w = scenario_case(seed, family=family, fleet=fleet,
                             n_jobs=N_JOBS, pad_tasks=PAD_T,
                             pad_machines=PAD_M, horizon=HORIZON)
        packs.append(p)
        intens.append(np.asarray(w.intensity))
        cums.append(np.asarray(w.cumulative()))
    return (stack_packed(packs), jnp.asarray(np.stack(intens)),
            jnp.asarray(np.stack(cums)))


def _assert_tree_equal(a, b, ctx):
    flat_a, _ = jax.tree.flatten(a)
    flat_b, _ = jax.tree.flatten(b)
    assert len(flat_a) == len(flat_b), ctx
    for i, (x, y) in enumerate(zip(flat_a, flat_b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"{ctx} [leaf {i}]")


# ---------------------------------------------------------------------------
# Dispatch sweep: sharded == single-device, all families x fleets.
# ---------------------------------------------------------------------------

def _assert_dispatch_parity(cases, ctx):
    batch, inten, _ = _batch_case(cases)
    ref = sweep_policies(batch, inten, THETAS, WINDOWS, STRETCHES)
    results = {}
    for d in DEVICE_COUNTS:
        got = dispatch_sharded(batch, inten, THETAS, WINDOWS, STRETCHES,
                               devices=d)
        _assert_tree_equal(ref, got, f"{ctx} devices={d}")
        results[d] = got
    # metamorphic: every device count produced the identical tree
    for d in DEVICE_COUNTS[1:]:
        _assert_tree_equal(results[DEVICE_COUNTS[0]], results[d],
                           f"{ctx} invariance {DEVICE_COUNTS[0]} vs {d}")


@pytest.mark.parametrize("family", FAMILY_NAMES)
@pytest.mark.parametrize("fleet", FLEET_NAMES)
def test_dispatch_sharded_parity_fixed(family, fleet):
    # B=3 rows: not a multiple of 2/4/8, so every multi-device count also
    # exercises the inert batch-axis padding.
    cases = [(s, family, fleet) for s in range(3)]
    _assert_dispatch_parity(cases, f"{family}/{fleet}")


@settings(max_examples=10, deadline=None, derandomize=True)
@given(seed=seeds(), family=family_names(), fleet=fleet_names())
def test_dispatch_sharded_parity_property(seed, family, fleet):
    cases = [(seed + i, family if i else None, fleet if i else None)
             for i in range(3)]
    _assert_dispatch_parity(cases, f"drawn {family}/{fleet}/{seed}")


# ---------------------------------------------------------------------------
# Offline bi-level bound.
# ---------------------------------------------------------------------------

def test_bilevel_batch_size_independent():
    """The invariant bilevel_sharded's per-device dispatch rests on: a row
    solved alone is bit-identical to the same row solved in a batch."""
    cases = [(s, FAMILY_NAMES[s % 5], FLEET_NAMES[s % 3]) for s in range(4)]
    batch, _, cums = _batch_case(cases)
    keys = jax.random.split(jax.random.key(11), 4)
    kw = dict(objective="carbon", stretch=1.5, cfg1=SA_TINY, cfg2=SA_TINY)
    full = solve_bilevel_batch(batch, cums, keys, **kw)
    part = solve_bilevel_batch(
        *jax.tree.map(lambda x: x[1:3], (batch, cums, keys)), **kw)
    _assert_tree_equal(jax.tree.map(lambda x: x[1:3], full), part,
                       "rows 1:3 alone vs in batch")


def test_bilevel_sharded_parity():
    cases = [(s, FAMILY_NAMES[s % 5], FLEET_NAMES[s % 3]) for s in range(5)]
    batch, _, cums = _batch_case(cases)
    keys = jax.random.split(jax.random.key(3), 5)
    kw = dict(objective="carbon", stretch=1.5, cfg1=SA_TINY, cfg2=SA_TINY)
    ref = solve_bilevel_batch(batch, cums, keys, **kw)
    for d in DEVICE_COUNTS:
        got = bilevel_sharded(batch, cums, keys, devices=d, **kw)
        _assert_tree_equal(ref, got, f"bilevel devices={d} (B=5, padded)")


# ---------------------------------------------------------------------------
# Gate-policy training + hard evaluation.
# ---------------------------------------------------------------------------

def _train_case(n_rows=5, steps=8):
    cases = [(s, FAMILY_NAMES[s % 5], FLEET_NAMES[s % 3])
             for s in range(n_rows)]
    batch, inten, cums = _batch_case(cases)
    group = np.asarray([s % 2 for s in range(n_rows)])
    window = np.full(n_rows, WINDOWS[0], np.int32)
    theta0 = np.full(2, 0.5, np.float32)
    return batch, inten, cums, group, window, theta0, LearnConfig(steps=steps)


def test_train_sharded_parity():
    batch, inten, cums, group, window, theta0, cfg = _train_case()
    ref = train_gate(batch, inten, cums, group, window, 1.5, theta0, cfg)
    results = {}
    for d in DEVICE_COUNTS:
        got = train_sharded(batch, inten, cums, group, window, 1.5, theta0,
                            cfg, devices=d)
        _assert_tree_equal(tuple(ref), tuple(got), f"train devices={d}")
        results[d] = got
    for d in DEVICE_COUNTS[1:]:
        _assert_tree_equal(tuple(results[DEVICE_COUNTS[0]]),
                           tuple(results[d]),
                           f"train invariance {DEVICE_COUNTS[0]} vs {d}")


@settings(max_examples=6, deadline=None, derandomize=True)
@given(seed=seeds(), family=family_names(), fleet=fleet_names(),
       stretch=st.sampled_from((1.25, 1.5, 2.0)))
def test_train_sharded_parity_property(seed, family, fleet, stretch):
    cases = [(seed + i, family, fleet) for i in range(3)]
    batch, inten, cums = _batch_case(cases)
    group = np.asarray([0, 0, 1])
    window = np.full(3, WINDOWS[0], np.int32)
    theta0 = np.asarray([0.4, 0.6], np.float32)
    cfg = LearnConfig(steps=5)
    ref = train_gate(batch, inten, cums, group, window, stretch, theta0, cfg)
    for d in DEVICE_COUNTS:
        got = train_sharded(batch, inten, cums, group, window, stretch,
                            theta0, cfg, devices=d)
        _assert_tree_equal(
            tuple(ref), tuple(got),
            f"train {family}/{fleet}/{seed} S={stretch} devices={d}")


def test_eval_theta_sharded_parity():
    batch, inten, cums, group, window, theta0, _ = _train_case()
    theta = jnp.asarray(theta0)[group]
    ref = evaluate_theta(batch, inten, cums, theta, window, 1.5)
    for d in DEVICE_COUNTS:
        got = eval_theta_sharded(batch, inten, cums, theta, window, 1.5,
                                 devices=d)
        _assert_tree_equal(ref, got, f"eval devices={d}")


# ---------------------------------------------------------------------------
# The exactness lemma itself: seq_sum — the one explicitly-sequenced
# reduction every sharded program funnels through — is invariant under any
# device/row dealing, provided rows come back in canonical order (which is
# exactly what the tiled all_gather by mesh position guarantees), and its
# value is the one fixed left-to-right association.  PR 5 relied on this;
# here it is tested directly.
# ---------------------------------------------------------------------------

def _row_values(seed, family, fleet, n=64):
    """Realistic float32 per-row terms (carbon-intensity magnitudes with
    full mantissas) — the population whose reassociation would actually
    drift."""
    _, w = scenario_case(seed, family=family, fleet=fleet, n_jobs=N_JOBS,
                         pad_tasks=PAD_T, pad_machines=PAD_M,
                         horizon=HORIZON)
    return jnp.asarray(np.asarray(w.intensity, np.float32)[:n])


@settings(max_examples=15, deadline=None, derandomize=True)
@given(seed=seeds(), family=family_names(), fleet=fleet_names(),
       n_dev=st.sampled_from((1, 2, 4, 8)),
       perm_seed=st.integers(0, 2**16))
def test_seq_sum_invariant_under_device_permutation(seed, family, fleet,
                                                    n_dev, perm_seed):
    from repro.learn.train import seq_sum
    x = _row_values(seed, family, fleet)
    ref = np.asarray(seq_sum(x))
    shards = np.asarray(x).reshape(n_dev, -1)
    perm = np.random.default_rng(perm_seed).permutation(n_dev)
    # Deal row blocks onto devices in an arbitrary (permuted) order, then
    # reassemble in canonical order — the all_gather-by-mesh-position
    # step.  The reduction must not move by a single bit.
    dealt = shards[perm]
    canonical = np.concatenate(dealt[np.argsort(perm)])
    np.testing.assert_array_equal(np.asarray(x), canonical)
    got = np.asarray(seq_sum(jnp.asarray(canonical)))
    np.testing.assert_array_equal(ref, got,
                                  err_msg=f"n_dev={n_dev} perm={perm}")


@settings(max_examples=10, deadline=None, derandomize=True)
@given(seed=seeds(), family=family_names(), fleet=fleet_names())
def test_seq_sum_is_the_left_fold(seed, family, fleet):
    """seq_sum's value is the plain left-to-right fold — the single fixed
    association every device count reproduces."""
    from repro.learn.train import seq_sum
    x = _row_values(seed, family, fleet, n=32)
    acc = jnp.zeros_like(x[0])
    for i in range(int(x.shape[0])):
        acc = acc + x[i]
    np.testing.assert_array_equal(np.asarray(seq_sum(x)), np.asarray(acc))


# ---------------------------------------------------------------------------
# Batch-axis padding at the shard boundary.
# ---------------------------------------------------------------------------

def test_instance_mesh_rejects_overcommit():
    from repro.shard import instance_mesh
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        instance_mesh(jax.device_count() + 1)
    with pytest.raises(ValueError, match=">= 1"):
        instance_mesh(0)


def test_run_rows_sharded_pads_and_slices():
    """B=1 on every device count: maximal padding, still bit-exact."""
    batch, inten, _ = _batch_case([(0, "tpch", "mixed")])
    ref = sweep_policies(batch, inten, THETAS, WINDOWS, STRETCHES)
    for d in DEVICE_COUNTS:
        got = dispatch_sharded(batch, inten, THETAS, WINDOWS, STRETCHES,
                               devices=d)
        _assert_tree_equal(ref, got, f"B=1 devices={d}")


# ---------------------------------------------------------------------------
# sweep_structure(devices=...): the whole structure sweep end to end,
# including the learned-theta cells, bit-exact with the default path.
# ---------------------------------------------------------------------------

def test_sweep_sharded_bitexact_with_learn():
    from repro.scenarios import ScenarioConfig, SweepSpec, sweep_structure
    from repro.shard import sweep_sharded

    cells = tuple(
        ScenarioConfig(family=f, n_jobs=3, width=2, depth=1, n_machines=3,
                       fleet="tiered").validate()
        for f in ("chain", "layered"))
    spec = SweepSpec(cells=cells, instances_per_cell=2, horizon=HORIZON,
                     thetas=(0.3, 0.5), windows=(48,), stretches=(1.5,))
    learn = LearnConfig(steps=5)
    rows, meta = sweep_structure(spec, offline=False, learn=learn)
    # the sharded front door: devices=None == all local devices
    rows_s, meta_s = sweep_sharded(spec, offline=False, learn=learn)
    assert meta_s["devices"] == jax.device_count()
    assert rows_s == rows     # every rounded value identical, learned cells
    # included — the devices knob changes wall-clock, never a number


# ---------------------------------------------------------------------------
# Forced-8-device subprocess: multi-device parity even in a plain tier-1
# run.  Spawn mechanics (env, stdout protocol) live in tests/harness.py —
# the payload only computes and prints its JSON result.
# ---------------------------------------------------------------------------

PAYLOAD = r"""
import json
import numpy as np
import jax, jax.numpy as jnp
from repro.core import synthesize
from repro.core.carbon import sample_window
from repro.core.instance import pack, stack_packed
from repro.core.solvers.online_jax import sweep_policies
from repro.learn import LearnConfig, train_gate
from repro.scenarios import FAMILY_NAMES, FLEET_NAMES, ScenarioConfig, \
    sample_instance
from repro.shard import dispatch_sharded, train_sharded

# no tests.strategies here: the subprocess has no conftest, so the
# hypothesis soft-dep shim is unavailable — build cases directly.
year = synthesize("AU-SA", days=10)
packs, intens, cums = [], [], []
for s in range(5):
    rng = np.random.default_rng(s)
    cfg = ScenarioConfig(family=FAMILY_NAMES[s % 5],
                         fleet=FLEET_NAMES[s % 3], n_jobs=3, width=2,
                         depth=2, n_machines=3)
    packs.append(pack(sample_instance(rng, cfg), pad_tasks=24,
                      pad_machines=5))
    w = sample_window(year, rng, 500)
    intens.append(np.asarray(w.intensity))
    cums.append(np.asarray(w.cumulative()))
batch = stack_packed(packs)
inten = jnp.asarray(np.stack(intens)); cum = jnp.asarray(np.stack(cums))

ref = sweep_policies(batch, inten, (0.3, 0.6), (48,), (1.5,))
eq = lambda a, b: bool(jax.tree.all(jax.tree.map(
    lambda x, y: bool((np.asarray(x) == np.asarray(y)).all()), a, b)))
disp = {d: eq(ref, dispatch_sharded(batch, inten, (0.3, 0.6), (48,), (1.5,),
                                    devices=d)) for d in (1, 2, 4, 8)}
group = np.asarray([0, 0, 1, 1, 1]); window = np.full(5, 48, np.int32)
theta0 = np.full(2, 0.5, np.float32)
cfg = LearnConfig(steps=5)
tref = train_gate(batch, inten, cum, group, window, 1.5, theta0, cfg)
train = {d: eq(tuple(tref), tuple(train_sharded(
    batch, inten, cum, group, window, 1.5, theta0, cfg, devices=d)))
    for d in (1, 2, 4, 8)}
print(json.dumps({"devices": jax.device_count(), "dispatch": disp,
                  "train": train}))
"""


@pytest.mark.slow
def test_sharded_parity_on_8_forced_devices():
    res = run_forced_devices(PAYLOAD, devices=8, timeout=900)
    assert res["devices"] == 8
    assert all(res["dispatch"].values()), res
    assert all(res["train"].values()), res
