"""MPC rolling replanner: frozen-prefix invariant + feasibility + warm-start.

The property the replanner must never break: once a task has *started*
executing under the incumbent plan, no later replan may move or migrate it.
Checked across the per-replan plan history the solver returns.  With a
perfect forecast (scale = 0) the incumbent-fallback guard additionally
guarantees realized carbon never exceeds the day-ahead baseline plan's.

Cases come from the shared scenario builders in ``tests/strategies``
(chain/fanout/diamond/layered/tpch DAGs on every fleet menu), all padded to
ONE static (T, M) — including padded *machines* for the small fleets — so
the whole module reuses a single XLA program.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import validate
from repro.core.solvers.annealing import SAConfig
from repro.core.solvers.rolling import (MPCConfig, forecast_cum, solve_mpc,
                                        solve_mpc_batch)
from repro.core.instance import stack_packed
from tests.strategies import scenario_case, family_names, fleet_names, seeds

HORIZON = 320
# One static shape for every case (largest: diamond w2 d2 x 3 jobs = 24).
PAD_T, PAD_M = 24, 4

# One shared config so every test in the module reuses the same XLA program.
CFG = MPCConfig(every=24, n_replans=5, stretch=1.5,
                sa=SAConfig(pop=16, iters=16, sweeps=1),
                sa_phase1=SAConfig(pop=24, iters=40))


def _case(seed, family=None, fleet=None):
    p, w = scenario_case(seed, family=family, fleet=fleet, n_jobs=3,
                         width=2, depth=2, n_machines=3, horizon=HORIZON,
                         pad_tasks=PAD_T, pad_machines=PAD_M)
    return p, jnp.asarray(w.intensity), jnp.asarray(w.cumulative())


def _solve(p, truth, cum, seed, scale):
    return solve_mpc(p, truth, cum, jax.random.key(seed),
                     jax.random.key(1000 + seed), jnp.float32(scale),
                     cfg=CFG)


def _assert_invariants(p, res, every):
    start, assign = np.asarray(res.start), np.asarray(res.assign)
    # final plan feasible on the ORIGINAL instance, deadline included
    validate.assert_feasible_np(p, start, assign,
                                deadline=int(res.deadline), ctx="mpc final")
    # frozen prefix: tasks started before each boundary keep (start, assign)
    ps, pa = np.asarray(res.plans_start), np.asarray(res.plans_assign)
    mask = np.asarray(p.task_mask)
    for k in range(ps.shape[0] - 1):
        frozen = mask & (ps[k] < (k + 1) * every)
        np.testing.assert_array_equal(ps[k + 1][frozen], ps[k][frozen],
                                      err_msg=f"start moved at replan {k+1}")
        np.testing.assert_array_equal(pa[k + 1][frozen], pa[k][frozen],
                                      err_msg=f"assign moved at replan {k+1}")
    # the final plan is the last replan's plan
    np.testing.assert_array_equal(start, ps[-1])
    np.testing.assert_array_equal(assign, pa[-1])


@pytest.mark.parametrize("seed,fleet,scale", [(0, "homog", 0.0),
                                              (1, "tiered", 0.8),
                                              (2, "mixed", 1.5)])
def test_mpc_frozen_prefix_and_feasibility_fixed(seed, fleet, scale):
    p, truth, cum = _case(seed, fleet=fleet)
    res = _solve(p, truth, cum, seed, scale)
    _assert_invariants(p, res, CFG.every)


@settings(max_examples=10, deadline=None, derandomize=True)
@given(seed=seeds(), family=family_names(), fleet=fleet_names(),
       scale=st.sampled_from([0.0, 0.5, 1.0, 2.0]))
def test_mpc_frozen_prefix_property(seed, family, fleet, scale):
    p, truth, cum = _case(seed % 50, family=family, fleet=fleet)
    res = _solve(p, truth, cum, seed, scale)
    _assert_invariants(p, res, CFG.every)


def test_mpc_zero_noise_never_worse_than_baseline():
    """Perfect forecast: the incumbent-fallback guard makes realized carbon
    monotone across replans, so the final plan beats (or ties) the
    carbon-agnostic day-ahead baseline."""
    for seed in range(3):
        p, truth, cum = _case(seed + 20)
        res = _solve(p, truth, cum, seed, 0.0)
        assert float(res.realized.carbon) <= \
            float(res.baseline.carbon) * (1 + 1e-6), seed
        assert int(res.realized.makespan) <= int(res.deadline)


def test_mpc_batch_matches_single():
    ps, truths, cums = zip(*(_case(s) for s in (0, 1)))
    batch = stack_packed(ps)
    truths = jnp.stack(truths)
    cums = jnp.stack(cums)
    keys = jnp.stack([jax.random.key(0), jax.random.key(1)])
    fc_keys = jnp.stack([jax.random.key(1000), jax.random.key(1001)])
    out = solve_mpc_batch(batch, truths, cums, keys, fc_keys, 0.7, cfg=CFG)
    assert out.start.shape == (2, 2, ps[0].T)
    for b in range(2):
        for s in range(2):
            single = solve_mpc(ps[b], truths[b], cums[b], keys[b],
                               fc_keys[s], jnp.float32(0.7), cfg=CFG)
            np.testing.assert_array_equal(np.asarray(out.start[b, s]),
                                          np.asarray(single.start))
            np.testing.assert_array_equal(np.asarray(out.assign[b, s]),
                                          np.asarray(single.assign))


def test_forecast_cum_matches_trace_cumulative():
    _, truth, cum = _case(7)
    np.testing.assert_allclose(np.asarray(forecast_cum(truth)),
                               np.asarray(cum), rtol=2e-5)


def test_band_conditioned_theta_slope_zero_is_flat_gate():
    """slope=0 band gate == rolling_dirty_mask, bit for bit (PR 4 contract).

    The band-conditioned theta profile must collapse to the flat gate when
    the conditioning slope is zero, for every replan frequency and error
    scale — the anchor that keeps the forecast-conditioned path honest.
    A nonzero slope must actually change the mask (the feature is live).
    """
    from repro.forecast.rolling import (rolling_band_dirty_mask,
                                        rolling_dirty_mask)
    _, truth, _ = _case(3)
    key = jax.random.key(9)
    changed = False
    for every in (24, 48):
        for scale in (0.0, 0.8):
            flat = rolling_dirty_mask(truth, jnp.float32(0.4), jnp.int32(48),
                                      key, jnp.float32(scale), every=every,
                                      max_window=48)
            band0 = rolling_band_dirty_mask(
                truth, jnp.float32(0.4), jnp.float32(0.0), jnp.int32(48),
                key, jnp.float32(scale), every=every, max_window=48)
            np.testing.assert_array_equal(np.asarray(flat),
                                          np.asarray(band0))
            band1 = rolling_band_dirty_mask(
                truth, jnp.float32(0.4), jnp.float32(0.4), jnp.int32(48),
                key, jnp.float32(scale), every=every, max_window=48)
            changed |= not bool(jnp.array_equal(flat, band1))
    assert changed, "nonzero slope never changed the gate"
