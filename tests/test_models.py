"""Model zoo: per-arch smoke tests + numerics vs naive references."""
import numpy as np
import pytest
from numpy.testing import assert_allclose

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, ARCHS
from repro.models.api import build_model
from repro.models.attention import flash_scan, flash_unrolled
from repro.models.common import SHAPES, ShapeCfg, input_specs, supports_shape
from repro.models.layers import chunked_ce_loss, logits_apply
from repro.models.moe import moe_apply, moe_ref
from repro.models.params import init_params
from repro.models.parallel import ParallelCfg
from repro.models.ssm import ssd_chunked, ssd_ref

PAR = ParallelCfg(mesh=None, remat="none")


def materialize(cfg, shape_name, seq=64, batch=2, key=0):
    sc = ShapeCfg(shape_name, SHAPES[shape_name].kind, seq, batch)
    specs = input_specs(cfg, sc)
    rng = np.random.default_rng(key)
    out = {}
    for k, s in specs.items():
        if s.dtype == jnp.int32:
            out[k] = (jnp.int32(seq // 2) if s.shape == () else
                      jnp.asarray(rng.integers(0, cfg.vocab_size, s.shape),
                                  jnp.int32))
        else:
            out[k] = jnp.asarray(0.02 * rng.standard_normal(s.shape),
                                 s.dtype)
    return out


# ---------------------------------------------------------------------------
# Per-arch smoke: reduced config, one train step + one decode step on CPU.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_train_and_decode(arch):
    cfg = ARCHS[arch].reduced()
    m = build_model(cfg)
    params = init_params(jax.random.key(0), m.defs)
    batch = materialize(cfg, "train_4k")
    loss = jax.jit(lambda p, b: m.loss(p, b, cfg, PAR))(params, batch)
    assert loss.shape == () and bool(jnp.isfinite(loss))
    assert 1.0 < float(loss) < 20.0

    bd = materialize(cfg, "decode_32k")
    logits, caches = jax.jit(lambda p, b: m.decode(p, b, cfg, PAR))(
        params, bd)
    assert logits.shape == (2, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    for k, v in caches.items():
        assert v.shape == bd[k].shape, k
        assert bool(jnp.all(jnp.isfinite(v.astype(jnp.float32)))), k


@pytest.mark.parametrize("arch", ["mamba2-370m", "hymba-1.5b",
                                  "qwen3-moe-30b-a3b", "whisper-base",
                                  "llava-next-34b"])
def test_arch_smoke_prefill(arch):
    cfg = ARCHS[arch].reduced()
    m = build_model(cfg)
    params = init_params(jax.random.key(0), m.defs)
    bp = materialize(cfg, "prefill_32k")
    logits, caches = jax.jit(lambda p, b: m.prefill(p, b, cfg, PAR))(
        params, bp)
    assert logits.shape == (2, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert caches              # prefill must hand decode a cache


def test_prefill_then_decode_consistent():
    """Greedy next token from prefill == decode step fed the same prefix."""
    cfg = ARCHS["qwen1.5-0.5b"].reduced()
    m = build_model(cfg)
    params = init_params(jax.random.key(0), m.defs)
    S = 32
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (1, S)), jnp.int32)
    logits_p, caches = m.prefill(params, {"tokens": toks}, cfg, PAR)
    nxt = jnp.argmax(logits_p, -1)
    # one free slot for the new token (the serve engine pads to max_len)
    pad = lambda c: jnp.pad(c, [(0, 0), (0, 0), (0, 4), (0, 0), (0, 0)])  # noqa: E731
    batch = {"token": nxt[:, None], "pos": jnp.int32(S),
             "k_cache": pad(caches["k_cache"]),
             "v_cache": pad(caches["v_cache"])}
    logits_d, _ = m.decode(params, batch, cfg, PAR)
    # and compare against a full forward over S+1 tokens
    toks2 = jnp.concatenate([toks, nxt[:, None]], 1)
    logits_f, _ = m.prefill(params, {"tokens": toks2}, cfg, PAR)
    assert_allclose(np.asarray(logits_d), np.asarray(logits_f),
                    atol=2e-2, rtol=2e-2)


def test_long_500k_applicability_flags():
    ok = {a: supports_shape(ARCHS[a], "long_500k")[0] for a in ALL_ARCHS}
    assert ok["mamba2-370m"] and ok["hymba-1.5b"]
    for a in ("deepseek-67b", "codeqwen1.5-7b", "whisper-base",
              "llava-next-34b", "qwen3-moe-30b-a3b", "kimi-k2-1t-a32b",
              "minitron-4b", "qwen1.5-0.5b"):
        assert not ok[a], a


# ---------------------------------------------------------------------------
# Numerics: blockwise attention vs naive, MoE vs dense ref, SSD vs scan.
# ---------------------------------------------------------------------------

def _naive_attn(q, k, v, causal=True, window=0):
    B, S, K, G, h = q.shape
    kk = jnp.repeat(k, G, axis=2).reshape(B, -1, K, G, h)
    vv = jnp.repeat(v, G, axis=2).reshape(B, -1, K, G, h)
    s = jnp.einsum("bqkgh,bvkgh->bkgqv", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / np.sqrt(h)
    qp, kp = jnp.arange(S)[:, None], jnp.arange(kk.shape[1])[None, :]
    if causal:
        mask = kp <= qp
        if window:
            mask &= kp > qp - window
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bkgqv,bvkgh->bqkgh", p,
                      vv.astype(jnp.float32)).astype(q.dtype)


@pytest.mark.parametrize("S,block,window,G", [
    (128, 64, 0, 1), (128, 32, 0, 2), (256, 64, 48, 1), (96, 64, 0, 4)])
def test_flash_unrolled_matches_naive(S, block, window, G):
    B, K, h = 2, 2, 32
    kq = jax.random.normal(jax.random.key(1), (B, S, K, G, h), jnp.float32)
    k = jax.random.normal(jax.random.key(2), (B, S, K, h), jnp.float32)
    v = jax.random.normal(jax.random.key(3), (B, S, K, h), jnp.float32)
    out = flash_unrolled(kq, k, v, block=block, window=window)
    ref = _naive_attn(kq, k, v, causal=True, window=window)
    assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)


def test_flash_scan_matches_naive_noncausal():
    B, S, K, G, h = 1, 128, 2, 2, 32
    q = jax.random.normal(jax.random.key(1), (B, S, K, G, h), jnp.float32)
    k = jax.random.normal(jax.random.key(2), (B, S, K, h), jnp.float32)
    v = jax.random.normal(jax.random.key(3), (B, S, K, h), jnp.float32)
    out = flash_scan(q, k, v, block_q=32, block_k=64)
    ref = _naive_attn(q, k, v, causal=False)
    assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)


def test_moe_matches_dense_ref_when_capacity_ample():
    import dataclasses
    cfg = dataclasses.replace(ARCHS["qwen3-moe-30b-a3b"].reduced(),
                              capacity_factor=8.0)   # no drops
    from repro.models.moe import moe_defs
    p = init_params(jax.random.key(0), moe_defs(cfg))
    x = 0.1 * jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model),
                                jnp.float32)
    y, aux = moe_apply(p, x, cfg, PAR)
    yr = moe_ref(p, x, cfg)
    assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5, rtol=1e-4)
    assert float(aux) > 0.0


def test_ssd_chunked_matches_sequential():
    B, S, H, P, G, N = 2, 64, 4, 16, 2, 8
    x = 0.5 * jax.random.normal(jax.random.key(4), (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(5), (B, S, H)))
    A = -jnp.exp(0.3 * jax.random.normal(jax.random.key(6), (H,)))
    Bm = 0.5 * jax.random.normal(jax.random.key(7), (B, S, G, N))
    Cm = 0.5 * jax.random.normal(jax.random.key(8), (B, S, G, N))
    y, h = ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
    yr, hr = ssd_ref(x, dt, A, Bm, Cm)
    assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-4, rtol=2e-4)
    assert_allclose(np.asarray(h), np.asarray(hr), atol=2e-4, rtol=2e-4)


def test_ssm_prefill_state_matches_decode_continuation():
    """Prefill's emitted state must continue exactly like step-by-step."""
    cfg = ARCHS["mamba2-370m"].reduced()
    m = build_model(cfg)
    params = init_params(jax.random.key(0), m.defs)
    rng = np.random.default_rng(1)
    S = 32
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, S + 1)),
                       jnp.int32)
    # full forward over S+1 tokens (teacher): last-position logits
    logits_full, _ = m.prefill(params, {"tokens": toks}, cfg, PAR)
    # prefill S then decode 1
    _, caches = m.prefill(params, {"tokens": toks[:, :S]}, cfg, PAR)
    batch = {"token": toks[:, S:], "pos": jnp.int32(S),
             "ssm_state": caches["ssm_state"],
             "conv_state": caches["conv_state"]}
    logits_d, _ = m.decode(params, batch, cfg, PAR)
    assert_allclose(np.asarray(logits_d), np.asarray(logits_full),
                    atol=2e-2, rtol=2e-2)


def test_chunked_ce_matches_direct():
    V, D, B, S = 128, 32, 2, 64
    rngk = jax.random.key(9)
    h = jax.random.normal(rngk, (B, S, D), jnp.float32)
    w = {"w": 0.1 * jax.random.normal(jax.random.key(10), (D, V))}
    labels = jax.random.randint(jax.random.key(11), (B, S), 0, V)
    labels = labels.at[:, -1].set(-1)
    loss_c = chunked_ce_loss(w, h, labels, chunk=16)
    logits = logits_apply(w, h)
    lse = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                               -1)[..., 0]
    mask = labels >= 0
    ref = jnp.where(mask, lse - gold, 0).sum() / mask.sum()
    assert_allclose(float(loss_c), float(ref), rtol=1e-6)


def test_param_counts_sane():
    # kimi ~1T, deepseek ~67B, qwen-0.5b ~0.6B (padded vocab)
    assert 0.95e12 < ARCHS["kimi-k2-1t-a32b"].param_count() < 1.2e12
    assert 60e9 < ARCHS["deepseek-67b"].param_count() < 75e9
    assert 0.4e9 < ARCHS["qwen1.5-0.5b"].param_count() < 0.8e9
    moe = ARCHS["qwen3-moe-30b-a3b"]
    assert 28e9 < moe.param_count() < 34e9
    assert 2.5e9 < moe.active_param_count() < 4.5e9
