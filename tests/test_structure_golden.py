"""Golden regression locks on benchmark numbers.

Two locks against silent numeric drift (generator streams, packing, dispatch
semantics, objective evaluation):

* the **tiny structure_sweep grid** (the exact grid CI smokes): every cell's
  greedy/gated dispatch aggregates, dispatch-only (``offline=False``) so the
  values are fully deterministic — no jax.random anywhere in the path;
* a seed-pinned **BENCH_online sanity cell**: the first instance of the
  ``online_vs_offline`` benchmark setup, greedy + one gate policy.

If a change legitimately moves these numbers (new generator defaults, a
different dispatch rule), regenerate with

    PYTHONPATH=src python tests/test_structure_golden.py --write

and explain the shift in the PR.  Tolerances are tight (rtol 1e-4 on
floats, exact on ints) — they allow float noise across platforms, not
semantic change.
"""
import functools
import json
import os
import sys

import numpy as np
import pytest

import jax.numpy as jnp

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                           "structure_tiny.json")

# Fields compared exactly (ints / strings); everything else numeric is
# allclose.  online_best_policy is skipped: a float-noise tie between two
# policies may flip the argmax without any semantic change.
EXACT_FIELDS = ("family", "width", "depth", "n_jobs", "n_machines", "fleet",
                "tasks_per_job", "greedy_makespan")
SKIP_FIELDS = ("online_best_policy",)


@functools.lru_cache(maxsize=None)   # golden + sharded tests share one run
def _tiny_rows(devices=None):
    """Cached: callers compare the rows, never mutate them."""
    from benchmarks.structure_sweep import make_spec
    from repro.scenarios import sweep_structure
    rows, meta = sweep_structure(make_spec(tiny=True), offline=False,
                                 devices=devices)
    return rows, meta


def _bench_online_cell(use_kernels=None):
    """Greedy + one gated policy on the first online_vs_offline instance."""
    from benchmarks.online_vs_offline import SIM_HORIZON
    from benchmarks.common import BenchSetup
    from repro.core import generate_instance, pack, synthesize
    from repro.core.objectives import evaluate
    from repro.core.solvers.online_jax import (online_carbon_gated_jax,
                                               online_greedy_jax)

    setup = BenchSetup(stretch=1.5, instances=8)
    rng = np.random.default_rng(setup.seed)
    year = synthesize(setup.region, days=366, seed=2024)
    inst = generate_instance(rng, n_jobs=setup.n_jobs,
                             k_tasks=setup.k_tasks,
                             n_machines=setup.n_machines)
    p = pack(inst, pad_tasks=setup.n_jobs * setup.k_tasks)
    w = year.window(int(rng.integers(0, year.n_epochs - SIM_HORIZON)),
                    SIM_HORIZON)
    cum = jnp.asarray(w.cumulative())
    g = online_greedy_jax(p, SIM_HORIZON)
    c = online_carbon_gated_jax(p, w.intensity, theta=0.3, window=48,
                                stretch=1.25, use_kernels=use_kernels)
    base = evaluate(p, g.start, g.assign, cum)
    gated = evaluate(p, c.start, c.assign, cum)
    return {
        "greedy_makespan": int(base.makespan),
        "greedy_carbon_g": round(float(base.carbon), 3),
        "gated_makespan": int(gated.makespan),
        "gated_carbon_g": round(float(gated.carbon), 3),
        "savings_pct": round(100 * (1 - float(gated.carbon)
                                    / float(base.carbon)), 3),
    }


def _load_golden():
    if not os.path.exists(GOLDEN_PATH):
        pytest.fail(f"golden file missing: {GOLDEN_PATH} — regenerate with "
                    "`PYTHONPATH=src python tests/test_structure_golden.py "
                    "--write`")
    with open(GOLDEN_PATH) as f:
        return json.load(f)


def _assert_row_matches(got: dict, want: dict, ctx: str):
    assert set(got) == set(want), \
        f"{ctx}: field set changed {sorted(set(got) ^ set(want))}"
    for k, w in want.items():
        if k in SKIP_FIELDS:
            continue
        g = got[k]
        if k in EXACT_FIELDS:
            assert g == w, f"{ctx}.{k}: {g!r} != golden {w!r}"
        elif isinstance(w, list):
            np.testing.assert_allclose(
                np.asarray(g, float), np.asarray(w, float),
                rtol=1e-4, atol=2e-3, err_msg=f"{ctx}.{k}")
        elif isinstance(w, (int, float)):
            np.testing.assert_allclose(float(g), float(w), rtol=1e-4,
                                       atol=2e-3, err_msg=f"{ctx}.{k}")
        else:
            assert g == w, f"{ctx}.{k}: {g!r} != golden {w!r}"


def test_structure_sweep_tiny_matches_golden():
    golden = _load_golden()
    rows, meta = _tiny_rows()
    want_rows = golden["structure_tiny"]["cells"]
    assert len(rows) == len(want_rows)
    assert meta["pad_tasks"] == golden["structure_tiny"]["pad_tasks"]
    assert meta["pad_machines"] == golden["structure_tiny"]["pad_machines"]
    for got, want in zip(rows, want_rows):
        ctx = (f"cell[{want['family']}-m{want['n_machines']}"
               f"-{want['fleet']}]")
        _assert_row_matches(got, want, ctx)


def test_structure_sweep_tiny_sharded_matches_golden():
    """Golden stability under sharding: the tiny grid run through
    repro.shard (all local devices — 8 under the CI forced-device job)
    reproduces the single-device rows **bit-exactly**, and therefore the
    stored golden JSON with no ``--write`` regeneration — that is the
    point of the sharding parity contract."""
    import jax

    golden = _load_golden()
    rows, meta = _tiny_rows()
    rows_sharded, meta_sharded = _tiny_rows(devices=jax.device_count())
    # bit-exact vs the single-device sweep: every row dict identical,
    # including every rounded float
    assert meta_sharded["pad_tasks"] == meta["pad_tasks"]
    assert meta_sharded["pad_machines"] == meta["pad_machines"]
    assert rows_sharded == rows
    # and the stored golden file still validates the sharded rows
    want_rows = golden["structure_tiny"]["cells"]
    assert len(rows_sharded) == len(want_rows)
    for got, want in zip(rows_sharded, want_rows):
        ctx = (f"sharded cell[{want['family']}-m{want['n_machines']}"
               f"-{want['fleet']}]")
        _assert_row_matches(got, want, ctx)


def test_structure_sweep_tiny_golden_unchanged_under_tracing(monkeypatch):
    """Telemetry bit-exactness vs the stored golden: the tiny sweep re-run
    with ``REPRO_TRACE=1`` (bypassing the lru_cache, so the traced path
    really executes) must reproduce the locked rows, and the ambient
    tracer must have captured the sweep's jitted calls."""
    from repro.obs import get_tracer, set_tracer
    monkeypatch.setenv("REPRO_TRACE", "1")
    set_tracer(None)
    try:
        golden = _load_golden()
        rows, meta = _tiny_rows.__wrapped__(None)
        tracer = get_tracer()
        assert tracer.enabled
        assert any(e["name"].startswith("xla:") for e in tracer.events)
        want_rows = golden["structure_tiny"]["cells"]
        assert len(rows) == len(want_rows)
        for got, want in zip(rows, want_rows):
            ctx = (f"traced cell[{want['family']}-m{want['n_machines']}"
                   f"-{want['fleet']}]")
            _assert_row_matches(got, want, ctx)
    finally:
        set_tracer(None)


def test_bench_online_cell_matches_golden():
    golden = _load_golden()
    got = _bench_online_cell()
    want = golden["bench_online_cell"]
    assert got["greedy_makespan"] == want["greedy_makespan"]
    assert got["gated_makespan"] == want["gated_makespan"]
    for k in ("greedy_carbon_g", "gated_carbon_g", "savings_pct"):
        np.testing.assert_allclose(got[k], want[k], rtol=1e-4, atol=2e-3,
                                   err_msg=k)


def test_bench_online_cell_golden_unchanged_under_kernels():
    """The stored golden must hold with the Pallas gate kernel enabled —
    the dispatcher's quantile gate is bit-exact vs the jnp path
    (docs/kernels.md), so flipping ``REPRO_KERNELS`` may not move a single
    locked number, makespans included."""
    golden = _load_golden()
    got = _bench_online_cell(use_kernels=True)
    want = golden["bench_online_cell"]
    assert got["greedy_makespan"] == want["greedy_makespan"]
    assert got["gated_makespan"] == want["gated_makespan"]
    for k in ("greedy_carbon_g", "gated_carbon_g", "savings_pct"):
        np.testing.assert_allclose(got[k], want[k], rtol=1e-4, atol=2e-3,
                                   err_msg=k)
    # stronger than the golden tolerance: the two paths agree exactly
    assert got == _bench_online_cell(use_kernels=False)


def _write_golden():
    rows, meta = _tiny_rows()
    record = {
        "_regenerate": "PYTHONPATH=src python tests/test_structure_golden.py"
                       " --write",
        "structure_tiny": {
            "pad_tasks": meta["pad_tasks"],
            "pad_machines": meta["pad_machines"],
            "cells": rows,
        },
        "bench_online_cell": _bench_online_cell(),
    }
    os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
    with open(GOLDEN_PATH, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {GOLDEN_PATH}")


if __name__ == "__main__":
    # running as a script: make repo-root imports (benchmarks.*) resolve
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    if "--write" in sys.argv:
        _write_golden()
    else:
        print(__doc__)
