"""Subprocess harness: forced-fake-device and multi-process jax test runs.

JAX locks its device count (and its process topology) at first backend
init, so any test that needs "8 CPU devices" or "2 processes x 4 devices"
inside a plain tier-1 run must spawn fresh interpreters.  This module is
the one spawn path both kinds of test share:

* :func:`run_forced_devices` — the single-subprocess pattern
  ``tests/test_shard.py`` / ``tests/test_multidevice.py`` use: run a
  payload under ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
  and parse the payload's last stdout line as JSON (the stdout protocol —
  payloads may log freely as long as the final line is the result).
* :func:`run_distributed` — the multi-process pattern
  ``tests/test_distributed.py`` uses: pick a free coordinator port, spawn
  one worker per rank with the ``REPRO_COORDINATOR`` /
  ``REPRO_NUM_PROCESSES`` / ``REPRO_PROCESS_ID`` env contract
  (:mod:`repro.shard.distributed` reads it via ``initialize_from_env``),
  each with ``devices`` forced fake CPU devices, collect every rank's
  stdout-protocol result, **assert the ranks agree bit-for-bit**, and
  report which rank hung when the fleet times out.
* ``python -m tests.harness --processes P --devices D -- cmd ...`` — the
  same spawn path as a CLI, for running e.g.
  ``benchmarks/structure_sweep.py --tiny --processes 2 --devices 4``
  multi-process locally or in CI.

Workers are spawned with ``PYTHONPATH`` covering ``src`` and the repo
root, and with any inherited ``REPRO_*`` contract scrubbed first so a
nested single-process payload never accidentally joins an outer fleet.
"""
from __future__ import annotations

import json
import os
import socket
import subprocess
import sys
import time

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SRC = os.path.join(REPO_ROOT, "src")

ENV_COORDINATOR = "REPRO_COORDINATOR"
ENV_NUM_PROCESSES = "REPRO_NUM_PROCESSES"
ENV_PROCESS_ID = "REPRO_PROCESS_ID"

# Payload prelude: join the fleet described by the env (no-op when the
# harness spawned a plain single-process payload).  The short timeout is
# what turns a dead worker into a loud failure instead of a 300 s hang.
DISTRIBUTED_PRELUDE = (
    "from repro.shard.distributed import initialize_from_env\n"
    "initialize_from_env(initialization_timeout=120)\n")


def _worker_env(devices: int, extra: dict | None = None) -> dict:
    env = dict(os.environ)
    path = [SRC, REPO_ROOT]
    if env.get("PYTHONPATH"):
        path.append(env["PYTHONPATH"])
    env["PYTHONPATH"] = os.pathsep.join(path)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    for k in (ENV_COORDINATOR, ENV_NUM_PROCESSES, ENV_PROCESS_ID):
        env.pop(k, None)
    if extra:
        env.update(extra)
    return env


def _last_json_line(stdout: str, ctx: str):
    lines = stdout.strip().splitlines()
    assert lines, f"{ctx}: payload produced no stdout"
    try:
        return json.loads(lines[-1])
    except json.JSONDecodeError as e:
        raise AssertionError(
            f"{ctx}: last stdout line is not JSON ({e}): {lines[-1]!r}")


def free_port() -> int:
    """A free localhost TCP port for the coordinator."""
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def run_forced_devices(payload: str, devices: int = 8,
                       timeout: int = 900):
    """Run ``payload`` in one subprocess with ``devices`` forced fake CPU
    devices; returns the JSON parsed from its last stdout line."""
    out = subprocess.run([sys.executable, "-c", payload],
                         env=_worker_env(devices), capture_output=True,
                         text=True, timeout=timeout)
    assert out.returncode == 0, (
        f"forced-{devices}-device payload failed "
        f"(rc={out.returncode}):\n{out.stderr[-2000:]}")
    return _last_json_line(out.stdout, f"forced-{devices}-device payload")


def run_distributed(payload: str, processes: int, devices: int,
                    timeout: int = 900,
                    spawn_order: tuple[int, ...] | None = None) -> dict:
    """Run ``payload`` on a ``processes``-rank fleet, ``devices`` fake CPU
    devices per rank.

    Every rank gets the ``REPRO_*`` env contract (the payload joins via
    ``initialize_from_env`` — prepend :data:`DISTRIBUTED_PRELUDE`);
    ``spawn_order`` permutes the order the OS processes are launched in
    (rank identity comes from the env, so results must not change).

    Collects each rank's stdout-protocol result, asserts every rank
    produced the **identical** JSON (the cross-process agreement the
    replicated-output contract promises), and returns ``{rank: result}``.
    Raises :class:`TimeoutError` naming the rank(s) still running when
    the deadline passes — the dead-worker failure mode.
    """
    order = (tuple(range(processes)) if spawn_order is None
             else tuple(spawn_order))
    assert sorted(order) == list(range(processes)), order
    coord = f"127.0.0.1:{free_port()}"
    procs: dict[int, subprocess.Popen] = {}
    try:
        for rank in order:
            procs[rank] = subprocess.Popen(
                [sys.executable, "-c", payload],
                env=_worker_env(devices, {
                    ENV_COORDINATOR: coord,
                    ENV_NUM_PROCESSES: str(processes),
                    ENV_PROCESS_ID: str(rank),
                }),
                stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)
        deadline = time.monotonic() + timeout
        while (time.monotonic() < deadline
               and any(p.poll() is None for p in procs.values())):
            time.sleep(0.2)
        hung = sorted(r for r, p in procs.items() if p.poll() is None)
        if hung:
            done = sorted(r for r in procs if r not in hung)
            raise TimeoutError(
                f"distributed run ({processes} proc x {devices} dev) timed "
                f"out after {timeout}s: rank(s) {hung} still running, "
                f"rank(s) {done} exited — a worker likely died before the "
                "coordination barrier or the payload deadlocked")
    finally:
        for p in procs.values():
            if p.poll() is None:
                p.kill()
    outs = {r: p.communicate() for r, p in procs.items()}
    bad = {r: p.returncode for r, p in procs.items() if p.returncode != 0}
    assert not bad, (
        f"rank(s) {sorted(bad)} failed (rc={bad}):\n" + "\n".join(
            f"--- rank {r} stderr ---\n{outs[r][1][-2000:]}"
            for r in sorted(bad)))
    results = {r: _last_json_line(out, f"rank {r}")
               for r, (out, _err) in outs.items()}
    first = results[min(results)]
    for r in sorted(results):
        assert results[r] == first, (
            f"cross-process disagreement: rank {r} != rank {min(results)}\n"
            f"rank {min(results)}: {first}\nrank {r}: {results[r]}")
    return results


def launch(cmd: list[str], processes: int, devices: int,
           timeout: int = 3600) -> int:
    """CLI spawn path: run ``cmd`` once per rank under the ``REPRO_*``
    contract.  Rank 0 inherits this terminal; other ranks log to
    ``harness-rank<N>.log`` in the cwd.  Returns the max exit code."""
    coord = f"127.0.0.1:{free_port()}"
    procs, logs = {}, {}
    for rank in range(processes):
        if rank == 0:
            out = err = None
        else:
            logs[rank] = f"harness-rank{rank}.log"
            out = err = open(logs[rank], "w")
        procs[rank] = subprocess.Popen(
            cmd, env=_worker_env(devices, {
                ENV_COORDINATOR: coord,
                ENV_NUM_PROCESSES: str(processes),
                ENV_PROCESS_ID: str(rank),
            }), stdout=out, stderr=err)
    deadline = time.monotonic() + timeout
    while (time.monotonic() < deadline
           and any(p.poll() is None for p in procs.values())):
        time.sleep(0.5)
    hung = sorted(r for r, p in procs.items() if p.poll() is None)
    for p in procs.values():
        if p.poll() is None:
            p.kill()
    rcs = {r: p.wait() for r, p in procs.items()}
    if hung:
        print(f"harness: rank(s) {hung} timed out after {timeout}s and "
              "were killed", file=sys.stderr)
    for r, path in logs.items():
        if rcs[r] != 0:
            print(f"harness: rank {r} failed (rc={rcs[r]}), log: {path}",
                  file=sys.stderr)
    return max(max(rcs.values()), 1 if hung else 0)


def _main(argv: list[str]) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m tests.harness",
        description="Run a command once per rank on a local multi-process "
                    "jax fleet (CPU, fake devices per rank).")
    ap.add_argument("--processes", type=int, required=True)
    ap.add_argument("--devices", type=int, required=True,
                    help="fake CPU devices per process")
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("cmd", nargs=argparse.REMAINDER,
                    help="command to run per rank (prefix with --)")
    args = ap.parse_args(argv)
    cmd = args.cmd[1:] if args.cmd[:1] == ["--"] else args.cmd
    if not cmd:
        ap.error("no command given — e.g. ... -- python "
                 "benchmarks/structure_sweep.py --tiny --processes 2")
    return launch(cmd, args.processes, args.devices, timeout=args.timeout)


if __name__ == "__main__":
    sys.exit(_main(sys.argv[1:]))
