"""The shared feasibility validator (core/validate): both paths, every
constraint, and agreement between the jnp and numpy implementations."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import generate_instance, pack, stack_packed, validate
from repro.core.decoder import sgs, upward_rank
from repro.core.instance import Instance, Job
from repro.core.solvers.online import online_greedy


def _two_task_instance(arrival=2, n_machines=2, allowed=None):
    job = Job(arrival=arrival, base_durations=(2, 2), edges=((0, 1),))
    return pack(Instance(jobs=(job,), powers_kw=(1.0,) * n_machines,
                         speeds=(1.0,) * n_machines, allowed=allowed))


FEASIBLE = (jnp.asarray([2, 4], jnp.int32), jnp.asarray([0, 1], jnp.int32))


def test_feasible_schedule_passes_both_paths():
    p = _two_task_instance()
    rep = validate.violation_report(p, *FEASIBLE)
    assert all(int(v) == 0 for v in rep)
    assert bool(rep.feasible)
    assert int(validate.total_violations(p, *FEASIBLE)) == 0
    assert validate.check_feasible_np(p, *FEASIBLE) == []
    validate.assert_feasible_np(p, *FEASIBLE)  # must not raise


def test_pre_arrival_start_flagged():
    p = _two_task_instance(arrival=2)
    start = jnp.asarray([0, 4], jnp.int32)
    rep = validate.violation_report(p, start, FEASIBLE[1])
    assert int(rep.arrival) > 0
    assert int(rep.precedence) == int(rep.machine) == int(rep.overlap) == 0
    probs = validate.check_feasible_np(p, start, FEASIBLE[1])
    assert len(probs) == 1 and "before arrival" in probs[0]


def test_precedence_violation_flagged():
    p = _two_task_instance()
    start = jnp.asarray([2, 3], jnp.int32)     # task 1 starts before 0 ends
    rep = validate.violation_report(p, start, FEASIBLE[1])
    assert int(rep.precedence) > 0
    assert int(rep.arrival) == int(rep.machine) == 0
    probs = validate.check_feasible_np(p, start, FEASIBLE[1])
    assert any("before pred" in s for s in probs)


def test_overlap_on_one_machine_flagged():
    p = _two_task_instance()
    start = jnp.asarray([2, 2], jnp.int32)
    assign = jnp.asarray([0, 0], jnp.int32)
    rep = validate.violation_report(p, start, assign)
    assert int(rep.overlap) > 0
    probs = validate.check_feasible_np(p, start, assign)
    assert any("overlap" in s for s in probs)


def test_disallowed_machine_flagged():
    # task 0 may only run on machine 0; assign it machine 1.
    p = _two_task_instance(allowed=(((0,), (0, 1)),))
    assign = jnp.asarray([1, 1], jnp.int32)
    start = jnp.asarray([2, 1 << 21], jnp.int32)  # keep precedence clean
    rep = validate.violation_report(p, start, assign)
    assert int(rep.machine) == 1
    # one disallowed assignment outweighs any epoch mass in the scalar form
    assert int(validate.total_violations(p, start, assign)) >= 10**6
    probs = validate.check_feasible_np(p, start, assign)
    assert any("not allowed" in s for s in probs)


def test_budget_overshoot_flagged():
    p = _two_task_instance()
    rep = validate.violation_report(p, *FEASIBLE, deadline=jnp.int32(5))
    assert int(rep.budget) == 1          # completion 6 vs deadline 5
    assert not bool(rep.feasible)
    rep_ok = validate.violation_report(p, *FEASIBLE, deadline=jnp.int32(6))
    assert bool(rep_ok.feasible)
    probs = validate.check_feasible_np(p, *FEASIBLE, deadline=5)
    assert len(probs) == 1 and "past deadline" in probs[0]
    with pytest.raises(AssertionError, match="past deadline"):
        validate.assert_feasible_np(p, *FEASIBLE, deadline=5, ctx="bench")


def test_padding_tasks_ignored():
    job = Job(arrival=0, base_durations=(2,), edges=())
    p = pack(Instance(jobs=(job,), powers_kw=(1.0,), speeds=(1.0,)),
             pad_tasks=6)
    # padded tasks all "start" at 0 on machine 0 — must not count as overlap
    start = jnp.zeros(6, jnp.int32)
    assign = jnp.zeros(6, jnp.int32)
    assert int(validate.total_violations(p, start, assign)) == 0
    assert validate.check_feasible_np(p, start, assign) == []


def test_validator_is_jit_and_vmap_friendly(rng):
    insts = []
    for seed in range(4):
        r = np.random.default_rng(seed)
        insts.append(pack(generate_instance(r, n_jobs=3, k_tasks=3,
                                            n_machines=3), pad_tasks=9))
    batch = stack_packed(insts)
    starts, assigns = [], []
    for p in insts:
        dec = sgs(p, upward_rank(p))
        starts.append(dec.start)
        assigns.append(dec.assign)
    v = jax.jit(jax.vmap(validate.total_violations))(
        batch, jnp.stack(starts), jnp.stack(assigns))
    assert v.shape == (4,) and int(np.asarray(v).sum()) == 0


@settings(deadline=None)
@given(seed=st.integers(0, 10_000))
def test_jnp_and_numpy_paths_agree_on_random_schedules(seed):
    """total_violations == 0 exactly when check_feasible_np reports nothing,
    on arbitrary (mostly infeasible) random schedules."""
    r = np.random.default_rng(seed)
    inst = generate_instance(r, n_jobs=3, k_tasks=3, n_machines=3,
                             heterogeneous=bool(seed % 2))
    p = pack(inst)
    start = jnp.asarray(r.integers(0, 60, p.T), jnp.int32)
    assign = jnp.asarray(r.integers(0, p.M, p.T), jnp.int32)
    deadline = int(r.integers(10, 120))
    jfeas = int(validate.total_violations(p, start, assign,
                                          jnp.int32(deadline))) == 0
    nfeas = validate.check_feasible_np(p, start, assign, deadline) == []
    assert jfeas == nfeas


@settings(deadline=None)
@given(seed=st.integers(0, 10_000))
def test_every_produced_schedule_passes_validator(seed):
    """Decoded (SGS) and online-dispatched schedules are validator-clean."""
    r = np.random.default_rng(seed)
    inst = generate_instance(r, n_jobs=3, k_tasks=3, n_machines=3,
                             heterogeneous=bool(seed % 2))
    p = pack(inst)
    dec = sgs(p, jnp.asarray(r.normal(size=p.T), jnp.float32))
    assert int(validate.total_violations(p, dec.start, dec.assign)) == 0
    s0, a0 = online_greedy(p)
    validate.assert_feasible_np(p, s0, a0, ctx="online_greedy")


def test_objectives_reexports_still_work():
    """Historical import path (repro.core.objectives) stays usable."""
    from repro.core.objectives import check_feasible_np, violations
    p = _two_task_instance()
    assert int(violations(p, *FEASIBLE)) == 0
    assert check_feasible_np(p, *FEASIBLE) == []
