"""Pallas kernels vs. pure-jnp oracles: shape/dtype sweeps, interpret=True."""
import numpy as np
import pytest
from numpy.testing import assert_allclose

import jax
import jax.numpy as jnp

from repro.core import generate_instance, pack, synthesize
from repro.core.carbon import sample_window
from repro.core.objectives import task_durations
from repro.kernels.ops import flash_attention, population_carbon, ssd_scan
from repro.kernels.ref import attention_ref, schedule_carbon_ref, ssd_ref


# ---------------------------------------------------------------------------
# schedule_eval
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pop,pad,horizon", [(3, 10, 100), (17, 30, 500),
                                             (64, 64, 257), (8, 130, 640)])
def test_schedule_carbon_kernel(pop, pad, horizon):
    rng = np.random.default_rng(pop)
    inst = generate_instance(rng, n_jobs=4, k_tasks=2, n_machines=5,
                             heterogeneous=True)
    p = pack(inst, pad_tasks=pad)
    tr = synthesize("CAL", days=10)
    cum = jnp.asarray(sample_window(tr, rng, horizon).cumulative())
    starts = jnp.asarray(rng.integers(0, horizon // 2, (pop, p.T)),
                         jnp.int32)
    assigns = jnp.asarray(rng.integers(0, 5, (pop, p.T)), jnp.int32)
    out = population_carbon(p, starts, assigns, cum, interpret=True)
    dur = jax.vmap(lambda a: task_durations(p, a))(assigns)
    power = p.power[assigns] * p.task_mask[None, :]
    ref = schedule_carbon_ref(starts, dur, power.astype(jnp.float32), cum)
    assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,KVH,S,dh,causal,window,dtype", [
    (2, 4, 2, 128, 64, True, 0, jnp.float32),
    (1, 8, 8, 256, 32, True, 64, jnp.float32),
    (2, 2, 1, 128, 64, False, 0, jnp.float32),
    (1, 4, 4, 128, 128, True, 0, jnp.bfloat16),
    (1, 8, 2, 512, 64, True, 0, jnp.float32),
])
def test_flash_attention_kernel(B, H, KVH, S, dh, causal, window, dtype):
    q = jax.random.normal(jax.random.key(1), (B, H, S, dh)).astype(dtype)
    k = jax.random.normal(jax.random.key(2), (B, KVH, S, dh)).astype(dtype)
    v = jax.random.normal(jax.random.key(3), (B, KVH, S, dh)).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32),
                    atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,P,G,N,chunk,dtype", [
    (2, 128, 4, 32, 2, 16, 32, jnp.float32),
    (1, 64, 2, 16, 1, 8, 16, jnp.float32),
    (1, 256, 8, 64, 1, 32, 64, jnp.float32),
    (2, 64, 4, 32, 4, 16, 32, jnp.bfloat16),
])
def test_ssd_scan_kernel(B, S, H, P, G, N, chunk, dtype):
    x = (0.5 * jax.random.normal(jax.random.key(4), (B, S, H, P))
         ).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(5), (B, S, H)))
    A = -jnp.exp(0.3 * jax.random.normal(jax.random.key(6), (H,)))
    Bm = 0.5 * jax.random.normal(jax.random.key(7), (B, S, G, N))
    Cm = 0.5 * jax.random.normal(jax.random.key(8), (B, S, G, N))
    y, h = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    yr, hr = ssd_ref(x.astype(jnp.float32), dt, A, Bm, Cm)
    tol = 3e-4 if dtype == jnp.float32 else 3e-2
    assert_allclose(np.asarray(y, np.float32), np.asarray(yr, np.float32),
                    atol=tol, rtol=tol)
    assert_allclose(np.asarray(h), np.asarray(hr), atol=tol, rtol=tol)
