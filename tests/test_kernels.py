"""Pallas kernels vs. jnp paths: **bit-exactness** contracts + shape sweeps.

The carbon-eval and gate-quantile kernels are not allclose targets — their
contract is ``kernel path == jnp path`` bitwise in f32 (docs/kernels.md),
property-tested here across every scenario family x fleet x machine rule,
in every interpret mode available on the host, including ``pack_aligned``
padded batches, frozen-prefix (rolling) candidates, and candidates that
overrun the carbon trace (the regression the pre-fix kernel failed: zero
padding on ``cum`` gave wrong, even negative, deltas).

A seed-pinned tiny ``solve_bilevel`` run is additionally golden-locked in
``tests/golden/sa_bilevel_tiny.json`` and re-run with the kernel fitness
path enabled — the stored golden must hold *unchanged* on both paths
(regenerate with ``PYTHONPATH=src python tests/test_kernels.py --write``
and explain the shift in the PR, same convention as
``test_structure_golden.py``).

The flash-attention / SSD kernels keep their original allclose sweeps
(softmax reductions genuinely reassociate there).
"""
import functools
import inspect
import json
import os
import sys

import numpy as np
import pytest
from numpy.testing import assert_allclose

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # script mode (--write) without pytest:
    import conftest  # noqa: F401  — installs the hypothesis stub
    from hypothesis import given, settings, strategies as st

from repro.core import generate_instance, pack, synthesize
from repro.core.carbon import sample_window
from repro.core.instance import stack_packed
from repro.core.decoder import MACHINE_RULES, sgs
from repro.core.objectives import carbon, task_durations
from repro.core.solvers import common
from repro.core.solvers.annealing import SAConfig, solve_sa
from repro.core.solvers.bilevel import solve_bilevel
from repro.core.solvers.genetic import GAConfig, solve_ga
from repro.core.solvers.online_jax import (dirty_mask, quantile_threshold,
                                           sorted_windows)
from repro.kernels import ops
from repro.kernels.gate_quantile import gate_quantile_stats_pallas
from repro.kernels.ref import (attention_ref, gate_threshold_ref,
                               schedule_carbon_ref, ssd_ref)
from repro.kernels.schedule_eval import schedule_delta_pallas
from repro.scenarios import FAMILY_NAMES, FLEET_NAMES
from tests.strategies import scenario_case, seeds

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                           "sa_bilevel_tiny.json")

# Every interpret mode runnable on this host: the interpreter everywhere,
# compiled Mosaic only on a real TPU.  Tests sweep all of them so the TPU
# CI run covers compiled-vs-jnp with the same cases.
INTERPRET_MODES = ([True, False] if jax.default_backend() == "tpu"
                   else [True])


def _exact(a, b, ctx=""):
    a, b = np.asarray(a), np.asarray(b)
    assert a.dtype == b.dtype, f"{ctx}: dtype {a.dtype} != {b.dtype}"
    assert np.array_equal(a, b, equal_nan=True), (
        f"{ctx}: max abs diff {np.max(np.abs(a - b))} "
        f"at {np.unravel_index(np.argmax(a != b), a.shape)}")


def _population(rng, p, pop, horizon, overrun=False):
    """Random candidate (starts, assigns) with only *allowed* machines."""
    hi = 2 * horizon if overrun else max(horizon // 2, 2)
    lo = -5 if overrun else 0
    starts = jnp.asarray(rng.integers(lo, hi, (pop, p.T)), jnp.int32)
    allowed = np.asarray(p.allowed)
    assigns = np.zeros((pop, p.T), np.int32)
    for t in range(p.T):
        choices = np.nonzero(allowed[t])[0]
        if len(choices):
            assigns[:, t] = rng.choice(choices, size=pop)
    return starts, jnp.asarray(assigns)


# ---------------------------------------------------------------------------
# schedule_eval / population_carbon — bit-exact vs objectives.carbon
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", FAMILY_NAMES)
@pytest.mark.parametrize("fleet", FLEET_NAMES)
def test_population_carbon_bit_exact(family, fleet):
    rng = np.random.default_rng(hash((family, fleet)) % 2**31)
    p, w = scenario_case(3, family=family, fleet=fleet, horizon=300)
    cum = jnp.asarray(w.cumulative())
    starts, assigns = _population(rng, p, 9, 300, overrun=True)
    ref = jax.vmap(lambda s, a: carbon(p, s, a, cum))(starts, assigns)
    for interpret in INTERPRET_MODES:
        got = ops.population_carbon(p, starts, assigns, cum,
                                    interpret=interpret)
        _exact(ref, got, f"{family}/{fleet}/interpret={interpret}")


@pytest.mark.parametrize("pop,pad,horizon", [(3, 10, 100), (17, 30, 500),
                                             (64, 64, 257), (8, 130, 640)])
def test_population_carbon_shapes(pop, pad, horizon):
    """The original shape sweep, upgraded from allclose to exact."""
    rng = np.random.default_rng(pop)
    inst = generate_instance(rng, n_jobs=4, k_tasks=2, n_machines=5,
                             heterogeneous=True)
    p = pack(inst, pad_tasks=pad)
    tr = synthesize("CAL", days=10)
    cum = jnp.asarray(sample_window(tr, rng, horizon).cumulative())
    starts = jnp.asarray(rng.integers(0, horizon // 2, (pop, p.T)),
                         jnp.int32)
    assigns = jnp.asarray(rng.integers(0, 5, (pop, p.T)), jnp.int32)
    out = ops.population_carbon(p, starts, assigns, cum, interpret=True)
    dur = jax.vmap(lambda a: task_durations(p, a))(assigns)
    power = p.power[assigns] * p.task_mask[None, :]
    ref = schedule_carbon_ref(starts, dur, power.astype(jnp.float32), cum)
    _exact(ref, out, f"shapes {pop}/{pad}/{horizon}")


def test_population_carbon_overrun_regression():
    """Candidates ending at or past H+1 must integrate to the trace edge.

    The pre-fix kernel zero-padded ``cum`` to a lane multiple without
    clamping ``e1``, so an overrunning candidate read ``cum[e1] = 0`` and
    produced a *negative* carbon delta — this test fails on that kernel.
    """
    rng = np.random.default_rng(0)
    p, w = scenario_case(1, family="chain", fleet="homog", horizon=120)
    cum = jnp.asarray(w.cumulative())
    H = cum.shape[0] - 1
    # Every candidate deliberately ends past the horizon (starts near/past
    # H), several land inside the lane-padding region [H+1, 128).
    starts = jnp.asarray(
        rng.integers(H - 2, H + 40, (8, p.T)), jnp.int32)
    _, assigns = _population(rng, p, 8, H)
    got = ops.population_carbon(p, starts, assigns, cum, interpret=True)
    ref = jax.vmap(lambda s, a: carbon(p, s, a, cum))(starts, assigns)
    _exact(ref, got, "overrun")
    # And the fixed semantics: overrunning work costs >= 0 carbon, and a
    # task straddling the edge integrates exactly to cum[H].
    assert np.all(np.asarray(got) >= 0.0)
    one_start = jnp.full((1, p.T), H - 1, jnp.int32)
    one = ops.population_carbon(p, one_start, assigns[:1], cum,
                                interpret=True)
    expect = jax.vmap(lambda s, a: carbon(p, s, a, cum))(one_start,
                                                         assigns[:1])
    _exact(expect, one, "edge-straddle")


def test_population_carbon_pack_aligned_padding_inert():
    """Mixed-shape batches through pack_aligned: padded tasks/machines must
    not move the kernel's carbon (the PackedInstance padding contract)."""
    from repro.scenarios import ScenarioConfig, pack_aligned, sample_batch
    rng = np.random.default_rng(11)
    insts = (sample_batch(rng, ScenarioConfig(
        family="diamond", fleet="mixed", n_jobs=2, width=2, depth=2,
        n_machines=3), 2)
        + sample_batch(rng, ScenarioConfig(
            family="chain", fleet="homog", n_jobs=4, width=1, depth=3,
            n_machines=2), 2))
    batch = pack_aligned(insts)
    tr = synthesize("AU-SA", days=10)
    cum = jnp.asarray(sample_window(tr, np.random.default_rng(1),
                                    400).cumulative())
    for i in range(len(insts)):
        p = jax.tree.map(lambda a: a[i], batch)
        starts, assigns = _population(rng, p, 6, 400, overrun=True)
        ref = jax.vmap(lambda s, a: carbon(p, s, a, cum))(starts, assigns)
        got = ops.population_carbon(p, starts, assigns, cum, interpret=True)
        _exact(ref, got, f"pack_aligned[{i}]")


@settings(max_examples=20, deadline=None, derandomize=True)
@given(seed=seeds(), rule=st.sampled_from(MACHINE_RULES))
def test_population_carbon_property(seed, rule):
    """Property: kernel == vmap(objectives.carbon) on *decoded* (SGS)
    populations across drawn families x fleets x machine rules."""
    p, w = scenario_case(seed, family=FAMILY_NAMES[seed % len(FAMILY_NAMES)],
                         fleet=FLEET_NAMES[seed % len(FLEET_NAMES)],
                         horizon=350)
    cum = jnp.asarray(w.cumulative())
    rng = np.random.default_rng(seed)
    prio = jnp.asarray(rng.normal(size=(5, p.T)), jnp.float32)
    _, assigns = _population(rng, p, 5, 350)
    dec = jax.vmap(lambda pr, a: sgs(p, pr, a, machine_rule=rule))(prio,
                                                                   assigns)
    ref = jax.vmap(lambda s, a: carbon(p, s, a, cum))(dec.start, dec.assign)
    got = ops.population_carbon(p, dec.start, dec.assign, cum,
                                interpret=True)
    _exact(ref, got, f"seed={seed} rule={rule}")


# ---------------------------------------------------------------------------
# gate_quantile / gate_threshold — bit-exact vs online_jax internals
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("E,W,theta", [
    (300, 48, 0.3), (257, 96, 0.5), (64, 24, 0.9), (100, 1, 0.25),
    (130, 130, 0.6), (16, 96, 0.0), (200, 48, 1.0),
])
def test_gate_threshold_bit_exact(E, W, theta):
    rng = np.random.default_rng(E * 1000 + W)
    inten = jnp.asarray(rng.uniform(50, 900, E), jnp.float32)
    inten = inten.at[::7].set(inten[0])          # inject ties
    sv, n = sorted_windows(inten, jnp.int32(W), W)
    ref = quantile_threshold(sv, n, jnp.float32(theta))
    naive = gate_threshold_ref(inten, jnp.float32(theta), jnp.int32(W), W)
    for interpret in INTERPRET_MODES:
        got = ops.gate_threshold(inten, jnp.float32(theta), jnp.int32(W), W,
                                 interpret=interpret)
        _exact(ref, got, f"E={E} W={W} th={theta} interp={interpret}")
        _exact(naive, got, f"vs-ref E={E} W={W} th={theta}")


def test_gate_threshold_vector_theta():
    """Per-epoch theta vectors (forecast-conditioned gates) stay exact."""
    rng = np.random.default_rng(5)
    E, W = 220, 48
    inten = jnp.asarray(rng.uniform(50, 900, E), jnp.float32)
    theta = jnp.asarray(rng.uniform(0, 1, E), jnp.float32)
    sv, n = sorted_windows(inten, jnp.int32(W), W)
    ref = quantile_threshold(sv, n, theta)
    got = ops.gate_threshold(inten, theta, jnp.int32(W), W, interpret=True)
    _exact(ref, got, "vector-theta")


def test_gate_stats_are_order_statistics():
    """The kernel's (a, b) are bitwise the sorted-window positions the jnp
    path gathers — selection, not arithmetic."""
    rng = np.random.default_rng(9)
    E, W, theta = 140, 32, 0.37
    inten = jnp.asarray(rng.uniform(50, 900, E), jnp.float32)
    inten = inten.at[::3].set(inten[1])
    a, b, n = gate_quantile_stats_pallas(
        inten, jnp.full((E,), theta, jnp.float32), jnp.int32(W),
        max_window=W, interpret=True)
    sv, n_ref = sorted_windows(inten, jnp.int32(W), W)
    _exact(n_ref, n, "valid count")
    vi = jnp.float32(theta) * (n_ref - 1).astype(jnp.float32)
    lo_i = jnp.floor(vi).astype(jnp.int32)
    hi_i = jnp.minimum(lo_i + 1, n_ref - 1)
    _exact(jnp.take_along_axis(sv, lo_i[:, None], 1)[:, 0], a, "a")
    _exact(jnp.take_along_axis(sv, hi_i[:, None], 1)[:, 0], b, "b")


@settings(max_examples=25, deadline=None, derandomize=True)
@given(seed=seeds(), theta=st.floats(0.0, 1.0), window=st.integers(1, 120))
def test_dirty_mask_property(seed, theta, window):
    """Property: the wired gate switch produces identical dirty masks."""
    rng = np.random.default_rng(seed)
    E = 120 + seed % 200
    inten = jnp.asarray(rng.uniform(30, 950, E), jnp.float32)
    ref = dirty_mask(inten, jnp.float32(theta), jnp.int32(window),
                     max_window=window, use_kernels=False)
    got = dirty_mask(inten, jnp.float32(theta), jnp.int32(window),
                     max_window=window, use_kernels=True)
    _exact(ref, got, f"seed={seed} th={theta} w={window}")


# ---------------------------------------------------------------------------
# population_fitness — the wired SA/GA hot loop, kernel == jnp
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("objective", ["carbon", "energy"])
@pytest.mark.parametrize("rule", MACHINE_RULES)
def test_population_fitness_paths_equal(objective, rule):
    rng = np.random.default_rng(21)
    p, w = scenario_case(7, family="layered", fleet="tiered", horizon=400)
    cum = jnp.asarray(w.cumulative())
    prio = jnp.asarray(rng.normal(size=(6, p.T)), jnp.float32)
    _, assign = _population(rng, p, 6, 400)
    deadline = jnp.int32(180)
    ref = common.population_fitness(p, cum, deadline, prio, assign,
                                    objective, rule, 2, use_kernels=False)
    got = common.population_fitness(p, cum, deadline, prio, assign,
                                    objective, rule, 2, use_kernels=True)
    _exact(ref, got, f"{objective}/{rule}")


def test_population_fitness_frozen_prefix():
    """Rolling-replan candidates: frozen tasks pin the executed prefix; the
    kernel path must price them identically (the timing sweep skips them
    on both paths)."""
    rng = np.random.default_rng(31)
    p, w = scenario_case(13, family="fanout", fleet="mixed", horizon=400)
    cum = jnp.asarray(w.cumulative())
    prio = jnp.asarray(rng.normal(size=(5, p.T)), jnp.float32)
    _, assign = _population(rng, p, 5, 400)
    frozen = jnp.asarray(np.arange(p.T) < p.T // 3)
    ref = common.population_fitness(p, cum, jnp.int32(150), prio, assign,
                                    "carbon", "fixed", 2, frozen=frozen,
                                    use_kernels=False)
    got = common.population_fitness(p, cum, jnp.int32(150), prio, assign,
                                    "carbon", "fixed", 2, frozen=frozen,
                                    use_kernels=True)
    _exact(ref, got, "frozen")


@settings(max_examples=15, deadline=None, derandomize=True)
@given(seed=seeds(), rule=st.sampled_from(MACHINE_RULES),
       objective=st.sampled_from(("carbon", "energy")))
def test_population_fitness_property(seed, rule, objective):
    p, w = scenario_case(seed, family=FAMILY_NAMES[seed % len(FAMILY_NAMES)],
                         fleet=FLEET_NAMES[seed % len(FLEET_NAMES)],
                         horizon=320)
    cum = jnp.asarray(w.cumulative())
    rng = np.random.default_rng(seed)
    prio = jnp.asarray(rng.normal(size=(4, p.T)), jnp.float32)
    _, assign = _population(rng, p, 4, 320)
    deadline = jnp.int32(100 + seed % 150)
    ref = common.population_fitness(p, cum, deadline, prio, assign,
                                    objective, rule, 2, use_kernels=False)
    got = common.population_fitness(p, cum, deadline, prio, assign,
                                    objective, rule, 2, use_kernels=True)
    _exact(ref, got, f"seed={seed} {objective}/{rule}")


# ---------------------------------------------------------------------------
# solvers end to end — kernel fitness path reproduces identical solves
# ---------------------------------------------------------------------------

_SA_CFG = SAConfig(pop=16, iters=12, migrate_every=5)


def test_solve_sa_identical_under_kernels():
    p, w = scenario_case(17, family="diamond", fleet="tiered", horizon=400)
    cum = jnp.asarray(w.cumulative())
    key = jax.random.PRNGKey(0)
    ref = solve_sa(p, cum, jnp.int32(200), key, cfg=_SA_CFG,
                   use_kernels=False)
    got = solve_sa(p, cum, jnp.int32(200), key, cfg=_SA_CFG,
                   use_kernels=True)
    for r, g, name in zip(ref, got, ref._fields):
        _exact(r, g, f"solve_sa.{name}")


def test_solve_ga_identical_under_kernels():
    p, w = scenario_case(19, family="tpch", fleet="homog", horizon=400)
    cum = jnp.asarray(w.cumulative())
    key = jax.random.PRNGKey(2)
    cfg = GAConfig(pop=12, gens=6)
    ref = solve_ga(p, cum, jnp.int32(200), key, cfg=cfg, use_kernels=False)
    got = solve_ga(p, cum, jnp.int32(200), key, cfg=cfg, use_kernels=True)
    for r, g, name in zip(ref, got, ref._fields):
        _exact(r, g, f"solve_ga.{name}")


def test_solve_bilevel_batch_identical_under_kernels():
    """The batch entry point (vmapped solve_bilevel — Pallas under vmap)."""
    from repro.core.solvers.bilevel import solve_bilevel_batch
    pt, pm = 32, 4
    p1, w1 = scenario_case(23, family="fanout", fleet="homog", horizon=400,
                           pad_tasks=pt, pad_machines=pm)
    p2, w2 = scenario_case(29, family="chain", fleet="mixed", horizon=400,
                           pad_tasks=pt, pad_machines=pm)
    batch = stack_packed([p1, p2])
    cums = jnp.stack([jnp.asarray(w1.cumulative()),
                      jnp.asarray(w2.cumulative())])
    keys = jax.random.split(jax.random.PRNGKey(3), 2)
    ref = solve_bilevel_batch(batch, cums, keys, stretch=1.5, cfg1=_SA_CFG,
                              use_kernels=False)
    got = solve_bilevel_batch(batch, cums, keys, stretch=1.5, cfg1=_SA_CFG,
                              use_kernels=True)
    for r, g in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
        _exact(r, g, "solve_bilevel_batch")


# ---------------------------------------------------------------------------
# sa_bilevel_tiny golden — locked on BOTH fitness paths
# ---------------------------------------------------------------------------

def _sa_tiny_run(use_kernels):
    p, w = scenario_case(2024, family="layered", fleet="mixed", horizon=500)
    cum = jnp.asarray(w.cumulative())
    res = solve_bilevel(p, cum, jax.random.PRNGKey(42), stretch=1.5,
                        cfg1=SAConfig(pop=24, iters=30, migrate_every=10),
                        use_kernels=use_kernels)
    return {
        "opt_makespan": int(res.opt_makespan),
        "deadline": int(res.deadline),
        "baseline_carbon_g": float(res.baseline.carbon),
        "optimized_carbon_g": float(res.optimized.carbon),
        "optimized_makespan": int(res.optimized.makespan),
        "carbon_savings": float(res.carbon_savings),
        "optimized_start": np.asarray(res.optimized.start).tolist(),
        "optimized_assign": np.asarray(res.optimized.assign).tolist(),
    }


@pytest.mark.parametrize("use_kernels", [False, True])
def test_sa_bilevel_golden(use_kernels):
    """The stored SA golden must hold bit-exactly on both fitness paths —
    the 'goldens unchanged under REPRO_KERNELS=1' contract."""
    if not os.path.exists(GOLDEN_PATH):
        pytest.fail(f"golden file missing: {GOLDEN_PATH} — regenerate with "
                    "`PYTHONPATH=src python tests/test_kernels.py --write`")
    with open(GOLDEN_PATH) as f:
        want = json.load(f)["sa_bilevel_tiny"]
    got = _sa_tiny_run(use_kernels)
    for k in ("opt_makespan", "deadline", "optimized_makespan",
              "optimized_start", "optimized_assign"):
        assert got[k] == want[k], f"{k}: {got[k]!r} != golden {want[k]!r}"
    for k in ("baseline_carbon_g", "optimized_carbon_g", "carbon_savings"):
        # floats cross platforms: tight allclose, identical on like hosts
        assert_allclose(got[k], want[k], rtol=1e-5, err_msg=k)


# ---------------------------------------------------------------------------
# interpret-mode plumbing — the backend default lives in ops.py only
# ---------------------------------------------------------------------------

def test_kernels_require_explicit_interpret():
    """No kernel signature may default ``interpret`` (the silent-interpret
    bug: compiled callers falling back to the CPU interpreter on TPU)."""
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.kernels.ssd_scan import ssd_scan_pallas
    for fn in (schedule_delta_pallas, gate_quantile_stats_pallas,
               flash_attention_pallas, ssd_scan_pallas):
        wrapped = inspect.unwrap(fn, stop=lambda f: hasattr(f, "__wrapped__"))
        sig = inspect.signature(wrapped)
        param = sig.parameters["interpret"]
        assert param.default is inspect.Parameter.empty, \
            f"{fn.__name__} defaults interpret={param.default!r}"
        assert param.kind is inspect.Parameter.KEYWORD_ONLY


def test_kernels_enabled_resolution(monkeypatch):
    assert ops.kernels_enabled(True) is True
    assert ops.kernels_enabled(False) is False
    for val, want in [("1", True), ("true", True), ("ON", True),
                      ("yes", True), ("0", False), ("false", False),
                      ("off", False), ("No", False)]:
        monkeypatch.setenv("REPRO_KERNELS", val)
        assert ops.kernels_enabled() is want, val
        # explicit argument always wins over the env
        assert ops.kernels_enabled(not want) is (not want)
    monkeypatch.delenv("REPRO_KERNELS")
    assert ops.kernels_enabled() is ops.on_tpu()


# ---------------------------------------------------------------------------
# flash attention (allclose — softmax genuinely reassociates)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,KVH,S,dh,causal,window,dtype", [
    (2, 4, 2, 128, 64, True, 0, jnp.float32),
    (1, 8, 8, 256, 32, True, 64, jnp.float32),
    (2, 2, 1, 128, 64, False, 0, jnp.float32),
    (1, 4, 4, 128, 128, True, 0, jnp.bfloat16),
    (1, 8, 2, 512, 64, True, 0, jnp.float32),
])
def test_flash_attention_kernel(B, H, KVH, S, dh, causal, window, dtype):
    q = jax.random.normal(jax.random.key(1), (B, H, S, dh)).astype(dtype)
    k = jax.random.normal(jax.random.key(2), (B, KVH, S, dh)).astype(dtype)
    v = jax.random.normal(jax.random.key(3), (B, KVH, S, dh)).astype(dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=64, block_k=64, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32),
                    atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# ssd scan (allclose — chunked recurrence reassociates)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,P,G,N,chunk,dtype", [
    (2, 128, 4, 32, 2, 16, 32, jnp.float32),
    (1, 64, 2, 16, 1, 8, 16, jnp.float32),
    (1, 256, 8, 64, 1, 32, 64, jnp.float32),
    (2, 64, 4, 32, 4, 16, 32, jnp.bfloat16),
])
def test_ssd_scan_kernel(B, S, H, P, G, N, chunk, dtype):
    x = (0.5 * jax.random.normal(jax.random.key(4), (B, S, H, P))
         ).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(5), (B, S, H)))
    A = -jnp.exp(0.3 * jax.random.normal(jax.random.key(6), (H,)))
    Bm = 0.5 * jax.random.normal(jax.random.key(7), (B, S, G, N))
    Cm = 0.5 * jax.random.normal(jax.random.key(8), (B, S, G, N))
    y, h = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    yr, hr = ssd_ref(x.astype(jnp.float32), dt, A, Bm, Cm)
    tol = 3e-4 if dtype == jnp.float32 else 3e-2
    assert_allclose(np.asarray(y, np.float32), np.asarray(yr, np.float32),
                    atol=tol, rtol=tol)
    assert_allclose(np.asarray(h), np.asarray(hr), atol=tol, rtol=tol)


def _write_golden():
    ref = _sa_tiny_run(use_kernels=False)
    kern = _sa_tiny_run(use_kernels=True)
    assert ref == kern, "kernel path diverged from jnp path at write time"
    record = {
        "_regenerate": "PYTHONPATH=src python tests/test_kernels.py --write",
        "sa_bilevel_tiny": ref,
    }
    os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
    with open(GOLDEN_PATH, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {GOLDEN_PATH}")


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    if "--write" in sys.argv:
        _write_golden()
    else:
        print(__doc__)
